//! Theory validation (Theorems 13/15, §5.4): DSGD with optimal client
//! sampling on the quadratic testbed where x*, L and µ are exact.
//!
//! Reproduces three claims:
//!  1. the E‖x^k − x*‖² recursion of OCS sits between full participation
//!     and uniform sampling (γ^k interpolation);
//!  2. γ^k ∈ [m/n, 1] every round, approaching 1 as heterogeneity grows;
//!  3. OCS tolerates a larger maximum stable step size than uniform
//!     sampling (the "larger learning rates" claim).
//!
//! ```sh
//! cargo run --release --example dsgd_theory
//! ```

use fedsamp::bench::{f, Table};
use fedsamp::model::quadratic::QuadraticProblem;
use fedsamp::sampling::Sampler;
use fedsamp::sim::theory::{max_stable_eta, run_dsgd_quadratic};

fn main() {
    let n = 32;
    let m = 4;
    let problem =
        QuadraticProblem::generate_skewed(n, 32, 3.0, 1.5, 8.0, None, 11);
    let eta = 0.05 / problem.smoothness();
    println!(
        "testbed: n={n}, dim=32, L={:.3}, µ={:.3}, η={:.4}, m={m}",
        problem.smoothness(),
        problem.strong_convexity(),
        eta
    );

    // claim 1+2: the distance recursion per strategy
    println!("\n— E‖x^k − x*‖² trajectories (mean of 5 seeds) —");
    let mut t = Table::new(&["round", "full", "ocs", "uniform", "ocs γ̄"]);
    let runs_for = |s: &Sampler| -> Vec<fedsamp::sim::theory::TheoryRun> {
        (0..5)
            .map(|seed| run_dsgd_quadratic(&problem, s, m, eta, 400, 0.0, seed))
            .collect()
    };
    let full = runs_for(&Sampler::Full);
    let ocs = runs_for(&Sampler::Ocs);
    let uni = runs_for(&Sampler::Uniform);
    let mean_at = |rs: &[fedsamp::sim::theory::TheoryRun], k: usize| -> f64 {
        rs.iter().map(|r| r.rounds[k].dist_sq).sum::<f64>() / rs.len() as f64
    };
    let mean_gamma_at = |rs: &[fedsamp::sim::theory::TheoryRun], k: usize| {
        rs.iter().map(|r| r.rounds[k].gamma).sum::<f64>() / rs.len() as f64
    };
    for k in [0, 25, 50, 100, 200, 399] {
        t.row(vec![
            k.to_string(),
            format!("{:.3e}", mean_at(&full, k)),
            format!("{:.3e}", mean_at(&ocs, k)),
            format!("{:.3e}", mean_at(&uni, k)),
            f(mean_gamma_at(&ocs, k), 3),
        ]);
    }
    t.print();
    println!(
        "expected: full ≤ ocs ≤ uniform at every horizon; γ̄ ∈ [{:.3}, 1]",
        m as f64 / n as f64
    );

    // claim 3: maximum stable step size
    println!("\n— max stable step size (bisection, 150-round horizon) —");
    let mut t2 = Table::new(&["strategy", "max η", "×(1/L)"]);
    for s in [Sampler::Full, Sampler::Ocs, Sampler::Aocs { j_max: 4 },
              Sampler::Uniform] {
        let e = max_stable_eta(&problem, &s, m, 150, 5);
        t2.row(vec![
            s.name().into(),
            f(e, 4),
            f(e * problem.smoothness(), 2),
        ]);
    }
    t2.print();
    println!("expected: η_max(ocs) ≳ η_max(uniform) — the §5.4 claim");

    // heterogeneity sweep: skew ↑ ⇒ α ↓ ⇒ γ ↑ (OCS gains grow)
    println!("\n— heterogeneity sweep: client skew vs mean α, γ —");
    let mut t3 = Table::new(&["skew", "mean α", "mean γ"]);
    for skew in [0.0, 0.5, 1.5, 3.0] {
        let pr = QuadraticProblem::generate_skewed(
            n, 32, 3.0, skew, 8.0, None, 13,
        );
        let e = 0.05 / pr.smoothness();
        let run = run_dsgd_quadratic(&pr, &Sampler::Ocs, m, e, 100, 0.0, 3);
        let ma = run.rounds.iter().map(|r| r.alpha).sum::<f64>()
            / run.rounds.len() as f64;
        t3.row(vec![f(skew, 1), f(ma, 3), f(run.mean_gamma(), 3)]);
    }
    t3.print();
    println!("expected: mean α falls (and γ rises) as skew grows.");
}
