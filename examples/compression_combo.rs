//! Future-work §6 extension: optimal client sampling composed with
//! unbiased update compression (rand-k sparsification / QSGD dithering).
//!
//! The paper conjectures the two are orthogonal; this driver measures
//! accuracy-per-bit for {full, aocs} × {none, randk, qsgd} on the sim
//! path and prints the combined wins.
//!
//! ```sh
//! cargo run --release --example compression_combo
//! ```

use fedsamp::bench::{f, Table};
use fedsamp::compress::Compressor;
use fedsamp::config::{presets, DataSpec, Strategy};
use fedsamp::fl::TrainOptions;
use fedsamp::sim::run_sim_with;

fn main() {
    let mut base = presets::femnist(1, 3);
    base.rounds = 40;
    base.model = "native:logistic".into();
    base.data = DataSpec::FemnistLike { pool: 80, variant: 1 };
    base.eval_examples = 320;
    base.secure_updates = false;

    // sim-path model dim: 64 features ×62 classes + bias ≈ 4030 params.
    // Some(Compressor::None) (not None) keeps the baseline arm honest:
    // a None option would inherit any config-level compressor.
    let compressors: [(&str, Option<Compressor>); 3] = [
        ("none", Some(Compressor::None)),
        ("randk256", Some(Compressor::RandK { k: 256 })),
        ("qsgd4", Some(Compressor::QsgdQuant { levels: 4 })),
    ];

    let mut t = Table::new(&[
        "strategy",
        "compressor",
        "final_loss",
        "final_acc",
        "total_Mbits",
        "acc_per_Mbit",
    ]);
    for strategy in [Strategy::Full, Strategy::Aocs { j_max: 4 }] {
        for (cname, comp) in &compressors {
            let cfg = base.with_strategy(strategy.clone());
            let opts = TrainOptions {
                compressor: comp.clone(),
                ..TrainOptions::default()
            };
            let run = run_sim_with(&cfg, &opts).expect("run failed");
            // measured wire bytes — native sparse/quantized payloads,
            // counted from their actual encoded length
            let mbits = run.total_uplink_bytes() as f64 * 8.0 / 1e6;
            t.row(vec![
                strategy.name().into(),
                cname.to_string(),
                f(run.final_train_loss(), 4),
                f(run.final_accuracy(), 4),
                f(mbits, 2),
                f(run.final_accuracy() / mbits, 4),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected: aocs×compression multiplies the bit savings while \
         keeping accuracy near full participation — the §6 conjecture."
    );
}
