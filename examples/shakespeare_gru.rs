//! Figure 6 on the XLA path: the char-GRU next-character task with
//! n = 32 cohort and m ∈ {2, 6}, comparing all three strategies.
//!
//! ```sh
//! make artifacts && cargo run --release --example shakespeare_gru \
//!     [-- --rounds 40 --pool 120 --workers 4]
//! ```

use fedsamp::config::{presets, DataSpec};
use fedsamp::exp::figures::print_summary;
use fedsamp::exp::{default_artifacts_dir, have_artifacts, run_comparison};
use fedsamp::fl::TrainOptions;
use fedsamp::util::args::Cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("shakespeare_gru", "XLA-path Figure 6 driver")
        .opt("rounds", Some("40"), "communication rounds")
        .opt("pool", Some("120"), "client pool (paper: 715 roles)")
        .opt("workers", Some("4"), "PJRT worker threads")
        .opt("ms", Some("2,6"), "budgets to run");
    let p = cli.parse(&argv).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let artifacts = default_artifacts_dir();
    if !have_artifacts(&artifacts) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    for m in p.usize_list("ms") {
        let mut cfg = presets::shakespeare(32, m);
        cfg.rounds = p.usize("rounds");
        cfg.data = DataSpec::ShakespeareLike { pool: p.usize("pool") };
        cfg.workers = p.usize("workers");
        cfg.eval_examples = 512;
        println!(
            "\nshakespeare GRU: n=32, m={m}, {} rounds, pool {}",
            cfg.rounds,
            p.usize("pool")
        );
        let opts =
            TrainOptions { verbose_every: 10, ..TrainOptions::default() };
        let arms = run_comparison(&cfg, 1, &artifacts, &opts)
            .expect("shakespeare run failed");
        print_summary(&format!("Figure 6 (m={m}, XLA path)"), &arms);
    }
}
