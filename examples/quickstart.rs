//! Quickstart: the paper's three-way comparison in under a minute.
//!
//! Runs FedAvg on the unbalanced FEMNIST-like dataset (sim path — no
//! artifacts needed) with full participation, uniform sampling, and
//! approximate optimal client sampling (Algorithm 2), then prints the
//! summary table the paper's §5.4 narrates.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use fedsamp::config::{presets, DataSpec};
use fedsamp::exp::figures::{print_summary, scaled, Scale};
use fedsamp::exp::run_comparison;
use fedsamp::fl::TrainOptions;

fn main() {
    // Figure-3 preset (FEMNIST dataset 1, n=32, m=3), shrunk to demo size
    let mut cfg = scaled(presets::femnist(1, 3), Scale::Quick);
    cfg.model = "native:logistic".into(); // sim path: no artifacts needed
    cfg.data = DataSpec::FemnistLike { pool: 80, variant: 1 };
    cfg.rounds = 40;
    cfg.name = "quickstart".into();

    println!(
        "quickstart: FedAvg, n={} cohort, m={} expected uploads, {} rounds",
        cfg.cohort, cfg.budget, cfg.rounds
    );
    let arms = run_comparison(&cfg, 2, ".", &TrainOptions::default())
        .expect("comparison failed");
    print_summary("Quickstart (FEMNIST-like DS1, m=3)", &arms);

    println!(
        "\nReading the table: optimal sampling (aocs) should sit between\n\
         full participation and uniform sampling on accuracy-per-round,\n\
         and beat BOTH on accuracy-per-megabit (the paper's headline)."
    );
}
