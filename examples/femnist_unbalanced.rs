//! End-to-end driver (DESIGN.md §4, EXPERIMENTS.md §E2E): the full
//! three-layer stack on the Figure-3 workload.
//!
//! Pallas kernels → JAX model → AOT HLO → rust PJRT runtime → federated
//! orchestration with AOCS. Trains the 242k-parameter FEMNIST MLP across
//! an unbalanced 80-client pool for 60 rounds under all three strategies
//! and logs the loss/accuracy/bits curves.
//!
//! ```sh
//! make artifacts && cargo run --release --example femnist_unbalanced \
//!     [-- --rounds 60 --pool 80 --seeds 1 --workers 4 --out results/]
//! ```

use fedsamp::config::{presets, DataSpec};
use fedsamp::exp::figures::{print_series, print_summary};
use fedsamp::exp::{default_artifacts_dir, have_artifacts, run_comparison, save_arms};
use fedsamp::fl::TrainOptions;
use fedsamp::util::args::Cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("femnist_unbalanced", "XLA-path Figure 3 driver")
        .opt("rounds", Some("60"), "communication rounds")
        .opt("pool", Some("80"), "client pool size")
        .opt("m", Some("3"), "expected budget m")
        .opt("seeds", Some("1"), "seeds to average")
        .opt("workers", Some("4"), "PJRT worker threads")
        .opt("out", None, "save JSON/CSV series here");
    let p = cli.parse(&argv).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });

    let artifacts = default_artifacts_dir();
    if !have_artifacts(&artifacts) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let mut cfg = presets::femnist(1, p.usize("m"));
    cfg.name = "e2e_femnist1".into();
    cfg.rounds = p.usize("rounds");
    cfg.data = DataSpec::FemnistLike { pool: p.usize("pool"), variant: 1 };
    cfg.workers = p.usize("workers");
    cfg.eval_examples = 496;
    cfg.secure_updates = true; // the deployable path, masks and all

    println!(
        "e2e femnist: model=femnist_mlp (242k params), pool={}, n={}, m={}, \
         {} rounds, {} workers, secure aggregation ON",
        p.usize("pool"),
        cfg.cohort,
        cfg.budget,
        cfg.rounds,
        cfg.workers
    );

    let opts =
        TrainOptions { verbose_every: 5, ..TrainOptions::default() };
    let t0 = std::time::Instant::now();
    let arms = run_comparison(&cfg, p.u64("seeds"), &artifacts, &opts)
        .expect("e2e run failed");
    let wall = t0.elapsed();

    print_series("E2E Figure 3 (XLA path)", &arms);
    print_summary("E2E Figure 3 (XLA path)", &arms);
    println!("\nwall-clock: {:.1}s for 3 arms", wall.as_secs_f64());

    if let Some(out) = p.get("out") {
        let paths = save_arms(&arms, out).expect("save failed");
        println!("saved {} files under {out}", paths.len());
    }
}
