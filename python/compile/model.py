"""L2: JAX model definitions (fwd/bwd) built on the L1 Pallas kernels.

Three model families cover the paper's workloads:

* ``mlp``   — image classification head (FEMNIST-like / CIFAR-like).
* ``cnn``   — the McMahan-et-al. CNN (2 conv blocks + dense) used by the
              paper's FEMNIST experiments; conv layers use ``lax.conv``
              (XLA already fuses these optimally), dense layers and the
              loss head use the Pallas kernels.
* ``gru``   — next-character model (Shakespeare-like): embedding + N GRU
              layers + dense head, gates via the Pallas matmul.

Every model exposes two AOT entry points, each lowered once by
``aot.py`` and executed forever after from the rust coordinator:

* ``train_step(params…, xb, yb_onehot, lr) -> (params…, loss)``
  one mini-batch SGD step; the rust client loop iterates it R times.
* ``eval_step(params…, xb, yb_onehot) -> (loss_sum, correct)``
  summed loss + correct-count over an eval batch.

Parameters travel as a *flat ordered list* of f32 arrays; the order is
frozen in ``param_specs`` and mirrored in artifacts/manifest.json so the
rust side can (de)serialize without pytree knowledge.

``use_pallas=False`` builds a structurally identical variant where the
dense ops are plain jnp — the interpret-mode Pallas while-loops are a
CPU-only artifact, so the rust benches use the XLA variant for wall-clock
runs while pytest pins pallas ≡ jnp ≡ ref (see DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import pmatmul, xent_loss
from .kernels.ref import matmul_ref, softmax_xent_ref

# --------------------------------------------------------------------------
# primitives parameterized on the kernel backend
# --------------------------------------------------------------------------


def _dense(x, w, b, *, activation: str, use_pallas: bool):
    mm = pmatmul if use_pallas else matmul_ref
    z = mm(x, w) + b
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    if activation == "sigmoid":
        return jax.nn.sigmoid(z)
    raise ValueError(activation)


def _ce_loss_vec(logits, onehot, *, use_pallas: bool):
    if use_pallas:
        return xent_loss(logits, onehot)
    loss, _ = softmax_xent_ref(logits, onehot)
    return loss


# --------------------------------------------------------------------------
# model specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """Everything aot.py needs to lower + describe one model variant."""

    name: str
    kind: str                      # mlp | cnn | gru
    param_specs: tuple[ParamSpec, ...]
    forward: Callable              # (params list, x int/f32 batch) -> logits
    input_shape: tuple[int, ...]   # per-example shape (images: flat; text: (seq,))
    num_classes: int
    batch_size: int
    eval_batch: int
    input_dtype: str               # "f32" | "i32"
    use_pallas: bool

    def init(self, key) -> list:
        params = []
        for spec in self.param_specs:
            key, sub = jax.random.split(key)
            if len(spec.shape) >= 2:
                fan_in = 1
                for s in spec.shape[:-1]:
                    fan_in *= s
                scale = 1.0 / float(max(fan_in, 1)) ** 0.5
                params.append(
                    scale * jax.random.truncated_normal(
                        sub, -2.0, 2.0, spec.shape, jnp.float32))
            else:
                params.append(jnp.zeros(spec.shape, jnp.float32))
        return params

    @property
    def num_params(self) -> int:
        return sum(s.size for s in self.param_specs)


# ---------------------------------- MLP ----------------------------------


def make_mlp(name: str, *, input_dim: int, hidden: Sequence[int],
             num_classes: int, batch_size: int, eval_batch: int,
             use_pallas: bool) -> ModelDef:
    dims = [input_dim, *hidden, num_classes]
    specs = []
    for i in range(len(dims) - 1):
        specs.append(ParamSpec(f"w{i}", (dims[i], dims[i + 1])))
        specs.append(ParamSpec(f"b{i}", (dims[i + 1],)))

    def forward(params, x):
        h = x
        nlayer = len(dims) - 1
        for i in range(nlayer):
            act = "relu" if i < nlayer - 1 else "none"
            h = _dense(h, params[2 * i], params[2 * i + 1],
                       activation=act, use_pallas=use_pallas)
        return h

    return ModelDef(name, "mlp", tuple(specs), forward, (input_dim,),
                    num_classes, batch_size, eval_batch, "f32", use_pallas)


# ---------------------------------- CNN ----------------------------------


def make_cnn(name: str, *, side: int, channels: int, num_classes: int,
             batch_size: int, eval_batch: int, use_pallas: bool,
             conv1: int = 32, conv2: int = 64, dense: int = 128) -> ModelDef:
    """McMahan-style CNN: conv5x5(c1) → pool2 → conv5x5(c2) → pool2 → dense."""
    flat_side = side // 4
    flat = flat_side * flat_side * conv2
    specs = (
        ParamSpec("conv1_w", (5, 5, channels, conv1)),
        ParamSpec("conv1_b", (conv1,)),
        ParamSpec("conv2_w", (5, 5, conv1, conv2)),
        ParamSpec("conv2_b", (conv2,)),
        ParamSpec("dense_w", (flat, dense)),
        ParamSpec("dense_b", (dense,)),
        ParamSpec("head_w", (dense, num_classes)),
        ParamSpec("head_b", (num_classes,)),
    )

    def _conv(x, w, b):
        y = lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jnp.maximum(y + b, 0.0)

    def _pool(x):
        return lax.reduce_window(
            x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    def forward(params, x):
        b = x.shape[0]
        img = x.reshape(b, side, side, channels)
        h = _pool(_conv(img, params[0], params[1]))
        h = _pool(_conv(h, params[2], params[3]))
        h = h.reshape(b, flat)
        h = _dense(h, params[4], params[5], activation="relu",
                   use_pallas=use_pallas)
        return _dense(h, params[6], params[7], activation="none",
                      use_pallas=use_pallas)

    return ModelDef(name, "cnn", specs, forward,
                    (side * side * channels,), num_classes, batch_size,
                    eval_batch, "f32", use_pallas)


# ---------------------------------- GRU ----------------------------------


def make_gru(name: str, *, vocab: int, embed: int, hidden: int, layers: int,
             seq_len: int, batch_size: int, eval_batch: int,
             use_pallas: bool) -> ModelDef:
    """Char-level GRU stack predicting the next character after seq_len."""
    specs = [ParamSpec("embed", (vocab, embed))]
    in_dim = embed
    for ell in range(layers):
        specs.append(ParamSpec(f"gru{ell}_wx", (in_dim, 3 * hidden)))
        specs.append(ParamSpec(f"gru{ell}_wh", (hidden, 3 * hidden)))
        specs.append(ParamSpec(f"gru{ell}_b", (3 * hidden,)))
        in_dim = hidden
    specs.append(ParamSpec("head_w", (hidden, vocab)))
    specs.append(ParamSpec("head_b", (vocab,)))

    mm = (lambda a, b: pmatmul(a, b)) if use_pallas else matmul_ref

    def _gru_cell(h, x_t, wx, wh, b):
        gx = mm(x_t, wx)
        gh = mm(h, wh)
        zx, rx, nx = jnp.split(gx + b, 3, axis=-1)
        zh, rh, nh = jnp.split(gh, 3, axis=-1)
        z = jax.nn.sigmoid(zx + zh)
        r = jax.nn.sigmoid(rx + rh)
        n = jnp.tanh(nx + r * nh)
        return (1.0 - z) * n + z * h

    def forward(params, tokens):
        b = tokens.shape[0]
        emb = params[0]
        x = jnp.take(emb, tokens.astype(jnp.int32), axis=0)  # (B, T, E)
        h_in = x
        idx = 1
        for _ in range(layers):
            wx, wh, bb = params[idx], params[idx + 1], params[idx + 2]
            idx += 3
            h0 = jnp.zeros((b, wh.shape[0]), jnp.float32)

            def step(h, x_t, wx=wx, wh=wh, bb=bb):
                hn = _gru_cell(h, x_t, wx, wh, bb)
                return hn, hn

            _, hs = lax.scan(step, h0, jnp.swapaxes(h_in, 0, 1))
            h_in = jnp.swapaxes(hs, 0, 1)  # (B, T, H)
        last = h_in[:, -1, :]
        return _dense(last, params[idx], params[idx + 1],
                      activation="none", use_pallas=use_pallas)

    return ModelDef(name, "gru", tuple(specs), forward, (seq_len,), vocab,
                    batch_size, eval_batch, "i32", use_pallas)


# --------------------------------------------------------------------------
# train / eval steps
# --------------------------------------------------------------------------


def loss_fn(model: ModelDef, params, xb, onehot):
    logits = model.forward(params, xb)
    # Padded examples carry an all-zero one-hot row => masked out of the mean.
    per_ex = _ce_loss_vec(logits, onehot, use_pallas=model.use_pallas)
    mask = jnp.sum(onehot, axis=-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_ex * mask) / denom


def make_train_step(model: ModelDef):
    def train_step(*args):
        n = len(model.param_specs)
        params = list(args[:n])
        xb, onehot, lr = args[n], args[n + 1], args[n + 2]
        loss, grads = jax.value_and_grad(
            functools.partial(loss_fn, model))(params, xb, onehot)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return (*new_params, loss)

    return train_step


def make_eval_step(model: ModelDef):
    def eval_step(*args):
        n = len(model.param_specs)
        params = list(args[:n])
        xb, onehot = args[n], args[n + 1]
        logits = model.forward(params, xb)
        per_ex = _ce_loss_vec(logits, onehot, use_pallas=model.use_pallas)
        mask = jnp.sum(onehot, axis=-1)
        pred = jnp.argmax(logits, axis=-1)
        label = jnp.argmax(onehot, axis=-1)
        correct = jnp.sum(jnp.where(mask > 0, (pred == label).astype(
            jnp.float32), 0.0))
        return jnp.sum(per_ex * mask), correct

    return eval_step


def example_args(model: ModelDef, *, train: bool):
    """ShapeDtypeStructs matching the AOT entry-point signature."""
    f32, i32 = jnp.float32, jnp.int32
    b = model.batch_size if train else model.eval_batch
    params = [jax.ShapeDtypeStruct(s.shape, f32) for s in model.param_specs]
    xdt = f32 if model.input_dtype == "f32" else i32
    xb = jax.ShapeDtypeStruct((b, *model.input_shape), xdt)
    onehot = jax.ShapeDtypeStruct((b, model.num_classes), f32)
    if train:
        return (*params, xb, onehot, jax.ShapeDtypeStruct((), f32))
    return (*params, xb, onehot)


# --------------------------------------------------------------------------
# registry — the set of artifacts `make artifacts` builds
# --------------------------------------------------------------------------


def build_registry(*, small: bool = False) -> dict:
    """All AOT model variants.

    ``small=True`` shrinks hidden sizes for fast pytest runs; the real
    artifact build uses the full sizes below.
    """
    h = (64, 32) if small else (256, 128)
    gru_h = 32 if small else 64
    models = [
        # FEMNIST-like: 28x28 grayscale, 62 classes, local batch 20 (paper §5.2)
        make_mlp("femnist_mlp", input_dim=784, hidden=h, num_classes=62,
                 batch_size=20, eval_batch=64, use_pallas=False),
        make_mlp("femnist_mlp_pallas", input_dim=784, hidden=h,
                 num_classes=62, batch_size=20, eval_batch=64,
                 use_pallas=True),
        # McMahan CNN used by the paper's FEMNIST runs
        make_cnn("femnist_cnn", side=28, channels=1, num_classes=62,
                 batch_size=20, eval_batch=64, use_pallas=False),
        # CIFAR100-like: 32x32x3, 100 classes, balanced (paper Appendix G)
        make_mlp("cifar_mlp", input_dim=3072, hidden=h, num_classes=100,
                 batch_size=20, eval_batch=64, use_pallas=False),
        # Shakespeare-like: 86-char vocab, seq len 5, batch 8 (paper §5.3)
        make_gru("shakespeare_gru", vocab=86, embed=8, hidden=gru_h,
                 layers=2, seq_len=5, batch_size=8, eval_batch=64,
                 use_pallas=False),
        make_gru("shakespeare_gru_pallas", vocab=86, embed=8, hidden=gru_h,
                 layers=2, seq_len=5, batch_size=8, eval_batch=64,
                 use_pallas=True),
    ]
    return {m.name: m for m in models}
