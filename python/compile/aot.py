"""AOT pipeline: lower every registry model's train/eval step to HLO text.

Build-time only — `make artifacts` runs this once; the rust coordinator
then loads `artifacts/*.hlo.txt` through PJRT and python never appears on
the training path again.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model NAME:
  NAME_train.hlo.txt    (params…, xb, onehot, lr) -> (params…, loss)
  NAME_eval.hlo.txt     (params…, xb, onehot)     -> (loss_sum, correct)
  NAME_init.bin         f32-LE concat of initial params (seeded)
plus a single manifest.json describing shapes/dtypes/sizes for rust.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import struct

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (ModelDef, build_registry, example_args, make_eval_step,
                    make_train_step)

INIT_SEED = 20200530  # arXiv id of the paper, why not


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_model(model: ModelDef, out_dir: str) -> dict:
    train = jax.jit(make_train_step(model))
    evalf = jax.jit(make_eval_step(model))
    train_hlo = to_hlo_text(train.lower(*example_args(model, train=True)))
    eval_hlo = to_hlo_text(evalf.lower(*example_args(model, train=False)))

    train_path = f"{model.name}_train.hlo.txt"
    eval_path = f"{model.name}_eval.hlo.txt"
    with open(os.path.join(out_dir, train_path), "w") as f:
        f.write(train_hlo)
    with open(os.path.join(out_dir, eval_path), "w") as f:
        f.write(eval_hlo)

    # Deterministic initial parameters (rust can also re-init per seed).
    params = model.init(jax.random.PRNGKey(INIT_SEED))
    init_path = f"{model.name}_init.bin"
    with open(os.path.join(out_dir, init_path), "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())

    return {
        "kind": model.kind,
        "train_hlo": train_path,
        "eval_hlo": eval_path,
        "init_params": init_path,
        "params": [
            {"name": s.name, "shape": list(s.shape), "size": s.size}
            for s in model.param_specs
        ],
        "num_params": model.num_params,
        "input_shape": list(model.input_shape),
        "input_dtype": model.input_dtype,
        "num_classes": model.num_classes,
        "batch_size": model.batch_size,
        "eval_batch": model.eval_batch,
        "use_pallas": model.use_pallas,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of registry names (default: all)")
    ap.add_argument("--small", action="store_true",
                    help="small hidden sizes (test builds)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    registry = build_registry(small=args.small)
    names = args.models or list(registry)

    manifest = {"format_version": 1, "seed": INIT_SEED, "models": {}}
    for name in names:
        model = registry[name]
        print(f"[aot] lowering {name} "
              f"({model.num_params} params, pallas={model.use_pallas}) ...")
        manifest["models"][name] = lower_model(model, args.out_dir)

    blob = json.dumps(manifest, indent=1, sort_keys=True)
    manifest["sha256"] = hashlib.sha256(blob.encode()).hexdigest()
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {len(names)} models to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
