"""L1 Pallas kernel: fused in-place SGD parameter update ``p - lr * g``.

Elementwise over the flattened parameter vector, gridded in VPU-friendly
1-D blocks. The learning rate arrives as a (1,)-shaped array replicated
to every grid step via a constant index map (scalar-prefetch is a
TPU-Mosaic feature; a broadcast block is the portable spelling).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import INTERPRET

BLOCK = 4096


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update_flat(p, g, lr, *, block: int = BLOCK,
                    interpret: bool = INTERPRET):
    """SGD step over 1-D f32 arrays. ``lr`` is a scalar or (1,) array."""
    if p.shape != g.shape or p.ndim != 1:
        raise ValueError(f"sgd_update_flat shapes: {p.shape} vs {g.shape}")
    n = p.shape[0]
    lr = jnp.asarray(lr, jnp.float32).reshape((1,))
    pad = (-n) % block
    pp = jnp.pad(p.astype(jnp.float32), (0, pad))
    gp = jnp.pad(g.astype(jnp.float32), (0, pad))
    grid = (pp.shape[0] // block,)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(pp.shape, jnp.float32),
        interpret=interpret,
    )(pp, gp, lr)
    return out[:n]


def sgd_update(params, grads, lr, *, interpret: bool = INTERPRET):
    """Apply the fused SGD kernel leaf-wise over a parameter pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    gleaves = jax.tree_util.tree_leaves(grads)
    new = [
        sgd_update_flat(p.reshape(-1), g.reshape(-1), lr,
                        interpret=interpret).reshape(p.shape)
        for p, g in zip(leaves, gleaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, new)
