"""Pallas kernels (L1) and their pure-jnp oracles."""

from .fused_linear import (  # noqa: F401
    ACTIVATIONS,
    fused_linear,
    matmul,
    mxu_utilization,
    pmatmul,
    vmem_bytes,
)
from .sgd_update import sgd_update, sgd_update_flat  # noqa: F401
from .softmax_xent import softmax_xent, xent_loss  # noqa: F401
