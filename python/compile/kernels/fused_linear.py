"""L1 Pallas kernels: tiled matmul and fused linear (matmul + bias + activation).

These are the compute hot-spots of every client's local training epoch
(dense layers of the MLP/CNN heads and all GRU gate projections).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles M×N×K into
MXU-friendly blocks (default 128×128×128, f32). Each (i, j) output block
stays resident in VMEM while the k-loop streams x/w blocks HBM→VMEM via
BlockSpec; the epilogue (bias + activation) runs on the final k step so
the activation never round-trips to HBM. On this image we execute under
``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls); correctness
is asserted against ``ref.py`` by pytest and the real-TPU efficiency is
estimated structurally in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Everything in this repo runs the interpret path (CPU PJRT target).
INTERPRET = True

ACTIVATIONS = ("none", "relu", "tanh", "sigmoid")


def _apply_activation(x, activation: str):
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "tanh":
        return jnp.tanh(x)
    if activation == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {activation!r}")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _block(dim: int, target: int, multiple: int = 8) -> int:
    """Pick a block size: full (rounded-up) dim for small axes, else `target`.

    `target`=128 matches the MXU systolic-array tile; small axes round up
    to the 8-sublane granule instead of wasting a full 128 tile.
    """
    return target if dim >= target else _round_up(max(dim, 1), multiple)


def _pad2(a, m0: int, m1: int):
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    """Accumulating matmul tile: o[i,j] += x[i,k] @ w[k,j] over the k grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _fused_linear_kernel(
    x_ref, w_ref, b_ref, o_ref, *, k_steps: int, activation: str
):
    """Matmul tile with a bias+activation epilogue on the last k step."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = _apply_activation(o_ref[...] + b_ref[...], activation)


def matmul(x, w, *, bm: int | None = None, bn: int | None = None,
           bk: int | None = None, interpret: bool = INTERPRET):
    """Tiled Pallas matmul ``x @ w`` for f32 operands of any 2-D shape.

    Inputs are zero-padded up to block multiples and the result sliced
    back, so arbitrary (M, K) x (K, N) shapes are supported.
    """
    (m, k), (k2, n) = x.shape, w.shape
    if k != k2:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {w.shape}")
    bm = bm or _block(m, 128)
    bn = bn or _block(n, 128)
    bk = bk or _block(k, 128)
    xp = _pad2(x.astype(jnp.float32), bm, bk)
    wp = _pad2(w.astype(jnp.float32), bk, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def fused_linear(x, w, b, *, activation: str = "none",
                 bm: int | None = None, bn: int | None = None,
                 bk: int | None = None, interpret: bool = INTERPRET):
    """Fused ``activation(x @ w + b)`` — one VMEM-resident epilogue, no
    extra HBM round-trip for the pre-activation. ``b`` has shape (N,)."""
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    (m, k), (k2, n) = x.shape, w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(
            f"fused_linear shape mismatch: {x.shape} @ {w.shape} + {b.shape}"
        )
    bm = bm or _block(m, 128)
    bn = bn or _block(n, 128)
    bk = bk or _block(k, 128)
    xp = _pad2(x.astype(jnp.float32), bm, bk)
    wp = _pad2(w.astype(jnp.float32), bk, bn)
    bp = _pad2(b.astype(jnp.float32)[None, :], 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(
            _fused_linear_kernel, k_steps=grid[2], activation=activation
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


# --------------------------------------------------------------------------
# Differentiable wrapper: autodiff cannot see through pallas_call, so the
# VJP is spelled out with the same tiled kernel (dA = g @ B^T, dB = A^T @ g).
# --------------------------------------------------------------------------


@jax.custom_vjp
def pmatmul(x, w):
    """Differentiable Pallas matmul used by the L2 models."""
    return matmul(x, w)


def _pmatmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _pmatmul_bwd(res, g):
    x, w = res
    return matmul(g, w.T), matmul(x.T, g)


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Structural VMEM footprint of one grid step (x, w, bias, acc blocks).

    Used by EXPERIMENTS.md §Perf to check the default tiling fits the
    ~16 MiB/core VMEM budget with room for double buffering.
    """
    return dtype_bytes * (bm * bk + bk * bn + bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued MACs doing useful work after padding."""
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    return (m * n * k) / float(mp * np_ * kp)
