"""L1 Pallas kernel: fused softmax cross-entropy (loss + gradient).

One pass over the logits block produces both the per-example loss and
``dlogits = softmax(logits) - onehot`` — the residual the backward pass
needs — so the loss head costs a single HBM read of the logits.

The class axis is kept whole inside the block (num_classes is 62/86/100
in this paper's workloads — far below the 128-lane tile), the batch axis
is gridded. Labels enter as a float one-hot matrix, which keeps the
kernel dtype-uniform and makes the custom VJP trivial.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import INTERPRET, _block, _round_up

_NEG_INF = -1e30


def _softmax_xent_kernel(logits_ref, onehot_ref, loss_ref, dlogits_ref):
    logits = logits_ref[...]
    onehot = onehot_ref[...]
    # Numerically stable log-softmax; padded classes carry -1e30 logits so
    # they contribute ~0 probability mass and 0 gradient.
    zmax = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - zmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    log_probs = shifted - lse
    loss_ref[...] = -jnp.sum(onehot * log_probs, axis=-1)
    dlogits_ref[...] = jnp.exp(log_probs) - onehot


def softmax_xent(logits, onehot, *, bm: int | None = None,
                 interpret: bool = INTERPRET):
    """Fused CE loss. Returns ``(loss[B], dlogits[B, C])``.

    ``onehot`` rows may be all-zero (padding examples): such rows get
    loss 0 contribution only through softmax mass — callers mask them.
    """
    b, c = logits.shape
    if onehot.shape != (b, c):
        raise ValueError(f"softmax_xent shapes: {logits.shape} vs {onehot.shape}")
    bm = bm or _block(b, 128)
    bc = _round_up(c, 8)
    pb = (-b) % bm
    pc = bc - c
    lp = jnp.pad(logits.astype(jnp.float32), ((0, pb), (0, pc)),
                 constant_values=_NEG_INF)
    op = jnp.pad(onehot.astype(jnp.float32), ((0, pb), (0, pc)))
    grid = (lp.shape[0] // bm,)
    loss, dlogits = pl.pallas_call(
        _softmax_xent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bc), lambda i: (i, 0)),
            pl.BlockSpec((bm, bc), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm, bc), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((lp.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct(lp.shape, jnp.float32),
        ),
        interpret=interpret,
    )(lp, op)
    return loss[:b], dlogits[:b, :c]


@jax.custom_vjp
def xent_loss(logits, onehot):
    """Differentiable per-example cross-entropy via the fused kernel."""
    loss, _ = softmax_xent(logits, onehot)
    return loss


def _xent_fwd(logits, onehot):
    loss, dlogits = softmax_xent(logits, onehot)
    return loss, dlogits


def _xent_bwd(dlogits, g):
    return dlogits * g[:, None], jnp.zeros_like(dlogits)


xent_loss.defvjp(_xent_fwd, _xent_bwd)
