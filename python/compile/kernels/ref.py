"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

pytest asserts ``assert_allclose(kernel(...), ref(...))`` under hypothesis
shape/dtype sweeps — this file must stay free of pallas imports.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def fused_linear_ref(x, w, b, *, activation: str = "none"):
    z = matmul_ref(x, w) + b.astype(jnp.float32)
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    if activation == "sigmoid":
        return jax.nn.sigmoid(z)
    raise ValueError(f"unknown activation {activation!r}")


def softmax_xent_ref(logits, onehot):
    logits = logits.astype(jnp.float32)
    onehot = onehot.astype(jnp.float32)
    log_probs = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.sum(onehot * log_probs, axis=-1)
    dlogits = jnp.exp(log_probs) - onehot
    return loss, dlogits


def sgd_update_flat_ref(p, g, lr):
    return p.astype(jnp.float32) - jnp.float32(lr) * g.astype(jnp.float32)
