"""L2 model correctness: shapes, gradients, pallas/jnp variant agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (build_registry, example_args, loss_fn,
                           make_eval_step, make_train_step)

REG = build_registry(small=True)


def _batch(model, seed=0):
    rng = np.random.RandomState(seed)
    b = model.batch_size
    if model.input_dtype == "f32":
        xb = rng.randn(b, *model.input_shape).astype("float32")
    else:
        xb = rng.randint(0, model.num_classes,
                         (b, *model.input_shape)).astype("int32")
    onehot = jax.nn.one_hot(
        rng.randint(0, model.num_classes, b), model.num_classes)
    return jnp.asarray(xb), onehot


@pytest.mark.parametrize("name", sorted(REG))
def test_train_step_shapes_and_loss_finite(name):
    model = REG[name]
    params = model.init(jax.random.PRNGKey(0))
    xb, onehot = _batch(model)
    out = make_train_step(model)(*params, xb, onehot, jnp.float32(0.05))
    assert len(out) == len(params) + 1
    for p, spec in zip(out[:-1], model.param_specs):
        assert p.shape == spec.shape
    assert np.isfinite(float(out[-1]))


@pytest.mark.parametrize("name", sorted(REG))
def test_eval_step_counts(name):
    model = REG[name]
    params = model.init(jax.random.PRNGKey(1))
    xb, onehot = _batch(model)
    # pad eval batch up to eval_batch with zero-onehot rows
    eb = model.eval_batch
    xb = jnp.concatenate([xb] * ((eb + xb.shape[0] - 1) // xb.shape[0]))[:eb]
    oh = jnp.concatenate([onehot] * ((eb + onehot.shape[0] - 1)
                                     // onehot.shape[0]))[:eb]
    # zero out the last quarter (padding)
    mask_from = 3 * eb // 4
    oh = oh.at[mask_from:].set(0.0)
    loss_sum, correct = make_eval_step(model)(*params, xb, oh)
    assert 0.0 <= float(correct) <= mask_from
    assert np.isfinite(float(loss_sum))


def test_training_reduces_loss_mlp():
    model = REG["femnist_mlp"]
    params = model.init(jax.random.PRNGKey(2))
    xb, onehot = _batch(model, seed=7)
    step = jax.jit(make_train_step(model))
    first = None
    for _ in range(30):
        out = step(*params, xb, onehot, jnp.float32(0.2))
        params, loss = list(out[:-1]), float(out[-1])
        first = first if first is not None else loss
    assert loss < first * 0.5, (first, loss)


def test_pallas_and_jnp_variants_agree():
    """femnist_mlp vs femnist_mlp_pallas: same init => same loss/grads."""
    m_ref, m_pal = REG["femnist_mlp"], REG["femnist_mlp_pallas"]
    params = m_ref.init(jax.random.PRNGKey(3))
    xb, onehot = _batch(m_ref, seed=9)
    out_ref = make_train_step(m_ref)(*params, xb, onehot, jnp.float32(0.1))
    out_pal = make_train_step(m_pal)(*params, xb, onehot, jnp.float32(0.1))
    for a, b in zip(out_ref, out_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_gru_variants_agree():
    m_ref, m_pal = REG["shakespeare_gru"], REG["shakespeare_gru_pallas"]
    params = m_ref.init(jax.random.PRNGKey(4))
    xb, onehot = _batch(m_ref, seed=11)
    out_ref = make_train_step(m_ref)(*params, xb, onehot, jnp.float32(0.1))
    out_pal = make_train_step(m_pal)(*params, xb, onehot, jnp.float32(0.1))
    for a, b in zip(out_ref, out_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_loss_fn_masking():
    """Zero-onehot rows must not contribute to the mean loss."""
    model = REG["femnist_mlp"]
    params = model.init(jax.random.PRNGKey(5))
    xb, onehot = _batch(model, seed=13)
    full = float(loss_fn(model, params, xb, onehot))
    oh_masked = onehot.at[10:].set(0.0)
    masked = float(loss_fn(model, params, xb, oh_masked))
    oh_first = onehot[:10]
    xb_first = xb[:10]
    want = float(loss_fn(model, params, xb_first, oh_first))
    np.testing.assert_allclose(masked, want, rtol=1e-5)
    assert masked != pytest.approx(full)


def test_example_args_match_signature():
    for model in REG.values():
        n = len(model.param_specs)
        args = example_args(model, train=True)
        assert len(args) == n + 3
        assert args[n].shape[0] == model.batch_size
        eargs = example_args(model, train=False)
        assert len(eargs) == n + 2
        assert eargs[n].shape[0] == model.eval_batch


def test_init_deterministic():
    model = REG["femnist_mlp"]
    p1 = model.init(jax.random.PRNGKey(42))
    p2 = model.init(jax.random.PRNGKey(42))
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
