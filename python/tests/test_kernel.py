"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (and block sizes) so padding paths, single-block
paths and multi-block grids are all exercised.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (ACTIVATIONS, fused_linear, matmul,
                             mxu_utilization, pmatmul, sgd_update,
                             sgd_update_flat, softmax_xent, vmem_bytes,
                             xent_loss)
from compile.kernels import ref

SETTINGS = dict(max_examples=10, deadline=None)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ------------------------------ matmul -----------------------------------


@settings(**SETTINGS)
@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70))
def test_matmul_matches_ref(m, k, n):
    x, w = _rand(0, (m, k)), _rand(1, (k, n))
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(m=st.integers(1, 40), k=st.integers(1, 40), n=st.integers(1, 40),
       bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([8, 16, 32]),
       bk=st.sampled_from([8, 16, 32]))
def test_matmul_block_sweep(m, k, n, bm, bn, bk):
    x, w = _rand(2, (m, k)), _rand(3, (k, n))
    got = matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_large_multiblock():
    x, w = _rand(4, (256, 384)), _rand(5, (384, 256))
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-4, atol=1e-4)


def test_matmul_shape_mismatch_raises():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


# --------------------------- fused linear --------------------------------


@pytest.mark.parametrize("activation", ACTIVATIONS)
def test_fused_linear_activations(activation):
    x, w, b = _rand(6, (20, 37)), _rand(7, (37, 62)), _rand(8, (62,))
    got = fused_linear(x, w, b, activation=activation)
    want = ref.fused_linear_ref(x, w, b, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(m=st.integers(1, 50), k=st.integers(1, 50), n=st.integers(1, 50),
       act=st.sampled_from(ACTIVATIONS))
def test_fused_linear_shape_sweep(m, k, n, act):
    x, w, b = _rand(9, (m, k)), _rand(10, (k, n)), _rand(11, (n,))
    got = fused_linear(x, w, b, activation=act)
    want = ref.fused_linear_ref(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_linear_bad_activation():
    with pytest.raises(ValueError):
        fused_linear(jnp.zeros((2, 3)), jnp.zeros((3, 4)), jnp.zeros((4,)),
                     activation="gelu6")


# --------------------------- softmax xent --------------------------------


@settings(**SETTINGS)
@given(b=st.integers(1, 64), c=st.sampled_from([2, 10, 62, 86, 100]))
def test_softmax_xent_matches_ref(b, c):
    logits = _rand(12, (b, c))
    labels = jax.random.randint(jax.random.PRNGKey(13), (b,), 0, c)
    onehot = jax.nn.one_hot(labels, c)
    l1, d1 = softmax_xent(logits, onehot)
    l2, d2 = ref.softmax_xent_ref(logits, onehot)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-5)


def test_softmax_xent_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0], [50.0, 50.0, 50.0]], jnp.float32)
    onehot = jax.nn.one_hot(jnp.array([0, 2]), 3)
    loss, dlog = softmax_xent(logits, onehot)
    assert np.all(np.isfinite(loss)) and np.all(np.isfinite(dlog))
    np.testing.assert_allclose(loss[0], 0.0, atol=1e-5)


def test_xent_loss_grad_matches_ref():
    x, w = _rand(14, (16, 24)), _rand(15, (24, 10))
    onehot = jax.nn.one_hot(
        jax.random.randint(jax.random.PRNGKey(16), (16,), 0, 10), 10)

    def f_kernel(w):
        return xent_loss(pmatmul(x, w), onehot).mean()

    def f_ref(w):
        return ref.softmax_xent_ref(ref.matmul_ref(x, w), onehot)[0].mean()

    np.testing.assert_allclose(jax.grad(f_kernel)(w), jax.grad(f_ref)(w),
                               rtol=1e-4, atol=1e-5)


def test_softmax_xent_zero_rows_masked():
    """All-zero one-hot rows (padding) must yield zero gradient wrt labels."""
    logits = _rand(17, (4, 5))
    onehot = jnp.zeros((4, 5))
    loss, dlog = softmax_xent(logits, onehot)
    # loss = lse - 0: finite; dlogits = softmax (sums to 1 per row)
    np.testing.assert_allclose(np.sum(np.asarray(dlog), axis=-1),
                               np.ones(4), rtol=1e-5)


# ---------------------------- sgd update ---------------------------------


@settings(**SETTINGS)
@given(n=st.integers(1, 10000), lr=st.floats(0.0, 1.0))
def test_sgd_update_flat(n, lr):
    p, g = _rand(18, (n,)), _rand(19, (n,))
    np.testing.assert_allclose(sgd_update_flat(p, g, lr),
                               ref.sgd_update_flat_ref(p, g, lr),
                               rtol=1e-6, atol=1e-6)


def test_sgd_update_tree():
    params = {"w": _rand(20, (8, 4)), "b": _rand(21, (4,))}
    grads = {"w": _rand(22, (8, 4)), "b": _rand(23, (4,))}
    new = sgd_update(params, grads, 0.5)
    np.testing.assert_allclose(new["w"], params["w"] - 0.5 * grads["w"],
                               rtol=1e-6)
    np.testing.assert_allclose(new["b"], params["b"] - 0.5 * grads["b"],
                               rtol=1e-6)


def test_sgd_update_shape_mismatch():
    with pytest.raises(ValueError):
        sgd_update_flat(jnp.zeros((3,)), jnp.zeros((4,)), 0.1)


# --------------------------- perf estimators ------------------------------


def test_vmem_budget_default_tiles():
    # default 128³ f32 tiling must fit well under 16 MiB VMEM
    assert vmem_bytes(128, 128, 128) < 1 << 20


def test_mxu_utilization_bounds():
    assert mxu_utilization(128, 128, 128, 128, 128, 128) == 1.0
    u = mxu_utilization(20, 62, 784, 24, 64, 128)
    assert 0.0 < u <= 1.0
