"""AOT pipeline: HLO text artifacts parse, manifest schema is sound."""

import json
import os
import struct
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from compile.aot import INIT_SEED, lower_model, to_hlo_text
from compile.model import build_registry, make_train_step, example_args


@pytest.fixture(scope="module")
def artifacts():
    reg = build_registry(small=True)
    with tempfile.TemporaryDirectory() as td:
        entry = lower_model(reg["femnist_mlp"], td)
        files = {name: open(os.path.join(td, entry[name])).read()
                 if name.endswith("hlo") else None
                 for name in ("train_hlo", "eval_hlo")}
        with open(os.path.join(td, entry["init_params"]), "rb") as f:
            init_blob = f.read()
        yield reg["femnist_mlp"], entry, files, init_blob


def test_hlo_text_is_parseable_module(artifacts):
    _, entry, files, _ = artifacts
    for key in ("train_hlo", "eval_hlo"):
        text = files[key]
        assert text.startswith("HloModule"), text[:40]
        assert "ENTRY" in text


def test_manifest_entry_schema(artifacts):
    model, entry, _, _ = artifacts
    assert entry["num_params"] == model.num_params
    assert sum(p["size"] for p in entry["params"]) == model.num_params
    assert entry["batch_size"] == model.batch_size
    assert [p["name"] for p in entry["params"]] == \
        [s.name for s in model.param_specs]


def test_init_bin_round_trips(artifacts):
    model, entry, _, blob = artifacts
    assert len(blob) == 4 * model.num_params
    vals = np.frombuffer(blob, dtype="<f4")
    params = model.init(jax.random.PRNGKey(INIT_SEED))
    flat = np.concatenate([np.asarray(p).reshape(-1) for p in params])
    np.testing.assert_allclose(vals, flat, rtol=1e-6)


def _entry_param_count(text: str) -> int:
    """Count parameter() instructions inside the ENTRY computation only."""
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    count = 0
    for line in lines[start + 1:]:
        if line.startswith("}"):
            break
        if "= " in line and "parameter(" in line:
            count += 1
    return count


def test_hlo_entry_signature_counts(artifacts):
    model, entry, files, _ = artifacts
    # train: nparams + 3 inputs (xb, onehot, lr); eval: nparams + 2
    assert _entry_param_count(files["train_hlo"]) == len(model.param_specs) + 3
    assert _entry_param_count(files["eval_hlo"]) == len(model.param_specs) + 2


def test_to_hlo_text_deterministic():
    reg = build_registry(small=True)
    model = reg["shakespeare_gru"]
    lowered = jax.jit(make_train_step(model)).lower(
        *example_args(model, train=True))
    assert to_hlo_text(lowered) == to_hlo_text(lowered)
