//! Integration: the full FL protocol over the XLA engine (artifacts →
//! PJRT → FedAvg with OCS/AOCS) — the three-layer stack end to end.

use fedsamp::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use fedsamp::data;
use fedsamp::fl::{train, TrainOptions};
use fedsamp::runtime::engine::XlaEngine;

const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn have_artifacts() -> bool {
    std::path::Path::new(ART).join("manifest.json").exists()
}

fn tiny_cfg(strategy: Strategy) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("it_{}", strategy.name()),
        seed: 5,
        rounds: 6,
        cohort: 8,
        budget: 2,
        strategy,
        algorithm: Algorithm::FedAvg { local_epochs: 1, eta_g: 1.0, eta_l: 0.125 },
        data: DataSpec::FemnistLike { pool: 12, variant: 1 },
        model: "femnist_mlp".into(),
        batch_size: 20,
        eval_every: 2,
        eval_examples: 124,
        workers: 1,
        secure_updates: true,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

fn build_engine(cfg: &ExperimentConfig, workers: usize) -> XlaEngine {
    let fd = data::build(&cfg.data, cfg.eval_examples, cfg.seed);
    XlaEngine::new(ART, &cfg.model, fd, cfg.algorithm.clone(), workers, cfg.seed)
        .expect("engine")
}

#[test]
fn fedavg_aocs_end_to_end() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cfg = tiny_cfg(Strategy::Aocs { j_max: 4 });
    let mut engine = build_engine(&cfg, 1);
    let run = train(&cfg, &mut engine, &TrainOptions::default()).unwrap();
    assert_eq!(run.rounds.len(), 6);
    assert!(run.rounds.iter().all(|r| r.train_loss.is_finite()));
    assert!(run.final_accuracy().is_finite());
    assert!(run.total_uplink_bits() > 0);
    // budget respected
    for r in &run.rounds {
        assert!(r.expected_budget <= 2.0 + 1e-6);
        assert!(r.transmitted <= 8);
    }
    // training signal: loss at end below loss at start
    assert!(
        run.final_train_loss() < run.rounds[0].train_loss,
        "{} -> {}",
        run.rounds[0].train_loss,
        run.final_train_loss()
    );
}

#[test]
fn worker_pool_reproduces_single_thread_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // per-(round, client) RNG forking makes results independent of the
    // thread schedule: 3 workers must equal 1 worker bit-for-bit on the
    // recorded metrics
    let cfg = tiny_cfg(Strategy::Ocs);
    let mut e1 = build_engine(&cfg, 1);
    let r1 = train(&cfg, &mut e1, &TrainOptions::default()).unwrap();
    let mut e3 = build_engine(&cfg, 3);
    let r3 = train(&cfg, &mut e3, &TrainOptions::default()).unwrap();
    for (a, b) in r1.rounds.iter().zip(&r3.rounds) {
        assert_eq!(a.train_loss, b.train_loss, "round {}", a.round);
        assert_eq!(a.transmitted, b.transmitted);
        assert_eq!(a.uplink_bits, b.uplink_bits);
    }
}

#[test]
fn ocs_uses_fewer_bits_than_full_for_same_rounds() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cfg_f = tiny_cfg(Strategy::Full);
    let mut ef = build_engine(&cfg_f, 1);
    let full = train(&cfg_f, &mut ef, &TrainOptions::default()).unwrap();
    let cfg_o = tiny_cfg(Strategy::Aocs { j_max: 4 });
    let mut eo = build_engine(&cfg_o, 1);
    let ocs = train(&cfg_o, &mut eo, &TrainOptions::default()).unwrap();
    // m=2 of n=8 → ~4× fewer update uploads (negotiation floats are noise)
    assert!(
        ocs.total_uplink_bits() < full.total_uplink_bits() / 2,
        "{} vs {}",
        ocs.total_uplink_bits(),
        full.total_uplink_bits()
    );
}

#[test]
fn gru_model_trains_through_fl() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut cfg = tiny_cfg(Strategy::Aocs { j_max: 4 });
    cfg.model = "shakespeare_gru".into();
    cfg.data = DataSpec::ShakespeareLike { pool: 10 };
    cfg.batch_size = 8;
    cfg.algorithm =
        Algorithm::FedAvg { local_epochs: 1, eta_g: 1.0, eta_l: 0.25 };
    cfg.rounds = 4;
    let mut engine = build_engine(&cfg, 1);
    let run = train(&cfg, &mut engine, &TrainOptions::default()).unwrap();
    assert_eq!(run.rounds.len(), 4);
    assert!(run.rounds.iter().all(|r| r.train_loss.is_finite()));
}

#[test]
fn seed_changes_trajectory() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut cfg = tiny_cfg(Strategy::Uniform);
    let mut e1 = build_engine(&cfg, 1);
    let r1 = train(&cfg, &mut e1, &TrainOptions::default()).unwrap();
    cfg.seed = 6;
    let mut e2 = build_engine(&cfg, 1);
    let r2 = train(&cfg, &mut e2, &TrainOptions::default()).unwrap();
    assert_ne!(r1.rounds[1].train_loss, r2.rounds[1].train_loss);
}
