//! Cross-module property tests: invariants that span sampling, secure
//! aggregation and the FL round protocol.

use fedsamp::sampling::aocs::aocs_probabilities;
use fedsamp::sampling::ocs::ocs_probabilities;
use fedsamp::sampling::probability::draw_independent;
use fedsamp::sampling::variance::{
    improvement_factor, sampling_variance, uniform_variance,
};
use fedsamp::secure_agg::SecureAggregator;
use fedsamp::tensor;
use fedsamp::util::prop::{check, norm_profile, Config};
use fedsamp::util::rng::Rng;

#[test]
fn estimator_unbiased_through_full_pipeline() {
    // Monte-Carlo over random vector updates: E[Σ_{i∈S} (w_i/p_i)U_i]
    // must equal Σ w_i U_i for OCS probabilities + independent draws +
    // secure aggregation.
    check("pipeline-unbiased", Config { cases: 12, seed: 42 }, |rng, case| {
        let n = rng.range(3, 10);
        let d = rng.range(2, 12);
        let m = rng.range(1, n + 1);
        let updates: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect())
            .collect();
        let weights: Vec<f64> = vec![1.0 / n as f64; n];
        let norms: Vec<f64> = updates
            .iter()
            .zip(&weights)
            .map(|(u, &w)| w * tensor::norm(u))
            .collect();
        let probs = ocs_probabilities(&norms, m).probs;

        let mut target = vec![0.0f64; d];
        for (u, &w) in updates.iter().zip(&weights) {
            for (t, &v) in target.iter_mut().zip(u) {
                *t += w * v as f64;
            }
        }

        let trials = 30_000;
        let mut mean = vec![0.0f64; d];
        let mut draw_rng = Rng::new(case as u64 ^ 0xDEAD);
        for t in 0..trials {
            let sel = draw_independent(&probs, &mut draw_rng);
            // secure-aggregate the selected scaled updates
            let scaled: Vec<(u64, Vec<f32>)> = (0..n)
                .filter(|&i| sel[i] && probs[i] > 0.0)
                .map(|i| {
                    let f = (weights[i] / probs[i]) as f32;
                    let mut v = updates[i].clone();
                    tensor::scale(&mut v, f);
                    (i as u64, v)
                })
                .collect();
            if scaled.is_empty() {
                continue;
            }
            let agg = SecureAggregator::new(t as u64);
            let roster: Vec<u64> = scaled.iter().map(|(i, _)| *i).collect();
            let masked: Vec<Vec<u64>> = scaled
                .iter()
                .map(|(i, v)| agg.mask(*i, &roster, v))
                .collect();
            let sum =
                SecureAggregator::decode_sum(&SecureAggregator::sum(&masked));
            for (mm, v) in mean.iter_mut().zip(sum) {
                *mm += v as f64;
            }
        }
        for (mm, t) in mean.iter().zip(&target) {
            let avg = mm / trials as f64;
            // Monte-Carlo tolerance: generous but catches systematic bias
            if (avg - t).abs() > 0.08 * (1.0 + t.abs()) {
                return Err(format!("bias: {avg} vs {t} (n={n} m={m})"));
            }
        }
        Ok(())
    });
}

#[test]
fn lemma1_variance_equality_for_independent_sampling() {
    // Empirical second moment matches Eq. (6) exactly (Lemma 1 equality)
    check("lemma1-equality", Config { cases: 10, seed: 7 }, |rng, case| {
        let n = rng.range(3, 12);
        let m = rng.range(1, n + 1);
        let norms: Vec<f64> =
            (0..n).map(|_| rng.exponential(0.5) + 0.05).collect();
        let probs = ocs_probabilities(&norms, m).probs;
        let target: f64 = norms.iter().sum();
        let predicted = sampling_variance(&norms, &probs);
        let trials = 120_000;
        let mut second = 0.0f64;
        let mut draw_rng = Rng::new(case as u64 ^ 0xBEEF);
        for _ in 0..trials {
            let sel = draw_independent(&probs, &mut draw_rng);
            let est: f64 = (0..n)
                .filter(|&i| sel[i] && probs[i] > 0.0)
                .map(|i| norms[i] / probs[i])
                .sum();
            let dd = est - target;
            second += dd * dd;
        }
        second /= trials as f64;
        if predicted == 0.0 {
            if second < 1e-9 {
                return Ok(());
            }
            return Err(format!("expected zero variance, got {second}"));
        }
        let rel = (second - predicted).abs() / predicted;
        if rel < 0.08 {
            Ok(())
        } else {
            Err(format!(
                "variance mismatch: measured {second} vs Eq.6 {predicted}"
            ))
        }
    });
}

#[test]
fn aocs_never_worse_than_uniform_variance() {
    check("aocs-vs-uniform", Config { cases: 300, seed: 3 }, |rng, _| {
        let n = rng.range(2, 64);
        let m = rng.range(1, n);
        let norms = norm_profile(rng, n);
        if norms.iter().sum::<f64>() <= 0.0 {
            return Ok(());
        }
        let probs = aocs_probabilities(&norms, m, 4).probs;
        let v = sampling_variance(&norms, &probs);
        let vu = uniform_variance(&norms, m);
        if v <= vu * (1.0 + 1e-9) + 1e-12 {
            Ok(())
        } else {
            Err(format!("aocs variance {v} > uniform {vu} (n={n} m={m})"))
        }
    });
}

#[test]
fn improvement_factor_extremes() {
    // sparse profiles → α → 0; constant profiles → α = 1
    check("alpha-extremes", Config { cases: 100, seed: 9 }, |rng, _| {
        let n = rng.range(3, 50);
        let m = rng.range(1, n);
        // sparse: ≤ m nonzero
        let mut sparse = vec![0.0f64; n];
        for i in 0..m {
            sparse[i] = rng.exponential(1.0) + 0.1;
        }
        if improvement_factor(&sparse, m) != 0.0 {
            return Err("sparse α != 0".into());
        }
        let constant = vec![1.0 + rng.f64(); n];
        let a = improvement_factor(&constant, m);
        if (a - 1.0).abs() > 1e-9 {
            return Err(format!("constant α = {a} != 1"));
        }
        Ok(())
    });
}

#[test]
fn secure_agg_dropout_recovery_is_exact() {
    check("dropout-recovery", Config { cases: 60, seed: 17 }, |rng, case| {
        let n = rng.range(2, 10);
        let d = rng.range(1, 16);
        let agg = SecureAggregator::new(case as u64);
        let roster: Vec<u64> = (0..n as u64).collect();
        let data: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 5.0)).collect())
            .collect();
        let masked: Vec<Vec<u64>> = roster
            .iter()
            .zip(&data)
            .map(|(&id, v)| agg.mask(id, &roster, v))
            .collect();
        // drop a random nonempty strict subset
        let k = rng.range(0, n - 1);
        let dropped: Vec<u64> = (0..k as u64).collect();
        let survivors: Vec<u64> = (k as u64..n as u64).collect();
        let mut sum = SecureAggregator::sum(
            &masked[k..].iter().cloned().collect::<Vec<_>>(),
        );
        agg.recover(&mut sum, &survivors, &dropped);
        let got = SecureAggregator::decode_sum(&sum);
        for lane in 0..d {
            let want: f32 = data[k..].iter().map(|v| v[lane]).sum();
            if (got[lane] - want).abs() > 1e-3 {
                return Err(format!("lane {lane}: {} vs {want}", got[lane]));
            }
        }
        Ok(())
    });
}
