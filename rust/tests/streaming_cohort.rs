//! Integration: streaming cohort selection at million-client pool scale.
//!
//! The acceptance gates of the scenario engine:
//!
//! * a round cohort is drawn from a pool of 1,000,000 clients with peak
//!   heap allocation proportional to the *cohort* (a counting global
//!   allocator measures it — the dense draw's O(pool) index vector
//!   alone would be ~8 MiB);
//! * the streaming draw is bitwise identical to the retained dense
//!   reference, so every pre-existing seed trajectory is unchanged.
//!
//! This file holds only the allocator-measured tests so no concurrent
//! test thread can pollute the peak counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use fedsamp::coordinator::Registry;
use fedsamp::fl::availability::{
    reference, sample_round_cohort, Availability, Churn, Diurnal, Outage,
    Trace,
};
use fedsamp::util::rng::Rng;

/// System allocator wrapped with live/peak byte counters.
struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live =
                LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
        System.dealloc(ptr, layout);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Serializes the tests of this file: the peak counter is global, so
/// measured regions must never overlap across harness threads.
static MEASURE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f`, returning its result and the peak heap growth (bytes above
/// the live watermark at entry) it caused. Hold [`MEASURE_LOCK`] while
/// calling.
fn measure_peak<T>(f: impl FnOnce() -> T) -> (T, usize) {
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    let out = f();
    let peak = PEAK.load(Ordering::Relaxed);
    (out, peak.saturating_sub(base))
}

/// Take the file-wide measurement lock (poison-tolerant: a failed test
/// must not cascade).
fn serialized() -> std::sync::MutexGuard<'static, ()> {
    MEASURE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const POOL: usize = 1_000_000;
const COHORT: usize = 512;

/// Generous O(cohort) budget: the sparse Fisher–Yates map, the pick
/// buffers and the cohort itself — and 30× below the ~8 MiB the dense
/// draw's O(pool) identity vector would cost on its own.
const COHORT_BUDGET: usize = 256 * 1024;

fn assert_valid_cohort(cohort: &[usize], n: usize) {
    assert!(cohort.len() <= n);
    let mut sorted = cohort.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), cohort.len(), "duplicate cohort member");
    assert!(cohort.iter().all(|&c| c < POOL));
}

#[test]
fn million_client_always_on_cohort_is_cohort_memory() {
    let _guard = serialized();
    let registry = Registry::new(POOL, 64);
    let avail = Availability::AlwaysOn;
    let mut rng = Rng::new(7).fork(0xF1).fork(0);
    let (draw, peak) = measure_peak(|| {
        sample_round_cohort(&avail, &registry, 0, COHORT, &mut rng)
    });
    assert_eq!(draw.cohort.len(), COHORT);
    assert_valid_cohort(&draw.cohort, COHORT);
    assert!(
        peak < COHORT_BUDGET,
        "always-on draw peaked at {peak} bytes (budget {COHORT_BUDGET})"
    );
    // and it is the exact draw the dense reference produces
    let mut dense_rng = Rng::new(7).fork(0xF1).fork(0);
    let dense = reference::sample_cohort_dense(
        &avail, &registry, 0, COHORT, &mut dense_rng,
    );
    assert_eq!(draw.cohort, dense);
}

#[test]
fn million_client_trace_cohort_is_cohort_memory() {
    let _guard = serialized();
    let registry = Registry::new(POOL, 64);
    let avail = Availability::Trace(Trace {
        seed: 41,
        base_q: 0.6,
        diurnal: Some(Diurnal { amplitude: 0.5, period: 24, zones: 4 }),
        churn: Some(Churn { session_len: 8, drop_prob: 0.2 }),
        outage: Some(Outage { prob: 0.05 }),
    });
    let mut rng = Rng::new(11).fork(0xF1).fork(3);
    let (draw, peak) = measure_peak(|| {
        sample_round_cohort(&avail, &registry, 3, COHORT, &mut rng)
    });
    assert_eq!(draw.cohort.len(), COHORT, "0.6-available 1M pool ≫ cohort");
    assert_valid_cohort(&draw.cohort, COHORT);
    assert!(
        peak < COHORT_BUDGET,
        "trace draw peaked at {peak} bytes (budget {COHORT_BUDGET})"
    );
}

#[test]
fn million_client_bernoulli_cohort_is_cohort_memory_and_bitwise_exact() {
    let _guard = serialized();
    let registry = Registry::new(POOL, 16);
    let avail = Availability::Bernoulli { q: 0.4 };
    let mut rng = Rng::new(3).fork(0xF1).fork(5);
    let (draw, peak) = measure_peak(|| {
        sample_round_cohort(&avail, &registry, 5, COHORT, &mut rng)
    });
    assert_eq!(draw.cohort.len(), COHORT);
    assert_valid_cohort(&draw.cohort, COHORT);
    assert!(
        peak < COHORT_BUDGET,
        "bernoulli draw peaked at {peak} bytes (budget {COHORT_BUDGET})"
    );
    // dense reference agreement at full pool scale (the reference is
    // allowed its O(pool) materialization here — that is the point)
    let mut dense_rng = Rng::new(3).fork(0xF1).fork(5);
    let dense = reference::sample_cohort_dense(
        &avail, &registry, 5, COHORT, &mut dense_rng,
    );
    assert_eq!(draw.cohort, dense);
    assert_eq!(rng.next_u64(), dense_rng.next_u64(), "rng states diverged");
}

#[test]
fn scarce_availability_returns_everyone_reachable() {
    let _guard = serialized();
    // when fewer clients are reachable than the cohort asks for, the
    // draw returns them all — still in O(reachable) memory
    let registry = Registry::new(POOL, 8);
    let avail = Availability::Trace(Trace::bernoulli(13, 0.0001));
    let mut rng = Rng::new(17).fork(0xF1).fork(1);
    let (draw, peak) =
        measure_peak(|| sample_round_cohort(&avail, &registry, 1, 512, &mut rng));
    // ~100 of 1M expected; all of them join the cohort
    assert!(!draw.cohort.is_empty() && draw.cohort.len() < 512);
    assert!(
        peak < COHORT_BUDGET,
        "scarce draw peaked at {peak} bytes (budget {COHORT_BUDGET})"
    );
    let mut dense_rng = Rng::new(17).fork(0xF1).fork(1);
    let dense = reference::sample_cohort_dense(
        &avail, &registry, 1, 512, &mut dense_rng,
    );
    assert_eq!(draw.cohort, dense);
}
