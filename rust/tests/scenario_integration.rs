//! Integration: the scenario engine end to end — availability traces
//! through the coordinator (composing with deadline drops), the
//! q = 1 degradation to the main-paper setting, the sharded AOCS
//! negotiation, and the sweep grid driver's file outputs.

use fedsamp::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use fedsamp::coordinator::{
    Coordinator, CoordinatorOptions, DeadlinePolicy, ParallelRunner,
};
use fedsamp::exp::sweep::{
    parse_availability_arm, run_sweep, SweepSpec, CSV_HEADER,
};
use fedsamp::fl::availability::{Churn, Diurnal, Outage, Trace};
use fedsamp::fl::TrainOptions;
use fedsamp::metrics::RunResult;
use fedsamp::sim::build_native_engine;

fn cfg(strategy: Strategy) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("scenario_{}", strategy.name()),
        seed: 9,
        rounds: 12,
        cohort: 16,
        budget: 4,
        strategy,
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 40, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: 3,
        eval_examples: 128,
        workers: 1,
        secure_updates: true,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

fn run(
    c: &ExperimentConfig,
    opts: CoordinatorOptions,
    workers: usize,
) -> (RunResult, fedsamp::coordinator::CoordStats) {
    let engine = build_native_engine(c);
    let mut runner = ParallelRunner::new(engine, workers);
    let mut coordinator = Coordinator::new(opts);
    let result = coordinator
        .run(c, &mut runner, &TrainOptions::default())
        .unwrap();
    (result, coordinator.stats)
}

fn assert_identical(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{tag}: round count");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{tag}: train_loss round {}",
            ra.round
        );
        assert_eq!(ra.uplink_bits, rb.uplink_bits, "{tag}: bits {}", ra.round);
        assert_eq!(
            ra.transmitted, rb.transmitted,
            "{tag}: transmitted {}",
            ra.round
        );
    }
}

fn hostile_trace() -> Trace {
    Trace {
        seed: 77,
        base_q: 0.7,
        diurnal: Some(Diurnal { amplitude: 0.5, period: 6, zones: 3 }),
        churn: Some(Churn { session_len: 4, drop_prob: 0.25 }),
        outage: Some(Outage { prob: 0.1 }),
    }
}

#[test]
fn trace_runs_are_deterministic_per_seed() {
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    c.availability_trace = Some(hostile_trace());
    c.rounds = 10;
    let opts = || CoordinatorOptions {
        shards: 4,
        ..CoordinatorOptions::default()
    };
    let (a, sa) = run(&c, opts(), 1);
    let (b, sb) = run(&c, opts(), 3);
    // same seed → identical trajectory, for any worker provisioning
    assert_identical(&a, &b, "trace determinism");
    assert_eq!(sa.shards_outaged, sb.shards_outaged);
}

#[test]
fn trace_unavailability_composes_with_deadline_drops() {
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    c.availability_trace = Some(hostile_trace());
    c.rounds = 30;
    let (result, stats) = run(
        &c,
        CoordinatorOptions {
            shards: 4,
            deadline: Some(DeadlinePolicy { miss_prob: 0.2 }),
            ..CoordinatorOptions::default()
        },
        2,
    );
    assert_eq!(result.rounds.len(), c.rounds);
    assert!(stats.shards_outaged > 0, "outage model never fired");
    assert!(stats.shards_dropped > 0, "deadline model never fired");
    // hostile availability + stragglers, and training still progresses
    let first = result
        .rounds
        .iter()
        .find(|r| !r.train_loss.is_nan())
        .expect("every round lost its cohort")
        .train_loss;
    let last = result
        .rounds
        .iter()
        .rev()
        .find(|r| !r.train_loss.is_nan())
        .unwrap()
        .train_loss;
    assert!(last < first, "no progress under the trace: {first} -> {last}");
    // cohorts shrink under unavailability but stay within the ask
    assert!(result.rounds.iter().all(|r| r.transmitted <= c.cohort));
}

#[test]
fn q1_trace_is_bitwise_the_main_paper_setting() {
    // a trace with base_q = 1 and no modulation must reproduce the
    // availability-1.0 trajectory bit for bit (the AlwaysOn degradation)
    let always = cfg(Strategy::Aocs { j_max: 4 });
    let mut traced = always.clone();
    traced.availability_trace = Some(Trace::bernoulli(123, 1.0));
    let (a, _) = run(&always, CoordinatorOptions::default(), 1);
    let (b, _) = run(&traced, CoordinatorOptions::default(), 1);
    assert_identical(&a, &b, "q=1 trace vs always-on");
}

#[test]
fn sharded_negotiation_tracks_the_central_fixed_point() {
    let c = cfg(Strategy::Aocs { j_max: 4 });
    let central = run(
        &c,
        CoordinatorOptions { shards: 4, ..CoordinatorOptions::default() },
        2,
    )
    .0;
    let sharded = run(
        &c,
        CoordinatorOptions {
            shards: 4,
            sharded_negotiation: true,
            ..CoordinatorOptions::default()
        },
        2,
    )
    .0;
    assert_eq!(central.rounds.len(), sharded.rounds.len());
    for (rc, rs) in central.rounds.iter().zip(&sharded.rounds) {
        // same fixed point up to the f32 partial-sum transport: the
        // expected budget (Σp) must agree closely and respect m
        assert!(
            (rc.expected_budget - rs.expected_budget).abs() < 1e-3,
            "round {}: Σp {} vs {}",
            rc.round,
            rc.expected_budget,
            rs.expected_budget
        );
        assert!(rs.expected_budget <= c.budget as f64 + 1e-3);
    }
    // and the run still trains
    assert!(
        sharded.final_train_loss() < sharded.rounds[0].train_loss,
        "sharded negotiation broke training"
    );
}

#[test]
fn sharded_negotiation_is_deterministic_across_workers() {
    let c = cfg(Strategy::Aocs { j_max: 4 });
    let opts = || CoordinatorOptions {
        shards: 4,
        sharded_negotiation: true,
        ..CoordinatorOptions::default()
    };
    let (a, _) = run(&c, opts(), 1);
    let (b, _) = run(&c, opts(), 3);
    assert_identical(&a, &b, "sharded negotiation workers 1 vs 3");
}

#[test]
fn sweep_quick_grid_writes_csv_and_json() {
    let dir = std::env::temp_dir().join(format!(
        "fedsamp_sweep_test_{}",
        std::process::id()
    ));
    let dir = dir.to_str().unwrap().to_string();
    let spec = SweepSpec::quick();
    let report = run_sweep(&spec, false).unwrap();
    assert_eq!(report.arms.len(), 6);
    // acceptance arms: {full, uniform, aocs} × {alwayson, bernoulli trace}
    for strategy in ["full", "uniform", "aocs"] {
        for avail in ["alwayson", "bern0.7"] {
            assert!(
                report.arms.iter().any(|a| a.strategy == strategy
                    && a.availability == avail),
                "missing arm {strategy}×{avail}"
            );
        }
    }
    let (json_path, csv_path) = report.save(&dir).unwrap();
    let json_text = std::fs::read_to_string(&json_path).unwrap();
    let doc = fedsamp::util::json::Json::parse(&json_text).unwrap();
    assert_eq!(doc.get("bench").as_str(), Some("sweep"));
    assert_eq!(doc.get("arms").as_arr().unwrap().len(), 6);
    let csv_text = std::fs::read_to_string(&csv_path).unwrap();
    assert!(csv_text.starts_with(CSV_HEADER));
    assert_eq!(csv_text.lines().count(), 7, "header + 6 arms");
    // unavailability must show up in the data: the bern0.7 arms
    // transmit no more than their always-on counterparts ask for
    for arm in &report.arms {
        assert!(arm.mean_transmitted <= spec.cohort as f64 + 1e-9);
        assert!(arm.final_train_loss.is_finite());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn availability_arms_parse_to_validating_configs() {
    for spec in ["alwayson", "bern0.5", "diurnal0.8", "churn0.9", "outage0.2"]
    {
        let arm = parse_availability_arm(spec).unwrap();
        let mut c = cfg(Strategy::Uniform);
        c.availability_trace = arm.trace;
        c.validate().unwrap_or_else(|e| panic!("{spec}: {e}"));
    }
}
