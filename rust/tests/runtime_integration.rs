//! Integration: AOT artifacts load through PJRT and execute correctly.
//!
//! Requires `make artifacts` to have run (the Makefile `test` target
//! guarantees it); tests skip gracefully when artifacts are absent so
//! bare `cargo test` still works in a fresh checkout. The whole file is
//! additionally gated on the `xla` feature: it drives the PJRT runtime
//! directly, which the default std-only build stubs out.
#![cfg(feature = "xla")]

use fedsamp::config::Algorithm;
use fedsamp::data::{synth_image, synth_text};
use fedsamp::runtime::engine::{evaluate, local_train};
use fedsamp::runtime::manifest::load_manifests;
use fedsamp::runtime::Runtime;
use fedsamp::tensor;
use fedsamp::util::rng::Rng;

const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn have_artifacts() -> bool {
    std::path::Path::new(ART).join("manifest.json").exists()
}

fn random_batch(rt: &Runtime, rng: &mut Rng) -> (xla::Literal, xla::Literal) {
    let b = rt.manifest.batch_size;
    let per = rt.manifest.input_elems();
    let labels: Vec<u32> = (0..b)
        .map(|_| rng.below(rt.manifest.num_classes as u64) as u32)
        .collect();
    let xb = if rt.manifest.input_dtype == "f32" {
        let xs: Vec<f32> = (0..b * per).map(|_| rng.f32()).collect();
        rt.input_literal(Some(&xs), None, b).unwrap()
    } else {
        let toks: Vec<i32> = (0..b * per)
            .map(|_| rng.below(rt.manifest.num_classes as u64) as i32)
            .collect();
        rt.input_literal(None, Some(&toks), b).unwrap()
    };
    let oh = rt.onehot_literal(&labels, b).unwrap();
    (xb, oh)
}

#[test]
fn mlp_train_step_executes_and_learns() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::load(ART, "femnist_mlp").unwrap();
    let flat = rt.init_params().unwrap();
    let mut params = rt.params_to_literals(&flat).unwrap();
    let mut rng = Rng::new(1);
    let (xb, oh) = random_batch(&rt, &mut rng);
    // repeated steps on one batch must drive the loss down hard
    let first = rt.train_step(&mut params, &xb, &oh, 0.2).unwrap();
    let mut last = first;
    for _ in 0..150 {
        last = rt.train_step(&mut params, &xb, &oh, 0.2).unwrap();
    }
    assert!(first.is_finite() && last.is_finite());
    assert!(last < first * 0.5, "no learning: {first} -> {last}");
    // parameters actually changed and round-trip flat<->literal
    let y = rt.literals_to_params(&params).unwrap();
    assert_eq!(y.len(), flat.len());
    assert!(tensor::dist_sq(&flat, &y) > 0.0);
}

#[test]
fn train_step_is_deterministic() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::load(ART, "femnist_mlp").unwrap();
    let flat = rt.init_params().unwrap();
    let mut rng = Rng::new(2);
    let (xb, oh) = random_batch(&rt, &mut rng);
    let run = |rt: &Runtime| -> (f64, Vec<f32>) {
        let mut p = rt.params_to_literals(&flat).unwrap();
        let loss = rt.train_step(&mut p, &xb, &oh, 0.25).unwrap();
        (loss, rt.literals_to_params(&p).unwrap())
    };
    let (l1, p1) = run(&rt);
    let (l2, p2) = run(&rt);
    assert_eq!(l1, l2);
    assert_eq!(p1, p2);
}

#[test]
fn gru_token_model_executes() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::load(ART, "shakespeare_gru").unwrap();
    assert_eq!(rt.manifest.input_dtype, "i32");
    let flat = rt.init_params().unwrap();
    let mut params = rt.params_to_literals(&flat).unwrap();
    let mut rng = Rng::new(3);
    let (xb, oh) = random_batch(&rt, &mut rng);
    let mut losses = Vec::new();
    for _ in 0..20 {
        losses.push(rt.train_step(&mut params, &xb, &oh, 0.5).unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(losses.last().unwrap() < &(losses[0] * 0.9), "{losses:?}");
}

#[test]
fn pallas_and_xla_variants_agree_numerically() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // the L1 acceptance test at the artifact level: identical inputs
    // through the pallas-kernel HLO and the plain-jnp HLO must match
    let rt_ref = Runtime::load(ART, "femnist_mlp").unwrap();
    let rt_pal = Runtime::load(ART, "femnist_mlp_pallas").unwrap();
    let flat = rt_ref.init_params().unwrap();
    let mut rng = Rng::new(4);
    let (xb, oh) = random_batch(&rt_ref, &mut rng);
    let mut p_ref = rt_ref.params_to_literals(&flat).unwrap();
    let mut p_pal = rt_pal.params_to_literals(&flat).unwrap();
    let l_ref = rt_ref.train_step(&mut p_ref, &xb, &oh, 0.125).unwrap();
    let l_pal = rt_pal.train_step(&mut p_pal, &xb, &oh, 0.125).unwrap();
    assert!(
        (l_ref - l_pal).abs() < 1e-4 * (1.0 + l_ref.abs()),
        "loss mismatch: {l_ref} vs {l_pal}"
    );
    let f_ref = rt_ref.literals_to_params(&p_ref).unwrap();
    let f_pal = rt_pal.literals_to_params(&p_pal).unwrap();
    let dist = tensor::dist_sq(&f_ref, &f_pal).sqrt();
    let norm = tensor::norm(&f_ref);
    assert!(dist / norm < 1e-4, "param drift {dist} (norm {norm})");
}

#[test]
fn evaluation_counts_are_sane() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::load(ART, "femnist_mlp").unwrap();
    let fd = synth_image::femnist_like(4, 0, 200, 9);
    let flat = rt.init_params().unwrap();
    let ev = evaluate(&rt, &fd.validation, &flat).unwrap();
    assert!(ev.loss.is_finite() && ev.loss > 0.0);
    assert!((0.0..=1.0).contains(&ev.accuracy));
}

#[test]
fn local_train_fedavg_produces_delta() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::load(ART, "femnist_mlp").unwrap();
    let fd = synth_image::femnist_like(3, 0, 32, 10);
    let flat = rt.init_params().unwrap();
    let alg = Algorithm::FedAvg { local_epochs: 1, eta_g: 1.0, eta_l: 0.125 };
    let out = local_train(&rt, &fd.clients[0], 0, 0, &flat, &alg, 7).unwrap();
    assert_eq!(out.delta.len(), flat.len());
    assert_eq!(out.examples, fd.clients[0].len());
    assert!(out.train_loss.is_finite());
    assert!(tensor::norm(&out.delta) > 0.0, "delta is zero");
    // determinism across identical calls
    let out2 = local_train(&rt, &fd.clients[0], 0, 0, &flat, &alg, 7).unwrap();
    assert_eq!(out.delta, out2.delta);
}

#[test]
fn local_train_dsgd_is_gradient() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::load(ART, "femnist_mlp").unwrap();
    let fd = synth_image::femnist_like(3, 0, 32, 11);
    let flat = rt.init_params().unwrap();
    let alg = Algorithm::Dsgd { eta: 0.1 };
    let out = local_train(&rt, &fd.clients[0], 0, 0, &flat, &alg, 7).unwrap();
    // DSGD path runs a single step with lr = 1 ⇒ delta = minibatch grad
    assert!(tensor::norm(&out.delta) > 0.0);
    assert!(out.train_loss > 0.0);
}

#[test]
fn token_dataset_evaluation() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::load(ART, "shakespeare_gru").unwrap();
    let fd = synth_text::shakespeare_like(4, 150, 12);
    let flat = rt.init_params().unwrap();
    let ev = evaluate(&rt, &fd.validation, &flat).unwrap();
    assert!(ev.loss.is_finite());
    assert!((0.0..=1.0).contains(&ev.accuracy));
}

#[test]
fn all_manifest_models_compile() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for m in load_manifests(ART).unwrap() {
        let rt = Runtime::load(ART, &m.name)
            .unwrap_or_else(|e| panic!("{}: {e}", m.name));
        assert_eq!(rt.manifest.num_params, m.num_params);
    }
}
