//! Integration: the telemetry subsystem end to end — exports are
//! machine-readable (JSONL parses line by line, the Chrome trace loads
//! as one JSON document with balanced B/E spans), summaries are
//! internally consistent, and switching telemetry on moves no bit of
//! the training trajectory.

use std::collections::BTreeMap;
use std::path::PathBuf;

use fedsamp::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use fedsamp::coordinator::{Coordinator, CoordinatorOptions, ParallelRunner};
use fedsamp::fl::TrainOptions;
use fedsamp::metrics::RunResult;
use fedsamp::sim::build_native_engine;
use fedsamp::telemetry::{TelemetryConfig, NUM_ROUND_PHASES, PHASE_NAMES};
use fedsamp::util::json::Json;

fn cfg() -> ExperimentConfig {
    ExperimentConfig {
        name: "telemetry_it".into(),
        seed: 11,
        rounds: 4,
        cohort: 12,
        budget: 4,
        strategy: Strategy::Aocs { j_max: 4 },
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 40, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: 2,
        eval_examples: 128,
        workers: 2,
        secure_updates: true,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

fn run_with(telemetry: TelemetryConfig, shards: usize, workers: usize) -> RunResult {
    let c = cfg();
    let engine = build_native_engine(&c);
    let mut runner = ParallelRunner::new(engine, workers);
    let mut coordinator = Coordinator::new(CoordinatorOptions {
        shards,
        ..CoordinatorOptions::default()
    });
    let opts = TrainOptions { telemetry, ..TrainOptions::default() };
    coordinator.run(&c, &mut runner, &opts).unwrap()
}

/// Unique temp path per test so parallel test threads never collide.
fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "fedsamp_telemetry_{}_{tag}",
        std::process::id()
    ))
}

#[test]
fn jsonl_export_parses_with_balanced_spans_and_counters() {
    let jsonl = temp_path("events.jsonl");
    let telemetry = TelemetryConfig {
        enabled: true,
        jsonl_out: Some(jsonl.to_string_lossy().into_owned()),
        trace_out: None,
        manual_clock: true,
    };
    let run = run_with(telemetry, 2, 2);
    assert!(run.telemetry.is_some());

    let text = std::fs::read_to_string(&jsonl).unwrap();
    let _ = std::fs::remove_file(&jsonl);
    assert!(!text.trim().is_empty(), "empty event log");

    // (phase name, round) -> (begin count, end count)
    let mut spans: BTreeMap<(String, usize), (usize, usize)> = BTreeMap::new();
    let mut jobs = 0usize;
    let mut counters = 0usize;
    let mut run_end_rounds = None;
    for line in text.lines() {
        let j = Json::parse(line)
            .unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e:?}"));
        match j.get("ev").as_str().expect("every event has an ev tag") {
            ev @ ("span_begin" | "span_end") => {
                let key = (
                    j.get("name").as_str().unwrap().to_string(),
                    j.get("round").as_usize().unwrap(),
                );
                let e = spans.entry(key).or_insert((0, 0));
                if ev == "span_begin" {
                    e.0 += 1;
                } else {
                    e.1 += 1;
                    assert!(j.get("dur_ns").as_f64().is_some());
                }
            }
            "job" => {
                jobs += 1;
                assert!(j.get("exec_ns").as_f64().is_some());
                assert!(j.get("queue_ns").as_f64().is_some());
            }
            "counter" => {
                counters += 1;
                assert!(j.get("value").as_f64().is_some());
            }
            "run_end" => {
                run_end_rounds = j.get("rounds").as_usize();
            }
            other => panic!("unknown event kind {other}"),
        }
    }
    assert_eq!(run_end_rounds, Some(cfg().rounds), "run_end footer");
    assert!(jobs > 0, "no worker job events recorded");
    assert!(counters > 0, "no counter events recorded");
    for ((name, round), (b, e)) in &spans {
        assert_eq!(b, e, "unbalanced span {name} round {round}");
    }
    // always-on availability: every round runs every protocol phase
    // (the trailing "checkpoint" phase only fires when checkpointing is
    // enabled, so it is excluded here)
    for round in 0..cfg().rounds {
        for name in &PHASE_NAMES[..NUM_ROUND_PHASES] {
            assert!(
                spans.contains_key(&(name.to_string(), round)),
                "round {round} missing {name} span"
            );
        }
    }
}

#[test]
fn chrome_trace_loads_and_balances_phase_events() {
    let trace = temp_path("trace.json");
    let telemetry = TelemetryConfig {
        enabled: true,
        jsonl_out: None,
        trace_out: Some(trace.to_string_lossy().into_owned()),
        manual_clock: true,
    };
    run_with(telemetry, 2, 2);

    let text = std::fs::read_to_string(&trace).unwrap();
    let _ = std::fs::remove_file(&trace);
    let doc = Json::parse(&text).expect("trace must be one JSON document");
    let events = doc.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty());

    let mut begins = 0usize;
    let mut ends = 0usize;
    let mut complete = 0usize;
    let mut phase_names_seen = std::collections::BTreeSet::new();
    for e in events {
        assert_eq!(e.get("pid").as_usize(), Some(1));
        assert!(e.get("ts").as_f64().is_some());
        match e.get("ph").as_str().unwrap() {
            "B" => {
                begins += 1;
                phase_names_seen
                    .insert(e.get("name").as_str().unwrap().to_string());
                // master-thread events carry tid 0
                assert_eq!(e.get("tid").as_usize(), Some(0));
            }
            "E" => ends += 1,
            "X" => {
                complete += 1;
                assert!(e.get("dur").as_f64().is_some());
                // pool jobs render on tid = worker + 1
                assert!(e.get("tid").as_usize().unwrap() >= 1);
            }
            other => panic!("unexpected trace phase {other}"),
        }
    }
    assert_eq!(begins, ends, "unbalanced B/E trace events");
    assert_eq!(begins, cfg().rounds * NUM_ROUND_PHASES);
    assert!(complete > 0, "no X (job) events in trace");
    for name in &PHASE_NAMES[..NUM_ROUND_PHASES] {
        assert!(phase_names_seen.contains(name), "trace missing {name}");
    }
}

#[test]
fn telemetry_on_moves_no_bit_of_the_trajectory() {
    let jsonl = temp_path("bitwise.jsonl");
    let trace = temp_path("bitwise_trace.json");
    let off = run_with(TelemetryConfig::off(), 4, 3);
    assert!(off.telemetry.is_none());
    let on = run_with(
        TelemetryConfig {
            enabled: true,
            jsonl_out: Some(jsonl.to_string_lossy().into_owned()),
            trace_out: Some(trace.to_string_lossy().into_owned()),
            manual_clock: false, // the real monotonic clock, full export
        },
        4,
        3,
    );
    let _ = std::fs::remove_file(&jsonl);
    let _ = std::fs::remove_file(&trace);
    assert!(on.telemetry.is_some());
    assert_eq!(off.rounds.len(), on.rounds.len());
    for (a, b) in off.rounds.iter().zip(&on.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.val_accuracy.to_bits(), b.val_accuracy.to_bits());
        assert_eq!(a.uplink_bits, b.uplink_bits);
        assert_eq!(a.transmitted, b.transmitted);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
        assert_eq!(a.expected_budget.to_bits(), b.expected_budget.to_bits());
    }
}

#[test]
fn summary_is_internally_consistent() {
    let run = run_with(
        TelemetryConfig { manual_clock: true, ..TelemetryConfig::summary_only() },
        2,
        2,
    );
    let s = run.telemetry.as_ref().expect("summary-only still summarizes");
    let c = cfg();
    assert_eq!(s.rounds, c.rounds);
    for name in &PHASE_NAMES[..NUM_ROUND_PHASES] {
        let p = s
            .phase(name)
            .unwrap_or_else(|| panic!("no phase summary for {name}"));
        assert_eq!(p.n as usize, c.rounds, "{name}: one span per round");
        assert!(
            p.p50 <= p.p90 && p.p90 <= p.p99 && p.p99 <= p.max as f64,
            "{name}: quantiles out of order"
        );
    }
    let transmitted: usize = run.rounds.iter().map(|r| r.transmitted).sum();
    assert_eq!(s.counter("clients_transmitted"), transmitted as u64);
    assert_eq!(s.payload_bytes.n, transmitted as u64);
    assert!(
        s.counter("clients_announced") >= s.counter("clients_transmitted")
    );
    assert!(s.counter("clients_selected") >= s.counter("clients_transmitted"));
    // secure path over a worker pool: local + mask-fold jobs measured
    let local = s.job_exec("local").unwrap();
    assert!(local.n > 0, "no local jobs timed");
    let folds = s.job_exec("mask_fold").unwrap();
    assert!(folds.n > 0, "no mask-fold jobs timed");
    // the run JSON carries the same rollup
    let j = run.to_json();
    assert_eq!(
        j.get("telemetry").get("rounds").as_usize(),
        Some(c.rounds)
    );
}

#[test]
fn checkpoint_counters_land_in_the_summary() {
    use fedsamp::checkpoint::CheckpointOptions;
    let snap = temp_path("ck_counters.bin");
    let snap_s = snap.to_string_lossy().into_owned();
    let c = cfg();
    let telemetry = TelemetryConfig {
        manual_clock: true,
        ..TelemetryConfig::summary_only()
    };

    let run_once = |resume: Option<String>| {
        let engine = build_native_engine(&c);
        let mut runner = ParallelRunner::new(engine, 2);
        let mut coordinator = Coordinator::new(CoordinatorOptions {
            shards: 2,
            ..CoordinatorOptions::default()
        });
        let opts = TrainOptions {
            telemetry: telemetry.clone(),
            checkpoint: CheckpointOptions {
                every: 2,
                out: Some(snap_s.clone()),
                resume,
            },
            ..TrainOptions::default()
        };
        coordinator.run(&c, &mut runner, &opts).unwrap()
    };

    // rounds=4, every=2 → snapshots after rounds 1 and 3
    let run = run_once(None);
    let s = run.telemetry.as_ref().unwrap();
    assert_eq!(s.counter("checkpoints_written"), 2);
    assert!(s.counter("checkpoint_bytes") > 0, "no snapshot bytes metered");
    assert_eq!(s.counter("resumes"), 0);
    let p = s.phase("checkpoint").expect("checkpoint phase summary");
    assert_eq!(p.n, 2, "one checkpoint span per snapshot");

    // resuming restores the cumulative counters and bumps `resumes`
    let resumed = run_once(Some(snap_s.clone()));
    let _ = std::fs::remove_file(&snap);
    let s = resumed.telemetry.as_ref().unwrap();
    assert_eq!(s.counter("checkpoints_written"), 2);
    assert_eq!(s.counter("resumes"), 1);
}

#[test]
fn cli_train_smoke_emits_parseable_exports() {
    let jsonl = temp_path("cli.jsonl");
    let trace = temp_path("cli_trace.json");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_fedsamp"))
        .args([
            "train",
            "--preset",
            "femnist1",
            "--rounds",
            "2",
            "--sim",
            "true",
            "--telemetry",
            "--telemetry-out",
            &jsonl.to_string_lossy(),
            "--trace-out",
            &trace.to_string_lossy(),
        ])
        .output()
        .expect("spawn fedsamp train");
    assert!(
        out.status.success(),
        "train failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("telemetry:"),
        "missing telemetry summary line:\n{stdout}"
    );

    let events = std::fs::read_to_string(&jsonl).unwrap();
    let _ = std::fs::remove_file(&jsonl);
    assert!(!events.trim().is_empty());
    for line in events.lines() {
        Json::parse(line)
            .unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e:?}"));
    }
    assert!(events.lines().last().unwrap().contains("run_end"));

    let trace_text = std::fs::read_to_string(&trace).unwrap();
    let _ = std::fs::remove_file(&trace);
    let doc = Json::parse(&trace_text).expect("trace JSON");
    assert!(!doc.get("traceEvents").as_arr().unwrap().is_empty());
}
