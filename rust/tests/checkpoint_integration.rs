//! Integration: the checkpoint subsystem end to end — a run killed at
//! any round and resumed from its last snapshot lands on the *bitwise
//! identical* trajectory (metrics, uplink bytes, coordinator stats),
//! checkpointing switched off is bitwise inert, and resuming under the
//! wrong config or model dimension is a typed refusal.

use std::path::PathBuf;

use fedsamp::checkpoint::{CheckpointOptions, Snapshot};
use fedsamp::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use fedsamp::coordinator::{
    CoordStats, Coordinator, CoordinatorOptions, ParallelRunner,
};
use fedsamp::faults::{parse_fault_spec, MASTERKILL_ERR_PREFIX};
use fedsamp::fl::TrainOptions;
use fedsamp::metrics::RunResult;
use fedsamp::sim::build_native_engine;

fn cfg(secure: bool) -> ExperimentConfig {
    ExperimentConfig {
        name: "checkpoint_it".into(),
        seed: 23,
        rounds: 6,
        cohort: 12,
        budget: 4,
        strategy: Strategy::Aocs { j_max: 4 },
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 24, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: 2,
        eval_examples: 128,
        workers: 2,
        secure_updates: secure,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

fn run(
    c: &ExperimentConfig,
    shards: usize,
    workers: usize,
    checkpoint: CheckpointOptions,
) -> Result<(RunResult, CoordStats), String> {
    let engine = build_native_engine(c);
    let mut runner = ParallelRunner::new(engine, workers);
    let mut coordinator = Coordinator::new(CoordinatorOptions {
        shards,
        ..CoordinatorOptions::default()
    });
    let opts = TrainOptions { checkpoint, ..TrainOptions::default() };
    let result = coordinator.run(c, &mut runner, &opts)?;
    Ok((result, coordinator.stats.clone()))
}

/// Unique temp path per test case so parallel test threads never collide.
fn temp_path(tag: &str) -> String {
    PathBuf::from(std::env::temp_dir())
        .join(format!("fedsamp_ckpt_it_{}_{tag}.bin", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Every trajectory-bearing bit must match: float fields compared via
/// `to_bits` (NaN accuracies on non-eval rounds included).
fn assert_bitwise(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.round, y.round, "{what}: round index");
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{what}: train_loss round {}",
            x.round
        );
        assert_eq!(
            x.val_accuracy.to_bits(),
            y.val_accuracy.to_bits(),
            "{what}: val_accuracy round {}",
            x.round
        );
        assert_eq!(x.uplink_bits, y.uplink_bits, "{what}: uplink_bits");
        assert_eq!(x.uplink_bytes, y.uplink_bytes, "{what}: uplink_bytes");
        assert_eq!(x.transmitted, y.transmitted, "{what}: transmitted");
        assert_eq!(
            x.expected_budget.to_bits(),
            y.expected_budget.to_bits(),
            "{what}: expected_budget"
        );
        assert_eq!(x.alpha.to_bits(), y.alpha.to_bits(), "{what}: alpha");
        assert_eq!(x.gamma.to_bits(), y.gamma.to_bits(), "{what}: gamma");
    }
    // the serialized artifact (what `--out` saves) is byte-identical too
    assert_eq!(
        a.to_json().to_pretty(),
        b.to_json().to_pretty(),
        "{what}: run JSON"
    );
}

fn assert_stats_eq(a: &CoordStats, b: &CoordStats, what: &str) {
    assert_eq!(a.shards_dropped, b.shards_dropped, "{what}: shards_dropped");
    assert_eq!(a.shards_outaged, b.shards_outaged, "{what}: shards_outaged");
    assert_eq!(a.noop_rounds, b.noop_rounds, "{what}: noop_rounds");
    assert_eq!(a.rounds_run, b.rounds_run, "{what}: rounds_run");
    assert_eq!(a.faults, b.faults, "{what}: fault counters");
}

/// The tentpole contract: kill at round k (early, mid, last) and resume
/// — the stitched trajectory is bitwise identical to the uninterrupted
/// one, on the secure and plain aggregation paths, single- and
/// multi-shard, serial and pooled workers.
#[test]
fn kill_and_resume_is_bitwise_identical() {
    for secure in [true, false] {
        for (shards, workers) in [(1usize, 1usize), (4, 3)] {
            let c = cfg(secure);
            let (reference, ref_stats) =
                run(&c, shards, workers, CheckpointOptions::default())
                    .unwrap();
            // kill rounds: first possible resume, mid-run, last round
            for k in [1usize, 3, 5] {
                let what = format!("secure={secure} s{shards} w{workers} k{k}");
                let snap = temp_path(&format!(
                    "kill_{secure}_{shards}_{workers}_{k}"
                ));
                let mut killed_cfg = c.clone();
                killed_cfg.fault_plan =
                    Some(parse_fault_spec(&format!("masterkill{k}")).unwrap());
                let err = run(
                    &killed_cfg,
                    shards,
                    workers,
                    CheckpointOptions {
                        every: 1,
                        out: Some(snap.clone()),
                        resume: None,
                    },
                )
                .unwrap_err();
                assert!(
                    err.starts_with(MASTERKILL_ERR_PREFIX),
                    "{what}: expected planned kill, got: {err}"
                );
                // the last snapshot stops exactly where the kill fired
                let on_disk = Snapshot::load(&snap).unwrap();
                assert_eq!(on_disk.next_round, k as u64, "{what}");

                // resume with the *same* config (masterkill disarmed)
                let (resumed, resumed_stats) = run(
                    &killed_cfg,
                    shards,
                    workers,
                    CheckpointOptions {
                        resume: Some(snap.clone()),
                        ..CheckpointOptions::default()
                    },
                )
                .unwrap();
                let _ = std::fs::remove_file(&snap);
                assert_bitwise(&reference, &resumed, &what);
                assert_stats_eq(&ref_stats, &resumed_stats, &what);
            }
        }
    }
}

/// Feature-off contract: a run that checkpoints every other round is
/// bitwise identical to one that never checkpoints.
#[test]
fn checkpointing_is_bitwise_inert() {
    let c = cfg(true);
    let snap = temp_path("inert");
    let (off, off_stats) =
        run(&c, 4, 3, CheckpointOptions::default()).unwrap();
    let (on, on_stats) = run(
        &c,
        4,
        3,
        CheckpointOptions {
            every: 2,
            out: Some(snap.clone()),
            resume: None,
        },
    )
    .unwrap();
    let _ = std::fs::remove_file(&snap);
    assert_bitwise(&off, &on, "checkpoint on vs off");
    assert_stats_eq(&off_stats, &on_stats, "checkpoint on vs off");
}

/// Kill-and-resume across no-op rounds: near-zero availability makes
/// most rounds empty, exercising the no-op snapshot path (`continue`
/// branch) through the same bitwise contract.
#[test]
fn resume_across_noop_rounds_is_bitwise_identical() {
    let mut c = cfg(true);
    c.availability = 0.05; // expected ~1 available client per round
    let (reference, ref_stats) =
        run(&c, 2, 2, CheckpointOptions::default()).unwrap();
    assert!(ref_stats.noop_rounds > 0, "scenario produced no no-op rounds");

    let snap = temp_path("noop");
    let mut killed_cfg = c.clone();
    killed_cfg.fault_plan = Some(parse_fault_spec("masterkill3").unwrap());
    let err = run(
        &killed_cfg,
        2,
        2,
        CheckpointOptions { every: 1, out: Some(snap.clone()), resume: None },
    )
    .unwrap_err();
    assert!(err.starts_with(MASTERKILL_ERR_PREFIX), "got: {err}");
    let (resumed, resumed_stats) = run(
        &killed_cfg,
        2,
        2,
        CheckpointOptions {
            resume: Some(snap.clone()),
            ..CheckpointOptions::default()
        },
    )
    .unwrap();
    let _ = std::fs::remove_file(&snap);
    assert_bitwise(&reference, &resumed, "noop resume");
    assert_stats_eq(&ref_stats, &resumed_stats, "noop resume");
}

/// Resume refusals are typed and early: a snapshot from a different
/// config (fingerprint) or a different model dimension never starts a
/// silently divergent run.
#[test]
fn resume_rejects_config_and_dim_mismatch() {
    let c = cfg(true);
    let snap = temp_path("mismatch");
    run(
        &c,
        1,
        1,
        CheckpointOptions { every: 3, out: Some(snap.clone()), resume: None },
    )
    .unwrap();

    // same snapshot, different config → ConfigMismatch
    let mut other = c.clone();
    other.seed += 1;
    let err = run(
        &other,
        1,
        1,
        CheckpointOptions {
            resume: Some(snap.clone()),
            ..CheckpointOptions::default()
        },
    )
    .unwrap_err();
    assert!(
        err.contains("different experiment config"),
        "expected ConfigMismatch, got: {err}"
    );

    // same config, doctored model dimension → DimMismatch
    let mut doctored = Snapshot::load(&snap).unwrap();
    doctored.x.push(0.0);
    let bad = temp_path("mismatch_dim");
    doctored.write_atomic(&bad).unwrap();
    let err = run(
        &c,
        1,
        1,
        CheckpointOptions {
            resume: Some(bad.clone()),
            ..CheckpointOptions::default()
        },
    )
    .unwrap_err();
    let _ = std::fs::remove_file(&snap);
    let _ = std::fs::remove_file(&bad);
    assert!(
        err.contains("model dimension"),
        "expected DimMismatch, got: {err}"
    );
}
