//! Integration: the SIMD kernel backend reproduces the scalar backend's
//! end-to-end trajectories bit-for-bit — plain and secure, single-shard
//! pooled and inline (DESIGN.md §12).
//!
//! This is the whole point of the AVX2 construction (no FMA, lane-mapped
//! f64 accumulators sharing the scalar fold tree, exact ring ops): the
//! backend switch is a pure speed knob, never a semantics knob, so
//! `--kernel-backend scalar` and `simd` emit identical artifacts.
//!
//! The backend selection is process-global, so the whole comparison runs
//! in ONE test function (integration tests run in their own process;
//! flipping the backend here cannot race the library's unit tests).

use fedsamp::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use fedsamp::coordinator::{Coordinator, CoordinatorOptions, ParallelRunner};
use fedsamp::fl::{train, TrainOptions};
use fedsamp::metrics::RunResult;
use fedsamp::sim::build_native_engine;
use fedsamp::tensor::dispatch::{self, Backend, BackendChoice};

fn cfg(name: &str, secure: bool) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        seed: 9,
        rounds: 6,
        cohort: 16,
        budget: 4,
        strategy: Strategy::Aocs { j_max: 4 },
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 40, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: 3,
        eval_examples: 128,
        workers: 1,
        secure_updates: secure,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

fn train_run(c: &ExperimentConfig) -> RunResult {
    let mut engine = build_native_engine(c);
    train(c, &mut engine, &TrainOptions::default()).unwrap()
}

/// Single fat shard + multi-worker pool: under SIMD this also exercises
/// the sub-chunked MaskFold fan-out on every secure round.
fn coord_run(c: &ExperimentConfig, shards: usize, workers: usize) -> RunResult {
    let engine = build_native_engine(c);
    let mut runner = ParallelRunner::new(engine, workers);
    let mut coordinator = Coordinator::new(CoordinatorOptions {
        shards,
        ..CoordinatorOptions::default()
    });
    coordinator.run(c, &mut runner, &TrainOptions::default()).unwrap()
}

fn assert_bitwise(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{tag}: round count");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{tag}: train_loss round {}",
            ra.round
        );
        assert_eq!(
            ra.uplink_bits, rb.uplink_bits,
            "{tag}: uplink_bits round {}",
            ra.round
        );
        assert_eq!(
            ra.transmitted, rb.transmitted,
            "{tag}: transmitted round {}",
            ra.round
        );
        assert_eq!(
            ra.val_accuracy.to_bits(),
            rb.val_accuracy.to_bits(),
            "{tag}: val_accuracy round {}",
            ra.round
        );
        assert_eq!(
            ra.alpha.to_bits(),
            rb.alpha.to_bits(),
            "{tag}: alpha round {}",
            ra.round
        );
    }
}

#[test]
fn simd_backend_reproduces_scalar_trajectories_bitwise() {
    if !dispatch::simd_available() {
        eprintln!("AVX2 unavailable; backend equivalence not exercised");
        return;
    }
    let plain = cfg("be_plain", false);
    let secure = cfg("be_secure", true);

    assert_eq!(
        dispatch::select(BackendChoice::Scalar).unwrap(),
        Backend::Scalar
    );
    let plain_scalar = train_run(&plain);
    let secure_scalar = train_run(&secure);
    let pooled_scalar = coord_run(&secure, 1, 4);

    assert_eq!(
        dispatch::select(BackendChoice::Simd).unwrap(),
        Backend::Simd
    );
    let plain_simd = train_run(&plain);
    let secure_simd = train_run(&secure);
    let pooled_simd = coord_run(&secure, 1, 4);
    dispatch::select(BackendChoice::Scalar).unwrap();

    assert_bitwise(&plain_scalar, &plain_simd, "plain train");
    assert_bitwise(&secure_scalar, &secure_simd, "secure train");
    assert_bitwise(&pooled_scalar, &pooled_simd, "1-shard pooled secure");
    // and the pooled secure run is itself pinned to the inline one
    assert_bitwise(&secure_scalar, &pooled_scalar, "pooled vs inline");
}
