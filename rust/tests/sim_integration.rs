//! Integration: sim-path end-to-end runs (native logistic engine) and the
//! 3-way strategy comparison of the paper's evaluation.

use fedsamp::config::presets;
use fedsamp::config::{DataSpec, Strategy};
use fedsamp::metrics::average_runs;
use fedsamp::sim::run_sim;

fn quick(strategy: Strategy, seed: u64) -> fedsamp::metrics::RunResult {
    let mut cfg = presets::femnist(1, 3).with_strategy(strategy);
    cfg.seed = seed;
    cfg.rounds = 30;
    cfg.eval_examples = 248;
    cfg.data = DataSpec::FemnistLike { pool: 60, variant: 1 };
    cfg.secure_updates = false;
    run_sim(&cfg).unwrap()
}

#[test]
fn three_way_comparison_matches_paper_shape() {
    // Figures 3–5 qualitative shape on the sim substrate:
    // per-round: full ≤ ocs < uniform loss; per-bit: ocs beats full
    let avg_loss = |s: Strategy| {
        let runs: Vec<_> = (0..3).map(|i| quick(s.clone(), i)).collect();
        average_runs(&runs)
    };
    let full = avg_loss(Strategy::Full);
    let aocs = avg_loss(Strategy::Aocs { j_max: 4 });
    let uniform = avg_loss(Strategy::Uniform);

    let fl = full.final_train_loss();
    let al = aocs.final_train_loss();
    let ul = uniform.final_train_loss();
    assert!(al < ul, "optimal {al} !< uniform {ul}");
    assert!(fl <= al * 1.15, "full {fl} should be ≈ best vs {al}");

    // bits-to-loss: AOCS reaches full's final loss with far fewer bits
    let target = fl * 1.1;
    let bits_full = full
        .rounds
        .iter()
        .find(|r| r.train_loss <= target)
        .map(|r| r.uplink_bits);
    let bits_aocs = aocs
        .rounds
        .iter()
        .find(|r| r.train_loss <= target)
        .map(|r| r.uplink_bits);
    if let (Some(bf), Some(ba)) = (bits_full, bits_aocs) {
        assert!(ba < bf, "aocs bits {ba} !< full bits {bf}");
    }
}

#[test]
fn alpha_below_one_on_unbalanced_data() {
    // the unbalanced FEMNIST variant must produce heterogeneous update
    // norms, i.e. a strict advantage for optimal sampling (α < 1)
    let run = quick(Strategy::Aocs { j_max: 4 }, 0);
    let mean_alpha = run.mean_alpha();
    assert!(
        mean_alpha < 0.95,
        "α ≈ 1 means no norm heterogeneity: {mean_alpha}"
    );
    assert!(mean_alpha > 0.0);
}

#[test]
fn gamma_bounds_hold_every_round() {
    let run = quick(Strategy::Ocs, 1);
    for r in &run.rounds {
        let m = 3.0;
        let n = 32.0;
        assert!(
            r.gamma >= m / n - 1e-9 && r.gamma <= 1.0 + 1e-9,
            "round {}: γ={} outside [m/n, 1]",
            r.round,
            r.gamma
        );
    }
}

#[test]
fn run_result_saves_and_reloads() {
    let run = quick(Strategy::Uniform, 2);
    let dir = std::env::temp_dir().join("fedsamp_test_results");
    let path = run.save(dir.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let v = fedsamp::util::json::Json::parse(&text).unwrap();
    assert_eq!(v.get("strategy").as_str(), Some("uniform"));
    assert_eq!(
        v.get("rounds").as_arr().unwrap().len(),
        run.rounds.len()
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn dsgd_theory_preset_runs() {
    let mut cfg = presets::dsgd_theory(8, 0.05);
    cfg.rounds = 40;
    cfg.data = DataSpec::FemnistLike { pool: 32, variant: 1 };
    cfg.secure_updates = false;
    let run = run_sim(&cfg).unwrap();
    assert_eq!(run.rounds.len(), 40);
    assert!(run.final_train_loss().is_finite());
}

#[test]
fn cifar_balanced_still_benefits() {
    // Appendix G: OCS ≥ uniform even on balanced data (norms still differ)
    let mk = |s: Strategy| {
        let mut cfg = presets::cifar(3).with_strategy(s);
        cfg.rounds = 25;
        cfg.eval_examples = 200;
        cfg.data = DataSpec::CifarLike { pool: 40, per_client: 40 };
        cfg.secure_updates = false;
        let runs: Vec<_> = (0..3)
            .map(|i| {
                let mut c = cfg.clone();
                c.seed = i;
                run_sim(&c).unwrap()
            })
            .collect();
        average_runs(&runs).final_train_loss()
    };
    let ocs = mk(Strategy::Ocs);
    let uni = mk(Strategy::Uniform);
    assert!(ocs <= uni * 1.02, "balanced: ocs {ocs} worse than uniform {uni}");
}
