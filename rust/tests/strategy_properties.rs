//! Statistical property harness for the strategy zoo (DESIGN.md §13).
//!
//! Three pinned families, all on fixed seeds so failures reproduce:
//!
//! 1. **Unbiasedness** — for every strategy, the w_i/p_i-scaled
//!    estimator of the scalar norm sum is unbiased: over `N = 4000`
//!    seeded draws the empirical mean sits within `6σ/√N` of the true
//!    sum, where σ² is the *analytic* sampling variance (Eq. 6). A 6σ
//!    band makes a false alarm astronomically unlikely while still
//!    catching any real properness bug (a single mis-scaled p_i shifts
//!    the mean by orders more than the band). Cyclic is tested at
//!    cycle granularity: one g-round cycle visits every group once, so
//!    the cycle-summed estimator targets the full norm sum.
//! 2. **Budget fixed point** — Σp_i = m at the AOCS fixed point
//!    (j_max = n + 2 guarantees convergence), both on raw norms and on
//!    compressed preview norms, and end-to-end for caocs through the
//!    coordinator with a real RandK compressor.
//! 3. **Variance ordering** — Var(clustered) ≤ Var(uniform) and
//!    Var(OCS) ≤ Var(uniform) on a heterogeneous banded profile,
//!    analytically (strict, deterministic) and empirically (second
//!    moment within 10% of Eq. 6 over 60k seeded draws — Monte-Carlo
//!    error at that trial count is ≲ 2%, so 10% is a safe pin).
//!
//! Plus the determinism contracts: every new strategy is bitwise
//! seed-stable across shards {1, 4} × workers {1, 3}, and cyclic
//! conserves participation (every client exactly once per cycle).

use fedsamp::compress::Compressor;
use fedsamp::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use fedsamp::coordinator::{
    Coordinator, CoordinatorOptions, ParallelRunner, Registry, RoundMachine,
};
use fedsamp::fl::availability::Availability;
use fedsamp::fl::TrainOptions;
use fedsamp::metrics::RunResult;
use fedsamp::sampling::probability::draw_independent;
use fedsamp::sampling::variance::{sampling_variance, uniform_variance};
use fedsamp::sampling::{aocs, cyclic, Sampler};
use fedsamp::sim::build_native_engine;
use fedsamp::telemetry::Telemetry;
use fedsamp::util::rng::Rng;

/// Seeded draws per unbiasedness check.
const DRAWS: usize = 4_000;

/// A heterogeneous norm profile with a zero-update client — the
/// worked profile of the harness (n = 12, Σũ = 21.25).
fn profile() -> Vec<f64> {
    vec![
        5.0, 2.0, 1.0, 0.5, 0.25, 3.0, 0.0, 1.5, 4.0, 0.75, 2.25, 1.0,
    ]
}

/// Monte-Carlo mean/second-moment of the w/p estimator of Σũ under
/// independent draws with `probs`.
fn estimate(
    norms: &[f64],
    probs: &[f64],
    rng: &mut Rng,
    draws: usize,
) -> (f64, f64) {
    let target: f64 = norms.iter().sum();
    let mut mean = 0.0f64;
    let mut second = 0.0f64;
    for _ in 0..draws {
        let sel = draw_independent(probs, rng);
        let est: f64 = sel
            .iter()
            .zip(norms.iter().zip(probs))
            .filter(|(s, _)| **s)
            .map(|(_, (u, p))| u / p)
            .sum();
        mean += est;
        let d = est - target;
        second += d * d;
    }
    (mean / draws as f64, second / draws as f64)
}

/// The 6σ/√N unbiasedness band for an analytic variance.
fn tolerance(variance: f64, draws: usize) -> f64 {
    6.0 * (variance / draws as f64).sqrt() + 1e-9
}

#[test]
fn every_strategy_estimator_is_unbiased() {
    let norms = profile();
    let n = norms.len();
    let ids: Vec<usize> = (0..n).collect();
    let m = 4;
    let target: f64 = norms.iter().sum();
    let samplers = [
        Sampler::Full,
        Sampler::Uniform,
        Sampler::Ocs,
        Sampler::Aocs { j_max: 4 },
        Sampler::Caocs { j_max: 4 },
        Sampler::from_strategy(&Strategy::Clustered { k: 3 }),
    ];
    for (i, s) in samplers.iter().enumerate() {
        let d = s.decide_for_round(&ids, &norms, m);
        let analytic = sampling_variance(&norms, &d.probs);
        assert!(
            analytic.is_finite(),
            "{}: improper sampling (p=0 on a live norm)",
            s.name()
        );
        let mut rng = Rng::new(0xB1A5 + i as u64);
        let (mean, _) = estimate(&norms, &d.probs, &mut rng, DRAWS);
        let tol = tolerance(analytic, DRAWS);
        assert!(
            (mean - target).abs() <= tol,
            "{}: mean {mean} vs target {target} (tol {tol})",
            s.name()
        );
    }
}

#[test]
fn cyclic_cycle_sum_estimator_is_unbiased() {
    // cyclic admits one group per round; unbiasedness holds at cycle
    // granularity: summing the g per-round within-group estimators
    // targets the full norm sum, with variances adding across rounds
    let norms = profile();
    let g = 3usize;
    let seed = 77u64;
    let m = 2usize;
    let target: f64 = norms.iter().sum();
    // the per-round (group, probs) schedule the coordinator would run
    let rounds: Vec<(Vec<usize>, Vec<f64>)> = (0..g)
        .map(|r| {
            let group: Vec<usize> = (0..norms.len())
                .filter(|&c| cyclic::is_scheduled(seed, c, r, g))
                .collect();
            let p = (m as f64 / group.len().max(1) as f64).min(1.0);
            let probs = vec![p; group.len()];
            (group, probs)
        })
        .collect();
    let analytic: f64 = rounds
        .iter()
        .map(|(group, probs)| {
            let gn: Vec<f64> = group.iter().map(|&c| norms[c]).collect();
            sampling_variance(&gn, probs)
        })
        .sum();
    let mut rng = Rng::new(0xC7C1E);
    let mut mean = 0.0f64;
    for _ in 0..DRAWS {
        let mut est = 0.0f64;
        for (group, probs) in &rounds {
            let sel = draw_independent(probs, &mut rng);
            for (&keep, (&c, &p)) in
                sel.iter().zip(group.iter().zip(probs))
            {
                if keep {
                    est += norms[c] / p;
                }
            }
        }
        mean += est;
    }
    mean /= DRAWS as f64;
    let tol = tolerance(analytic, DRAWS);
    assert!(
        (mean - target).abs() <= tol,
        "cyclic cycle mean {mean} vs target {target} (tol {tol})"
    );
}

#[test]
fn aocs_fixed_point_spends_exactly_the_budget() {
    // j_max = n + 2 guarantees Algorithm 2 reaches the Eq. (7) fixed
    // point, where Σp_i = m exactly (up to f64 arithmetic)
    let norms = profile();
    let n = norms.len();
    for m in [2usize, 4, 7] {
        let r = aocs::aocs_probabilities(&norms, m, n + 2);
        assert!(r.converged, "m={m}: not converged at j_max=n+2");
        let sum: f64 = r.probs.iter().sum();
        assert!(
            (sum - m as f64).abs() < 1e-9,
            "m={m}: Σp = {sum}"
        );
    }
    // the caocs solver input is a *transformed* norm vector (compressed
    // preview); the fixed point must hold for any such non-negative
    // profile, not just the raw one
    let compressed: Vec<f64> =
        norms.iter().map(|u| (u * 0.37).sqrt()).collect();
    let r = aocs::aocs_probabilities(&compressed, 4, n + 2);
    assert!(r.converged);
    let sum: f64 = r.probs.iter().sum();
    assert!((sum - 4.0).abs() < 1e-9, "compressed profile: Σp = {sum}");
}

fn cfg(strategy: Strategy) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("zoo_{}", strategy.name()),
        seed: 9,
        rounds: 12,
        cohort: 16,
        budget: 4,
        strategy,
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 40, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: 3,
        eval_examples: 128,
        workers: 1,
        secure_updates: true,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

fn coordinated(
    c: &ExperimentConfig,
    shards: usize,
    workers: usize,
) -> RunResult {
    let engine = build_native_engine(c);
    let mut runner = ParallelRunner::new(engine, workers);
    let mut coordinator = Coordinator::new(CoordinatorOptions {
        shards,
        ..CoordinatorOptions::default()
    });
    coordinator
        .run(c, &mut runner, &TrainOptions::default())
        .unwrap()
}

fn assert_trajectories_identical(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{tag}: round count");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.train_loss, rb.train_loss,
            "{tag}: train_loss round {}",
            ra.round
        );
        assert_eq!(
            ra.uplink_bits, rb.uplink_bits,
            "{tag}: uplink_bits round {}",
            ra.round
        );
        assert_eq!(
            ra.transmitted, rb.transmitted,
            "{tag}: transmitted round {}",
            ra.round
        );
        assert_eq!(
            ra.expected_budget, rb.expected_budget,
            "{tag}: expected_budget round {}",
            ra.round
        );
        // NaN on non-eval rounds: compare bit patterns
        assert_eq!(
            ra.val_accuracy.to_bits(),
            rb.val_accuracy.to_bits(),
            "{tag}: val_accuracy round {}",
            ra.round
        );
        assert_eq!(
            ra.alpha.to_bits(),
            rb.alpha.to_bits(),
            "{tag}: alpha round {}",
            ra.round
        );
    }
}

#[test]
fn caocs_spends_the_budget_through_the_coordinator() {
    // end to end: caocs at its fixed point (j_max > cohort), previewing
    // a real RandK compression, still spends Σp = m every live round
    let mut c = cfg(Strategy::Caocs { j_max: 18 });
    c.compressor = Some(Compressor::RandK { k: 64 });
    let run = coordinated(&c, 1, 1);
    assert_eq!(run.rounds.len(), 12);
    for rec in &run.rounds {
        assert!(
            (rec.expected_budget - 4.0).abs() < 1e-6,
            "round {}: Σp = {}",
            rec.round,
            rec.expected_budget
        );
    }
}

#[test]
fn new_strategies_are_seed_stable_across_provisioning() {
    // the §13 determinism contract: bitwise-identical trajectories for
    // shards {1, 4} × workers {1, 3} under secure aggregation
    let mut arms = vec![
        cfg(Strategy::Clustered { k: 3 }),
        cfg(Strategy::Cyclic { g: 3 }),
        cfg(Strategy::Caocs { j_max: 4 }),
    ];
    // caocs with a live compressor exercises the preview stream too
    arms[2].compressor = Some(Compressor::RandK { k: 64 });
    for c in &arms {
        let baseline = coordinated(c, 1, 1);
        for shards in [1usize, 4] {
            for workers in [1usize, 3] {
                if shards == 1 && workers == 1 {
                    continue;
                }
                let run = coordinated(c, shards, workers);
                assert_trajectories_identical(
                    &baseline,
                    &run,
                    &format!(
                        "{} shards={shards} workers={workers}",
                        c.strategy.name()
                    ),
                );
            }
        }
    }
}

#[test]
fn cyclic_conserves_participation_over_a_cycle() {
    // pool == cohort + always-on: the pre-filter cohort is the whole
    // pool, so each round's announced cohort is exactly the scheduled
    // group and one g-round cycle admits every client exactly once
    let g = 5usize;
    let pool = 30usize;
    let mut c = cfg(Strategy::Cyclic { g });
    c.data = DataSpec::FemnistLike { pool, variant: 1 };
    c.cohort = pool;
    let registry = Registry::new(pool, 3);
    let avail = Availability::AlwaysOn;
    let mut tel = Telemetry::disabled();
    let mut seen = vec![0usize; pool];
    for round in 0..g {
        let mut rng = Rng::new(c.seed).fork(round as u64);
        let mut m = RoundMachine::new(round);
        m.announce(&c, &avail, &registry, None, &mut rng, &mut tel);
        for &client in m.cohort() {
            assert!(
                cyclic::is_scheduled(c.seed, client, round, g),
                "client {client} admitted off-schedule in round {round}"
            );
            seen[client] += 1;
        }
    }
    assert_eq!(seen, vec![1usize; pool], "cycle must cover the pool once");
}

#[test]
fn variance_ordering_holds_analytically_and_empirically() {
    // three well-separated norm bands, 24 clients, k = 3, m = 6
    let ids: Vec<usize> = (0..24).collect();
    let norms: Vec<f64> = ids
        .iter()
        .map(|&c| match c {
            0..=7 => 0.2 + 0.01 * c as f64,
            8..=15 => 2.0 + 0.01 * c as f64,
            _ => 8.0 + 0.01 * c as f64,
        })
        .collect();
    let m = 6;
    let clustered = Sampler::from_strategy(&Strategy::Clustered { k: 3 })
        .decide_for_round(&ids, &norms, m);
    let ocs = Sampler::Ocs.decide(&norms, m);
    let v_clu = sampling_variance(&norms, &clustered.probs);
    let v_ocs = sampling_variance(&norms, &ocs.probs);
    let v_uni = uniform_variance(&norms, m);
    // analytic, deterministic, strict on this profile
    assert!(v_clu < v_uni, "clustered {v_clu} !< uniform {v_uni}");
    assert!(v_ocs < v_uni, "ocs {v_ocs} !< uniform {v_uni}");
    // empirical confirmation: the realized second moment matches the
    // analytic Eq. (6) value within 10% (documented tolerance; the
    // Monte-Carlo error over 60k draws is ≲ 2%)
    let trials = 60_000;
    let mut rng = Rng::new(0x0D0E);
    let (_, emp_clu) = estimate(&norms, &clustered.probs, &mut rng, trials);
    let (_, emp_ocs) = estimate(&norms, &ocs.probs, &mut rng, trials);
    assert!(
        (emp_clu - v_clu).abs() / v_clu < 0.10,
        "clustered empirical {emp_clu} vs analytic {v_clu}"
    );
    assert!(
        (emp_ocs - v_ocs).abs() / v_ocs < 0.10,
        "ocs empirical {emp_ocs} vs analytic {v_ocs}"
    );
    // and the empirical ordering agrees with the analytic one
    let uni = Sampler::Uniform.decide(&norms, m);
    let (_, emp_uni) = estimate(&norms, &uni.probs, &mut rng, trials);
    assert!(emp_clu < emp_uni, "empirical {emp_clu} !< {emp_uni}");
    assert!(emp_ocs < emp_uni, "empirical {emp_ocs} !< {emp_uni}");
}
