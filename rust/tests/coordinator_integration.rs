//! Integration: the sharded coordinator reproduces the seed `fl::train`
//! trajectory and degrades gracefully when shards miss round deadlines.
//!
//! Exactness relies on `secure_updates`: the fixed-point ring sums of the
//! secure-aggregation path commute, so per-shard partial aggregation is
//! bit-identical to the flat sum for *any* shard/worker count.

use fedsamp::compress::Compressor;
use fedsamp::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use fedsamp::coordinator::{
    Coordinator, CoordinatorOptions, DeadlinePolicy, ParallelRunner, Phase,
    Registry, RoundMachine,
};
use fedsamp::data::ClientData;
use fedsamp::faults::{parse_fault_spec, FaultCounters, FaultPlan};
use fedsamp::fl::availability::{Availability, Outage, Trace};
use fedsamp::fl::comm::BitMeter;
use fedsamp::fl::{train, TrainOptions};
use fedsamp::metrics::RunResult;
use fedsamp::model::logistic::Logistic;
use fedsamp::model::NativeModel;
use fedsamp::sampling::Sampler;
use fedsamp::sim::{build_native_engine, NativeEngine};
use fedsamp::telemetry::Telemetry;
use fedsamp::util::rng::Rng;

fn cfg(strategy: Strategy) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("coord_{}", strategy.name()),
        seed: 9,
        rounds: 12,
        cohort: 16,
        budget: 4,
        strategy,
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 40, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: 3,
        eval_examples: 128,
        workers: 1,
        secure_updates: true,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

/// The seed protocol: `fl::train` over the plain engine path.
fn reference(c: &ExperimentConfig) -> RunResult {
    let mut engine = build_native_engine(c);
    train(c, &mut engine, &TrainOptions::default()).unwrap()
}

fn coordinated(
    c: &ExperimentConfig,
    shards: usize,
    workers: usize,
    deadline: Option<DeadlinePolicy>,
) -> (RunResult, fedsamp::coordinator::CoordStats) {
    let engine = build_native_engine(c);
    let mut runner = ParallelRunner::new(engine, workers);
    let mut coordinator =
        Coordinator::new(CoordinatorOptions {
        shards,
        deadline,
        ..CoordinatorOptions::default()
    });
    let run = coordinator.run(c, &mut runner, &TrainOptions::default()).unwrap();
    (run, coordinator.stats)
}

fn assert_trajectories_identical(a: &RunResult, b: &RunResult, tag: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{tag}: round count");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.train_loss, rb.train_loss,
            "{tag}: train_loss round {}",
            ra.round
        );
        assert_eq!(
            ra.uplink_bits, rb.uplink_bits,
            "{tag}: uplink_bits round {}",
            ra.round
        );
        assert_eq!(
            ra.transmitted, rb.transmitted,
            "{tag}: transmitted round {}",
            ra.round
        );
        assert_eq!(
            ra.expected_budget, rb.expected_budget,
            "{tag}: expected_budget round {}",
            ra.round
        );
        // NaN on non-eval rounds: compare bit patterns
        assert_eq!(
            ra.val_accuracy.to_bits(),
            rb.val_accuracy.to_bits(),
            "{tag}: val_accuracy round {}",
            ra.round
        );
        assert_eq!(
            ra.alpha.to_bits(),
            rb.alpha.to_bits(),
            "{tag}: alpha round {}",
            ra.round
        );
    }
}

/// [`Logistic`] routed through the retained per-sample scalar reference
/// gradient — the seed semantics, with the kernel layer bypassed.
struct ScalarLogistic(Logistic);

impl NativeModel for ScalarLogistic {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn loss_grad(
        &self,
        params: &[f32],
        data: &ClientData,
        batch: &[usize],
        grad: &mut [f32],
    ) -> f64 {
        self.0.loss_grad_scalar(params, data, batch, grad)
    }
    fn loss(&self, params: &[f32], data: &ClientData) -> f64 {
        self.0.loss(params, data)
    }
    fn accuracy(&self, params: &[f32], data: &ClientData) -> f64 {
        self.0.accuracy(params, data)
    }
    fn init_params(&self, seed: u64) -> Vec<f32> {
        self.0.init_params(seed)
    }
}

#[test]
fn kernelized_sim_reproduces_the_scalar_reference_trajectory() {
    // the kernel layer's bit-exactness contract, end to end: a secure
    // sim run on the batch GEMM + rank-1 gradient path must be
    // bit-identical to the same run on the seed per-sample scalar path
    let c = cfg(Strategy::Aocs { j_max: 4 });
    assert!(c.secure_updates);
    let kernel_run = reference(&c);
    let proto = build_native_engine(&c);
    let mut scalar_engine = NativeEngine::new(
        ScalarLogistic(proto.model.clone()),
        proto.dataset.clone(),
        proto.algorithm.clone(),
        proto.batch_size,
        c.seed,
    );
    let scalar_run =
        train(&c, &mut scalar_engine, &TrainOptions::default()).unwrap();
    assert_trajectories_identical(&scalar_run, &kernel_run, "kernel vs scalar");
}

#[test]
fn sharded_runs_reproduce_the_seed_trajectory() {
    // the acceptance matrix: shards ∈ {1, 4} × workers ∈ {1, 3} must all
    // be trajectory-identical to the seed fl::train path
    let c = cfg(Strategy::Aocs { j_max: 4 });
    let seed_run = reference(&c);
    for shards in [1usize, 4] {
        for workers in [1usize, 3] {
            let (run, stats) = coordinated(&c, shards, workers, None);
            assert_trajectories_identical(
                &seed_run,
                &run,
                &format!("shards={shards} workers={workers}"),
            );
            assert_eq!(stats.shards_dropped, 0);
            assert_eq!(stats.noop_rounds, 0);
        }
    }
}

#[test]
fn exactness_holds_across_strategies() {
    for strategy in [Strategy::Full, Strategy::Uniform, Strategy::Ocs] {
        let c = cfg(strategy.clone());
        let seed_run = reference(&c);
        let (run, _) = coordinated(&c, 4, 3, None);
        assert_trajectories_identical(&seed_run, &run, strategy.name());
    }
}

#[test]
fn plain_aggregation_single_shard_is_still_exact() {
    // without secure aggregation the single-shard fold happens in cohort
    // order — bit-identical to the seed loop even with pooled workers
    let mut c = cfg(Strategy::Ocs);
    c.secure_updates = false;
    let seed_run = reference(&c);
    let (run, _) = coordinated(&c, 1, 3, None);
    assert_trajectories_identical(&seed_run, &run, "plain shards=1");
}

#[test]
fn plain_aggregation_multi_shard_stays_close() {
    // f32 reorder noise only: the multi-shard plain path may drift in the
    // last ulp but must track the seed trajectory closely
    let mut c = cfg(Strategy::Full); // full: no selection sensitivity
    c.secure_updates = false;
    let seed_run = reference(&c);
    let (run, _) = coordinated(&c, 4, 2, None);
    assert_eq!(seed_run.rounds.len(), run.rounds.len());
    for (ra, rb) in seed_run.rounds.iter().zip(&run.rounds) {
        let tol = 1e-3 * (1.0 + ra.train_loss.abs());
        assert!(
            (ra.train_loss - rb.train_loss).abs() < tol,
            "round {}: {} vs {}",
            ra.round,
            ra.train_loss,
            rb.train_loss
        );
        assert_eq!(ra.uplink_bits, rb.uplink_bits);
        assert_eq!(ra.transmitted, rb.transmitted);
    }
}

#[test]
fn payload_native_folds_match_the_densified_reference_end_to_end() {
    // the wire-layer acceptance gate: for every compressor kind, sim
    // runs on the payload-native scatter folds must be bit-identical to
    // the retained densify-then-accumulate reference (the pre-wire dense
    // path, kernels::reference semantics) — trajectory, measured bytes,
    // selection draws, everything
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    c.secure_updates = false; // plain folds are where the payload path forks
    for compressor in [
        None,
        Some(Compressor::RandK { k: 64 }),
        Some(Compressor::QsgdQuant { levels: 4 }),
    ] {
        let tag = compressor
            .as_ref()
            .map_or_else(|| "none".to_string(), Compressor::name);
        let run = |densify_folds: bool| {
            let mut engine = build_native_engine(&c);
            let opts = TrainOptions {
                compressor: compressor.clone(),
                verbose_every: 0,
                densify_folds,
                ..TrainOptions::default()
            };
            train(&c, &mut engine, &opts).unwrap()
        };
        let native = run(false);
        let reference = run(true);
        assert_trajectories_identical(&reference, &native, &tag);
        for (ra, rb) in reference.rounds.iter().zip(&native.rounds) {
            assert_eq!(ra.uplink_bytes, rb.uplink_bytes, "{tag} bytes");
        }
    }
}

#[test]
fn compressed_secure_runs_stay_sharding_invariant() {
    // compressed payloads densify at the shard boundary on the secure
    // path; ring sums still commute, so shard/worker provisioning must
    // not move a single bit of the trajectory
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    assert!(c.secure_updates);
    c.compressor = Some(Compressor::RandK { k: 64 });
    let seed_run = reference(&c);
    for (shards, workers) in [(1, 1), (4, 3)] {
        let (run, _) = coordinated(&c, shards, workers, None);
        assert_trajectories_identical(
            &seed_run,
            &run,
            &format!("randk secure shards={shards} workers={workers}"),
        );
    }
}

#[test]
fn config_compressor_equals_train_options_compressor() {
    // the config-level field and the TrainOptions override must drive
    // identical runs (same RNG draws, same measured bytes)
    let mut c = cfg(Strategy::Ocs);
    c.secure_updates = false;
    let mut e1 = build_native_engine(&c);
    let via_opts = train(
        &c,
        &mut e1,
        &TrainOptions {
            compressor: Some(Compressor::RandK { k: 32 }),
            ..TrainOptions::default()
        },
    )
    .unwrap();
    c.compressor = Some(Compressor::RandK { k: 32 });
    let mut e2 = build_native_engine(&c);
    let via_cfg = train(&c, &mut e2, &TrainOptions::default()).unwrap();
    assert_trajectories_identical(&via_opts, &via_cfg, "cfg vs opts");
}

#[test]
fn all_shards_missing_every_deadline_yields_noop_rounds() {
    let c = cfg(Strategy::Aocs { j_max: 4 });
    let (run, stats) =
        coordinated(&c, 4, 1, Some(DeadlinePolicy { miss_prob: 1.0 }));
    assert_eq!(run.rounds.len(), c.rounds);
    assert_eq!(stats.noop_rounds, c.rounds);
    assert_eq!(stats.shards_dropped, 4 * c.rounds);
    for r in &run.rounds {
        assert!(r.train_loss.is_nan());
        assert_eq!(r.transmitted, 0);
    }
}

#[test]
fn partial_deadline_misses_still_train() {
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    c.rounds = 25;
    let (run, stats) =
        coordinated(&c, 4, 2, Some(DeadlinePolicy { miss_prob: 0.3 }));
    assert_eq!(run.rounds.len(), c.rounds);
    assert!(stats.shards_dropped > 0, "straggler model never fired");
    let first = run
        .rounds
        .iter()
        .find(|r| !r.train_loss.is_nan())
        .expect("every round lost its whole cohort")
        .train_loss;
    let last = run
        .rounds
        .iter()
        .rev()
        .find(|r| !r.train_loss.is_nan())
        .unwrap()
        .train_loss;
    assert!(
        last < first,
        "no training progress under stragglers: {first} -> {last}"
    );
}

#[test]
fn outage_and_deadline_drop_accounting_is_consistent() {
    // satellite pin for the round machine's loss bookkeeping: trace
    // outages (pre-selection) and deadline drops (post-selection) must
    // stay disjoint in accounting, conserve the announced cohort, and
    // leave `transmitted` bounded by the surviving cohort
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    c.rounds = 60;
    c.availability_trace = Some(Trace {
        seed: 77,
        base_q: 1.0,
        diurnal: None,
        churn: None,
        outage: Some(Outage { prob: 0.45 }),
    });
    let shards = 4usize;
    let registry = Registry::new(40, shards);
    let avail = Availability::Trace(c.availability_trace.clone().unwrap());
    let policy = DeadlinePolicy { miss_prob: 0.4 };
    let sampler = Sampler::from_strategy(&c.strategy);
    let rng = Rng::new(c.seed).fork(0xF1);
    let mut tel = Telemetry::disabled();

    let mut both_fired = 0;
    for round in 0..c.rounds {
        // two machines over identical RNG streams: outage-only vs
        // outage + deadline — the deadline may only remove whole shards
        // from the announced cohort, and never perturbs the outage draw
        let mut a = RoundMachine::new(round);
        a.announce(
            &c,
            &avail,
            &registry,
            None,
            &mut rng.fork(round as u64),
            &mut tel,
        );
        let mut b = RoundMachine::new(round);
        let dropped = b.announce(
            &c,
            &avail,
            &registry,
            Some(&policy),
            &mut rng.fork(round as u64),
            &mut tel,
        );
        assert_eq!(dropped, b.dropped_shards());
        assert_eq!(a.dropped_shards(), 0);
        assert_eq!(a.outaged_shards(), b.outaged_shards());
        assert!(b.outaged_shards() <= shards);
        assert!(b.dropped_shards() <= shards);
        // cohort conservation: b's cohort is exactly a's minus the
        // members of deadline-dropped shards
        let removed: Vec<usize> = a
            .cohort()
            .iter()
            .copied()
            .filter(|id| !b.cohort().contains(id))
            .collect();
        assert_eq!(a.cohort().len(), b.cohort().len() + removed.len());
        let dead: std::collections::BTreeSet<usize> =
            removed.iter().map(|&id| registry.shard_of(id)).collect();
        assert!(dead.len() <= b.dropped_shards(), "round {round}");
        for &kept in b.cohort() {
            assert!(
                !dead.contains(&registry.shard_of(kept)),
                "round {round}: deadline drops must take whole shards"
            );
        }

        if b.outaged_shards() == 0
            || b.dropped_shards() == 0
            || b.cohort().is_empty()
        {
            continue;
        }
        both_fired += 1;
        // both loss mechanisms fired this round: drive a fresh machine
        // through commit and pin the downstream accounting
        let engine = build_native_engine(&c);
        let mut runner = ParallelRunner::new(engine, 1);
        let mut x = runner.init_params(c.seed);
        let mut meter = BitMeter::new();
        let mut round_rng = rng.fork(round as u64);
        let opts = TrainOptions::default();
        let mut m = RoundMachine::new(round);
        m.announce(
            &c,
            &avail,
            &registry,
            Some(&policy),
            &mut round_rng,
            &mut tel,
        );
        assert_eq!(m.cohort(), b.cohort());
        m.local_compute(&mut runner, &x, &mut tel);
        m.norm_report(&mut tel);
        m.negotiate(
            &sampler,
            &c,
            None,
            None,
            None,
            &mut meter,
            &mut round_rng,
            &mut tel,
        );
        m.secure_aggregate(
            &c,
            &opts,
            &registry,
            &mut runner,
            None,
            &mut meter,
            &mut round_rng,
            &mut tel,
        );
        m.repair(&c, None, &mut tel);
        let rec = m
            .commit(&c, &opts, 1.0, &mut x, &mut runner, &meter, &mut tel)
            .unwrap();
        assert_eq!(m.phase(), Phase::Done);
        assert!(m.outaged_shards() > 0 && m.dropped_shards() > 0);
        assert!(
            rec.transmitted <= m.cohort().len(),
            "round {round}: {} transmitted from a {}-client cohort",
            rec.transmitted,
            m.cohort().len()
        );
        assert!(rec.train_loss.is_finite());
        break;
    }
    assert!(
        both_fired > 0,
        "60 rounds at outage p=0.45 × deadline p=0.4 over 4 shards never \
         fired both loss mechanisms in one round — accounting untestable"
    );
}

#[test]
fn zero_rate_fault_plan_is_bitwise_inert() {
    // chaos-layer acceptance gate: a fault plan that can never fire must
    // leave the trajectory bit-identical to the plan-free run across the
    // full shard/worker acceptance matrix
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    let baseline = reference(&c);
    c.fault_plan = Some(FaultPlan::new(0xC0FFEE));
    for shards in [1usize, 4] {
        for workers in [1usize, 3] {
            let (run, stats) = coordinated(&c, shards, workers, None);
            assert_trajectories_identical(
                &baseline,
                &run,
                &format!("faults=0 shards={shards} workers={workers}"),
            );
            assert_eq!(stats.faults, FaultCounters::default());
        }
    }
}

#[test]
fn chaos_secure_run_repairs_dropouts_end_to_end() {
    // crash-after-commitment and in-flight corruption under secure
    // aggregation: every round must complete (mask residues subtracted,
    // estimator renormalized, quarantines absorbed) with finite losses
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    assert!(c.secure_updates);
    c.rounds = 20;
    c.fault_plan =
        Some(parse_fault_spec("crashpost0.3+corrupt0.3").unwrap());
    let (run, stats) = coordinated(&c, 4, 3, None);
    assert_eq!(run.rounds.len(), c.rounds);
    let f = stats.faults;
    // ~4 transmitters × 20 rounds at p=0.3 each: dodging every draw is
    // astronomically unlikely (the fault seed stream is pinned)
    assert!(f.crash_post > 0, "{f:?}");
    assert!(f.corrupt > 0, "{f:?}");
    assert!(f.mask_repairs > 0, "{f:?}");
    assert!(f.injected() > 0 && f.repaired() > 0);
    for r in &run.rounds {
        assert!(r.train_loss.is_finite(), "round {}: {f:?}", r.round);
    }
}

#[test]
fn chaos_plain_run_survives_crashes_and_quarantines() {
    // same chaos arm on the plain-f32 path: failures are pure absences /
    // exclusions, and the renormalized run still trains
    let mut c = cfg(Strategy::Ocs);
    c.secure_updates = false;
    c.rounds = 20;
    c.fault_plan =
        Some(parse_fault_spec("crash0.2+corrupt0.3").unwrap());
    let (run, stats) = coordinated(&c, 4, 2, None);
    let f = stats.faults;
    assert!(f.crash_pre > 0, "{f:?}");
    assert!(f.crash_post > 0, "{f:?}");
    assert!(f.corrupt > 0, "{f:?}");
    assert_eq!(f.mask_repairs, 0, "no masks exist on the plain path");
    for r in &run.rounds {
        assert!(r.train_loss.is_finite());
    }
}

#[test]
fn stalled_negotiation_degrades_and_recovers() {
    // stall faults live in the sharded AOCS negotiation: retries must be
    // issued, some shards must exhaust them and degrade to last-good
    // probabilities, and the run must keep training through it all
    let mut c = cfg(Strategy::Aocs { j_max: 4 });
    c.fault_plan = Some(parse_fault_spec("stall0.4+retries1").unwrap());
    let engine = build_native_engine(&c);
    let mut runner = ParallelRunner::new(engine, 2);
    let mut coordinator = Coordinator::new(CoordinatorOptions {
        shards: 4,
        deadline: None,
        sharded_negotiation: true,
    });
    let run = coordinator
        .run(&c, &mut runner, &TrainOptions::default())
        .unwrap();
    let f = coordinator.stats.faults;
    assert!(f.stalls > 0, "{f:?}");
    assert!(f.retries > 0, "{f:?}");
    // p=0.4 with one retry: a shard-exchange degrades with p=0.16; over
    // 4 shards × ~9 exchanges × 12 rounds dodging all is implausible
    assert!(f.shards_degraded > 0, "{f:?}");
    for r in &run.rounds {
        assert!(r.train_loss.is_finite());
    }
}

#[test]
fn zero_miss_probability_deadline_is_a_noop() {
    // the straggler stream is independent of the protocol RNG: a deadline
    // policy that never fires must leave the trajectory bit-identical
    let c = cfg(Strategy::Ocs);
    let baseline = coordinated(&c, 4, 1, None).0;
    let (gated, stats) =
        coordinated(&c, 4, 1, Some(DeadlinePolicy { miss_prob: 0.0 }));
    assert_eq!(stats.shards_dropped, 0);
    assert_trajectories_identical(&baseline, &gated, "deadline miss=0");
}
