//! Micro-bench: scalar-reference vs kernelized hot loops (`norm_sq`,
//! `dot`, `axpy`, `weighted_accumulate`, the logistic `loss_grad` batch
//! path) plus end-to-end sim rounds/sec.
//!
//! Thin wrapper over `exp::kernelbench` — the same suite the
//! `fedsamp bench kernels` CLI mode runs (which additionally emits
//! `BENCH_kernels.json`). Pass `--quick` for the 1-ish-iteration CI
//! smoke mode: `cargo bench --bench micro_kernels -- --quick`.

use fedsamp::exp::kernelbench::run_kernel_suite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let doc = run_kernel_suite(quick);
    println!("\n{}", doc.to_pretty());
}
