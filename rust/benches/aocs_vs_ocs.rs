//! Bench: footnote 4 ("results of Algorithms 1 and 2 are identical") and
//! Remark 3 (extra communication cost) — AOCS fixed-point quality and
//! negotiation overhead vs j_max.

use fedsamp::bench::{f, Table};
use fedsamp::sampling::aocs::aocs_probabilities;
use fedsamp::sampling::ocs::ocs_probabilities;
use fedsamp::sampling::variance::sampling_variance;
use fedsamp::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(21);
    let n = 128;
    println!("=== AOCS → OCS convergence vs j_max (n={n}, heavy-tail) ===");
    let mut t = Table::new(&[
        "m", "j_max", "max|p_aocs-p_ocs|", "var_ratio", "iters",
        "extra_floats/client",
    ]);
    for m in [4usize, 13, 32] {
        let norms: Vec<f64> =
            (0..n).map(|_| rng.exponential(0.25) + 1e-4).collect();
        let exact = ocs_probabilities(&norms, m);
        let v_exact = sampling_variance(&norms, &exact.probs);
        for j_max in [0usize, 1, 2, 4, 8, 16] {
            let a = aocs_probabilities(&norms, m, j_max);
            let max_gap = a
                .probs
                .iter()
                .zip(&exact.probs)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            let v_a = sampling_variance(&norms, &a.probs);
            t.row(vec![
                m.to_string(),
                j_max.to_string(),
                format!("{max_gap:.2e}"),
                f(v_a / v_exact.max(1e-300), 4),
                a.iterations.to_string(),
                a.extra_uplink_floats_per_client.to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape: the paper's j_max=4 already drives the \
         probability gap to ~float tolerance and var_ratio → 1.000 \
         (footnote 4); cost grows as 1 + 2·iters floats per client \
         (Remark 3) — negligible vs d=242k-float updates."
    );
}
