//! Micro-bench: the sampling hot path — exact OCS (Eq. 7), AOCS
//! (Algorithm 2) and the independent draw at pool sizes up to 10⁶.
//!
//! The coordinator computes these once per round; the paper's cross-
//! device setting has n up to millions, so the solver must stay
//! O(n log n) with small constants.

use fedsamp::bench::Bench;
use fedsamp::sampling::aocs::aocs_probabilities;
use fedsamp::sampling::ocs::ocs_probabilities;
use fedsamp::sampling::probability::draw_independent;
use fedsamp::util::rng::Rng;
use std::hint::black_box;
use std::time::Duration;

fn profile(n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n).map(|_| rng.exponential(0.3) + 1e-4).collect()
}

fn main() {
    let mut rng = Rng::new(42);
    for &n in &[100usize, 10_000, 1_000_000] {
        let norms = profile(n, &mut rng);
        let m = (n / 10).max(1);
        let b = Bench::new(&format!("sampling/n={n}"))
            .with_min_time(Duration::from_millis(400));
        b.run("ocs_exact", || {
            black_box(ocs_probabilities(black_box(&norms), m));
        });
        b.run("aocs_jmax4", || {
            black_box(aocs_probabilities(black_box(&norms), m, 4));
        });
        let probs = ocs_probabilities(&norms, m).probs;
        let mut draw_rng = Rng::new(7);
        b.run("independent_draw", || {
            black_box(draw_independent(black_box(&probs), &mut draw_rng));
        });
    }
    println!(
        "\nexpected: ocs ~O(n log n) (sort-dominated), aocs ~O(j_max·n), \
         draw ~O(n); all sub-ms at n=10⁴ — never the round bottleneck."
    );
}
