//! Micro-bench: the typed wire layer — payload-native sparse folds vs
//! the retained densify-then-accumulate reference, encode/decode codec
//! cost, and compressor × strategy sim arms with measured bytes/round.
//!
//! Thin wrapper over `exp::commbench` — the same suite the
//! `fedsamp bench comm` CLI mode runs (which additionally emits
//! `BENCH_comm.json`). Pass `--quick` for the 1-ish-iteration CI smoke
//! mode: `cargo bench --bench micro_comm -- --quick`.

use fedsamp::exp::commbench::run_comm_suite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let doc = run_comm_suite(quick);
    println!("\n{}", doc.to_pretty());
}
