//! Bench: regenerate Figure 2 — the client-size distributions of the
//! three modified FEMNIST training sets (footnote 6's (s,a,b) procedure).

use fedsamp::bench::{f, Table};
use fedsamp::config::DataSpec;
use fedsamp::data;
use fedsamp::util::stats::summarize;

fn main() {
    fedsamp::exp::figures::figure2(350, 1);

    // cross-variant summary the figure's caption implies
    println!("\n=== client-size summary per variant ===");
    let mut t = Table::new(&[
        "variant", "clients", "examples", "mean", "std", "cv", "median",
    ]);
    for variant in 1..=3u8 {
        let fd = data::build(
            &DataSpec::FemnistLike { pool: 350, variant },
            16,
            1,
        );
        let sizes: Vec<f64> =
            fd.client_sizes().iter().map(|&s| s as f64).collect();
        let s = summarize(&sizes);
        t.row(vec![
            variant.to_string(),
            s.n.to_string(),
            fd.total_examples().to_string(),
            f(s.mean, 1),
            f(s.std, 1),
            f(s.std / s.mean, 2),
            f(s.median, 0),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape: coefficient of variation (cv) decreases from \
         dataset 1 to dataset 3 (decreasing unbalancedness, Figure 2)."
    );
}
