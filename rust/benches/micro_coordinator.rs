//! Micro-bench: coordinator round throughput (rounds/sec) vs shard count
//! on the quadratic sim model — exact closed-form gradients, so the
//! measurement isolates protocol overhead (registry split, worker-pool
//! dispatch, norm report, negotiation, partial tree-aggregation) from
//! model compute.

use fedsamp::bench::Bench;
use fedsamp::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use fedsamp::coordinator::{
    ClientCompute, Coordinator, CoordinatorOptions, ParallelRunner,
};
use fedsamp::fl::{EvalOutcome, LocalOutcome, TrainOptions};
use fedsamp::model::quadratic::QuadraticProblem;
use fedsamp::tensor::kernels::Scratch;
use std::hint::black_box;
use std::time::Duration;

/// [`ClientCompute`] over the quadratic testbed: DSGD with exact local
/// gradients, uniform client weights.
struct QuadraticCompute {
    problem: QuadraticProblem,
}

impl ClientCompute for QuadraticCompute {
    fn dim(&self) -> usize {
        self.problem.dim
    }

    fn num_clients(&self) -> usize {
        self.problem.clients.len()
    }

    fn init_params(&self, _seed: u64) -> Vec<f32> {
        vec![0.0; self.problem.dim]
    }

    fn local_one(
        &self,
        _round: usize,
        global: &[f32],
        client: usize,
        scratch: &mut Scratch,
    ) -> LocalOutcome {
        let c = &self.problem.clients[client];
        Scratch::ensure(&mut scratch.grad, self.problem.dim);
        c.grad(global, &mut scratch.grad);
        LocalOutcome {
            train_loss: c.loss(global),
            delta: scratch.grad.clone(),
            examples: 1,
        }
    }

    fn evaluate(&self, global: &[f32]) -> EvalOutcome {
        EvalOutcome { loss: self.problem.loss(global), accuracy: f64::NAN }
    }
}

fn bench_cfg(rounds: usize, cohort: usize, secure: bool) -> ExperimentConfig {
    ExperimentConfig {
        name: "bench_coordinator".into(),
        seed: 1,
        rounds,
        cohort,
        budget: (cohort / 8).max(1),
        strategy: Strategy::Ocs,
        algorithm: Algorithm::Dsgd { eta: 0.05 },
        data: DataSpec::FemnistLike { pool: 0, variant: 0 }, // unused: compute is explicit
        model: "native:quadratic".into(),
        batch_size: 1,
        eval_every: rounds.max(1),
        eval_examples: 1,
        workers: 1,
        secure_updates: secure,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

fn main() {
    let n = 256;
    let dim = 4096;
    let rounds = 20;
    let cohort = 64;
    let problem = QuadraticProblem::generate(n, dim, 3.0, 8.0, None, 7);
    println!(
        "coordinator throughput: pool={n} dim={dim} cohort={cohort} \
         rounds/run={rounds}"
    );

    for &secure in &[false, true] {
        for &shards in &[1usize, 2, 4, 8] {
            let workers = shards;
            let compute = QuadraticCompute { problem: problem.clone() };
            let mut runner = ParallelRunner::new(compute, workers);
            let cfg = bench_cfg(rounds, cohort, secure);
            let b = Bench::new(&format!(
                "coordinator/secure={secure}/shards={shards}"
            ))
            .with_min_time(Duration::from_millis(400));
            b.run_throughput("rounds", rounds as u64, || {
                let mut coordinator = Coordinator::new(CoordinatorOptions {
                    shards,
                    ..CoordinatorOptions::default()
                });
                let run = coordinator
                    .run(&cfg, &mut runner, &TrainOptions::default())
                    .unwrap();
                black_box(run);
            });
        }
    }
    println!(
        "\nexpected: plain-path rounds/sec grows with shards until the \
         master-side negotiation and O(shards) tree combine dominate; the \
         secure path pays the O(|S|²·d) mask streams regardless of shard \
         count — that cost is per-participant, not per-shard."
    );
}
