//! Bench: the §5.4 "optimal sampling allows larger learning rates" claim,
//! measured as the maximum stable DSGD step size per strategy on the
//! quadratic testbed (cf. the tuned η_l gaps in Appendix F).

use fedsamp::bench::{f, Table};
use fedsamp::model::quadratic::QuadraticProblem;
use fedsamp::sampling::Sampler;
use fedsamp::sim::theory::max_stable_eta;

fn main() {
    println!("=== max stable step size per strategy (quadratic testbed) ===");
    let mut t = Table::new(&[
        "skew", "m", "full", "ocs", "aocs", "uniform", "ocs/uniform",
    ]);
    for &skew in &[0.0, 1.0, 2.0] {
        let p = QuadraticProblem::generate_skewed(
            32, 32, 3.0, skew, 8.0, None, 11,
        );
        for &m in &[3usize, 8] {
            let eta = |s: &Sampler| max_stable_eta(&p, s, m, 150, 5);
            let e_full = eta(&Sampler::Full);
            let e_ocs = eta(&Sampler::Ocs);
            let e_aocs = eta(&Sampler::Aocs { j_max: 4 });
            let e_uni = eta(&Sampler::Uniform);
            t.row(vec![
                f(skew, 1),
                m.to_string(),
                f(e_full, 4),
                f(e_ocs, 4),
                f(e_aocs, 4),
                f(e_uni, 4),
                f(e_ocs / e_uni.max(1e-12), 2),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape: η_max(ocs) ≈ η_max(aocs) ≥ η_max(uniform), \
         with the ocs/uniform ratio growing with client heterogeneity \
         (skew) — the paper found 4× (2^-3 vs 2^-5) on FEMNIST dataset 1."
    );
}
