//! Bench: Definition 11 / Appendix B quantities — α^k across norm-profile
//! families and budgets, the variance reduction OCS delivers over uniform
//! sampling, and the m̃ = γ·n "effective clients" intuition.

use fedsamp::bench::{f, Table};
use fedsamp::sampling::variance::{
    effective_clients, gamma, improvement_factor, sampling_variance,
    uniform_variance,
};
use fedsamp::sampling::ocs::ocs_probabilities;
use fedsamp::util::rng::Rng;

fn profile(kind: &str, n: usize, rng: &mut Rng) -> Vec<f64> {
    match kind {
        "constant" => vec![1.0; n],
        "gaussian" => (0..n).map(|_| rng.gaussian().abs() + 0.2).collect(),
        "heavy_tail" => (0..n).map(|_| rng.exponential(0.2)).collect(),
        "sparse20" => (0..n)
            .map(|i| if i % 5 == 0 { rng.exponential(0.5) + 0.5 } else { 0.0 })
            .collect(),
        _ => unreachable!(),
    }
}

fn main() {
    let n = 128;
    let mut rng = Rng::new(9);
    println!("=== α^k and variance reduction by norm profile (n={n}) ===");
    let mut t = Table::new(&[
        "profile", "m", "alpha", "gamma", "eff_clients",
        "var_ocs", "var_uniform", "reduction",
    ]);
    for kind in ["constant", "gaussian", "heavy_tail", "sparse20"] {
        for m in [4usize, 13, 32] {
            let norms = profile(kind, n, &mut rng);
            let a = improvement_factor(&norms, m);
            let g = gamma(a, n, m);
            let v_o = sampling_variance(
                &norms,
                &ocs_probabilities(&norms, m).probs,
            );
            let v_u = uniform_variance(&norms, m);
            t.row(vec![
                kind.into(),
                m.to_string(),
                f(a, 4),
                f(g, 3),
                f(effective_clients(a, n, m), 1),
                format!("{v_o:.3e}"),
                format!("{v_u:.3e}"),
                if v_u > 0.0 {
                    format!("{:.1}x", v_u / v_o.max(1e-300))
                } else {
                    "-".into()
                },
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape: constant → α=1 (no gain); heavier tails → \
         smaller α → γ→1; sparse (≤m non-zero at m=32) → α=0, \
         infinite reduction (full-participation behaviour)."
    );
}
