//! Micro-bench: secure-aggregation masking cost, scalar-reference vs
//! fused-kernel arms (roster size × dimension) plus secure-vs-plain sim
//! rounds/sec.
//!
//! Thin wrapper over `exp::securebench` — the same suite the
//! `fedsamp bench secure` CLI mode runs (which additionally emits
//! `BENCH_secure.json`). Pass `--quick` for the 1-ish-iteration CI
//! smoke mode: `cargo bench --bench micro_secure -- --quick`.

use fedsamp::exp::securebench::run_secure_suite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let doc = run_secure_suite(quick);
    println!("\n{}", doc.to_pretty());
}
