//! Micro-bench: secure aggregation masking/summing cost vs participant
//! count and update dimension (the O(k²·d)-mask-stream trade the paper's
//! deployable path pays).

use fedsamp::bench::Bench;
use fedsamp::secure_agg::SecureAggregator;
use fedsamp::util::rng::Rng;
use std::hint::black_box;
use std::time::Duration;

fn main() {
    let mut rng = Rng::new(3);
    for &(k, d) in &[(4usize, 10_000usize), (12, 10_000), (12, 250_000)] {
        let agg = SecureAggregator::new(99);
        let roster: Vec<u64> = (0..k as u64).collect();
        let data: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let b = Bench::new(&format!("secure_agg/k={k},d={d}"))
            .with_min_time(Duration::from_millis(400));
        b.run("mask_one_client", || {
            black_box(agg.mask(0, &roster, black_box(&data[0])));
        });
        let masked: Vec<Vec<u64>> = roster
            .iter()
            .zip(&data)
            .map(|(&id, v)| agg.mask(id, &roster, v))
            .collect();
        b.run("sum_and_decode", || {
            let s = SecureAggregator::sum(black_box(&masked));
            black_box(SecureAggregator::decode_sum(&s));
        });
    }
    println!(
        "\nexpected: masking scales with (k−1)·d PRG draws per client; \
         at the paper's m≈3–12 participants this stays millisecond-scale \
         even for 250k-parameter updates."
    );
}
