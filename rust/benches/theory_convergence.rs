//! Bench: Theorem 13/15 shape — the DSGD E‖x^k − x*‖² recursion for
//! full / OCS / uniform across budgets, on the exactly-solvable
//! quadratic testbed.

use fedsamp::bench::{f, Table};
use fedsamp::model::quadratic::QuadraticProblem;
use fedsamp::sampling::Sampler;
use fedsamp::sim::theory::run_dsgd_quadratic;

fn main() {
    let p = QuadraticProblem::generate(32, 32, 3.0, 8.0, None, 11);
    let eta = 0.25 / p.smoothness();
    println!(
        "=== DSGD distance recursion (n=32, η=0.25/L, mean of 5 seeds) ==="
    );
    let mut t = Table::new(&[
        "m", "strategy", "dist@50", "dist@200", "dist@400", "mean_gamma",
    ]);
    for m in [2usize, 4, 8, 16] {
        for s in [Sampler::Full, Sampler::Ocs, Sampler::Uniform] {
            // full ignores m but is run once per m for table alignment
            let mut d50 = 0.0;
            let mut d200 = 0.0;
            let mut d400 = 0.0;
            let mut mg = 0.0;
            let seeds = 5;
            for seed in 0..seeds {
                let run =
                    run_dsgd_quadratic(&p, &s, m, eta, 400, 0.0, seed);
                assert!(!run.diverged, "{} diverged at m={m}", s.name());
                d50 += run.rounds[49].dist_sq;
                d200 += run.rounds[199].dist_sq;
                d400 += run.rounds[399].dist_sq;
                mg += run.mean_gamma();
            }
            let k = seeds as f64;
            t.row(vec![
                m.to_string(),
                s.name().into(),
                format!("{:.3e}", d50 / k),
                format!("{:.3e}", d200 / k),
                format!("{:.3e}", d400 / k),
                f(mg / k, 3),
            ]);
        }
    }
    t.print();
    println!(
        "\nexpected shape (Theorem 13): at every horizon \
         full ≤ ocs ≤ uniform; the ocs↔full gap closes as m grows \
         (γ → 1), the ocs↔uniform gap closes as m → n."
    );
}
