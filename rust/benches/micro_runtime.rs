//! Micro-bench: PJRT runtime hot path — train-step latency, param
//! conversion overhead, and eval throughput on the AOT artifacts.
//!
//! Skips (with a message) when `make artifacts` has not run, and is a
//! no-op in default builds: the PJRT path needs `--features xla`.

#[cfg(feature = "xla")]
mod real {
    use fedsamp::bench::Bench;
    use fedsamp::exp::{default_artifacts_dir, have_artifacts};
    use fedsamp::runtime::Runtime;
    use fedsamp::util::rng::Rng;
    use std::hint::black_box;
    use std::time::Duration;

    fn batch_inputs(
        rt: &Runtime,
        bsz: usize,
        rng: &mut Rng,
    ) -> (xla::Literal, xla::Literal) {
        let per = rt.manifest.input_elems();
        let labels: Vec<u32> = (0..bsz)
            .map(|_| rng.below(rt.manifest.num_classes as u64) as u32)
            .collect();
        let xb = if rt.manifest.input_dtype == "f32" {
            let xs: Vec<f32> = (0..bsz * per).map(|_| rng.f32()).collect();
            rt.input_literal(Some(&xs), None, bsz).unwrap()
        } else {
            let toks: Vec<i32> = (0..bsz * per)
                .map(|_| rng.below(rt.manifest.num_classes as u64) as i32)
                .collect();
            rt.input_literal(None, Some(&toks), bsz).unwrap()
        };
        let oh = rt.onehot_literal(&labels, bsz).unwrap();
        (xb, oh)
    }

    pub fn run() {
        let dir = default_artifacts_dir();
        if !have_artifacts(&dir) {
            println!("micro_runtime: artifacts missing — run `make artifacts`");
            return;
        }
        let mut rng = Rng::new(5);
        for model in ["femnist_mlp", "femnist_mlp_pallas", "shakespeare_gru"] {
            let rt = match Runtime::load(&dir, model) {
                Ok(rt) => rt,
                Err(e) => {
                    println!("skip {model}: {e}");
                    continue;
                }
            };
            let flat = rt.init_params().unwrap();
            let (xb, oh) = batch_inputs(&rt, rt.manifest.batch_size, &mut rng);
            let (exb, eoh) = batch_inputs(&rt, rt.manifest.eval_batch, &mut rng);

            let b = Bench::new(&format!("runtime/{model}"))
                .with_min_time(Duration::from_millis(500));
            b.run("params_to_literals", || {
                black_box(rt.params_to_literals(black_box(&flat)).unwrap());
            });
            let mut params = rt.params_to_literals(&flat).unwrap();
            b.run("train_step", || {
                black_box(rt.train_step(&mut params, &xb, &oh, 0.01).unwrap());
            });
            b.run("literals_to_params", || {
                black_box(rt.literals_to_params(black_box(&params)).unwrap());
            });
            b.run("eval_step", || {
                black_box(rt.eval_step(&params, &exb, &eoh).unwrap());
            });
        }
        println!(
            "\nexpected: train_step dominates (the actual compute); the \
             flat↔literal conversions must stay ≪ one train_step — that's \
             why the client loop keeps params in literal form across batches. \
             femnist_mlp_pallas quantifies the interpret-mode overhead \
             (CPU-only artifact; see DESIGN.md §Hardware-Adaptation)."
        );
    }
}

#[cfg(feature = "xla")]
fn main() {
    real::run();
}

#[cfg(not(feature = "xla"))]
fn main() {
    println!(
        "micro_runtime: PJRT path disabled in this build — vendor the \
         xla bindings, add them to Cargo.toml [dependencies], and rerun \
         with `cargo bench --features xla`. See micro_coordinator for \
         the std-only round-protocol bench."
    );
}
