//! Bench: regenerate Figure 3 — FEMNIST dataset 1 (n=32, m∈{3,6}).
//!
//! Sim-path reduced-scale regeneration (quick scale, 1 seed). The series
//! and summary printed here are the figure's data; the paper-scale run is
//! `fedsamp figures --fig 3 --scale full --seeds 5` (or the XLA path
//! via --sim false). Also reports wall-clock per round.

use fedsamp::exp::figures::{run_figure, Scale};
use fedsamp::fl::TrainOptions;

fn main() {
    let t0 = std::time::Instant::now();
    let arms = run_figure(
        "3",
        Scale::Quick,
        1,
        &fedsamp::exp::default_artifacts_dir(),
        true, // sim engine: benches stay fast; examples cover the XLA path
        None,
        &TrainOptions::default(),
    )
    .expect("figure run failed");
    let rounds: usize = arms
        .iter()
        .flat_map(|panel| panel.iter().map(|a| a.result.rounds.len()))
        .sum();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n[bench] fig3_femnist1: {rounds} strategy-rounds in {wall:.2}s          ({:.1} ms/round)",
        1e3 * wall / rounds.max(1) as f64
    );
}
