//! The typed wire layer: what a participating client actually puts on
//! the uplink.
//!
//! The paper's headline claim is uplink reduction, and §6 positions OCS
//! as orthogonal to communication compression — so the upload path must
//! move *native* compressed payloads, not dense decompressed
//! equivalents, and the communication accounting must be **measured**
//! from the bytes a payload really encodes to, not estimated from a
//! formula. [`Payload`] is that contract:
//!
//! * [`Payload::Dense`] — one f32 per coordinate (the uncompressed
//!   upload; also what `Compressor::None` produces).
//! * [`Payload::SparseK`] — rand-k sparsification (Stich et al., 2018):
//!   `k` retained coordinates as parallel index/value arrays, values
//!   already carrying the d/k unbiasing scale.
//! * [`Payload::Quantized`] — QSGD-style dithering (Alistarh et al.,
//!   2017): one shared norm plus a sign+level code word per coordinate,
//!   bit-packed into u64 words (`tensor::kernels::{pack_bits,
//!   unpack_bits}`). The variant carries its coordinate count `dim`
//!   because it is not recoverable from `packed.len()` (the last word
//!   has slack bits).
//!
//! **Byte-exact framing.** [`Payload::encode_into`] appends a
//! self-describing little-endian frame (1-byte tag + per-kind header +
//! body); [`Payload::decode`] inverts it exactly —
//! `decode(encode(p)) == p` for every payload, pinned by property
//! tests:
//!
//! ```
//! use fedsamp::wire::Payload;
//! let p = Payload::SparseK { indices: vec![1, 4], values: vec![0.5, -2.0] };
//! let mut frame = Vec::new();
//! p.encode_into(&mut frame);
//! assert_eq!(frame.len(), p.wire_bytes()); // measured, not estimated
//! assert_eq!(Payload::decode(&frame).unwrap(), p);
//! ```
//! [`Payload::wire_bytes`] returns the encoded length without
//! encoding (property-tested equal to `encode_into`'s output length,
//! and re-verified against a real encode on every debug-build metering
//! call); the [`crate::fl::comm::BitMeter`] counts it per upload, so
//! the metrics are measured frame lengths, not formula estimates.
//!
//! **Densify boundary.** The secure-aggregation path is dense-only: the
//! pairwise masks cover every coordinate, so a sparse payload cannot
//! stay sparse once masked. Compressed payloads densify at the shard
//! boundary ([`Payload::densify_into`] into the per-worker scratch
//! arena) — see `coordinator::aggregate::fused_masked_partial` and
//! DESIGN.md §7. The plain path never densifies: the scatter-add
//! kernels (`tensor::kernels::{sparse_weighted_accumulate,
//! quantized_accumulate}`) fold payloads natively, bit-exact to the
//! retained densify-then-accumulate reference.

use crate::tensor::kernels;

/// Frame tags (first byte of every encoded payload).
const TAG_DENSE: u8 = 0;
const TAG_SPARSE: u8 = 1;
const TAG_QUANT: u8 = 2;

/// Typed decode/validation failure for an adversarial or damaged frame.
///
/// Every way a hostile frame can lie is a variant here, not a panic and
/// not silently folded garbage: the chaos layer's corruption faults and
/// any future real transport route through these errors to quarantine
/// the sender.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// Frame ended before the field at byte `at` (needed `need` more).
    Truncated { at: usize, need: usize },
    /// Bytes left over after a complete payload frame.
    TrailingBytes(usize),
    /// First byte is not a known payload tag.
    UnknownTag(u8),
    /// Sparse indices not strictly ascending (duplicates double-count
    /// in the scatter fold).
    UnsortedIndices,
    /// A carried f32 (`field`) is NaN or infinite — folding it would
    /// silently poison the aggregate.
    NonFinite { field: &'static str },
    /// A sparse index addresses past the model dimension.
    IndexOutOfRange { index: u32, dim: usize },
    /// Payload's coordinate count disagrees with the model dimension.
    DimMismatch { got: usize, want: usize },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated { at, need } => write!(
                f,
                "truncated payload frame at byte {at} (need {need} more)"
            ),
            DecodeError::TrailingBytes(n) => {
                write!(f, "{n} trailing bytes after payload frame")
            }
            DecodeError::UnknownTag(tag) => {
                write!(f, "unknown payload tag {tag}")
            }
            DecodeError::UnsortedIndices => {
                write!(f, "sparse indices must be strictly ascending")
            }
            DecodeError::NonFinite { field } => {
                write!(f, "non-finite {field} in payload")
            }
            DecodeError::IndexOutOfRange { index, dim } => {
                write!(f, "sparse index {index} out of dim {dim}")
            }
            DecodeError::DimMismatch { got, want } => {
                write!(f, "payload dim {got} does not match model dim {want}")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

impl From<DecodeError> for String {
    fn from(e: DecodeError) -> String {
        e.to_string()
    }
}

/// One client upload, in its native (possibly compressed) representation.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// One f32 per coordinate.
    Dense(Vec<f32>),
    /// Rand-k sparsification: `values[t]` belongs to coordinate
    /// `indices[t]` (ascending, each at most once) and already carries
    /// the d/k unbiasing scale.
    SparseK { indices: Vec<u32>, values: Vec<f32> },
    /// QSGD dithering: coordinate j reconstructs as
    /// `±norm·level_j/max(levels,1)` from the sign+level code word at
    /// slot j of `packed` (bit width `kernels::qsgd_bits_per_coord`).
    Quantized { dim: u32, norm: f32, levels: u32, packed: Vec<u64> },
}

impl Payload {
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Dense(_) => "dense",
            Payload::SparseK { .. } => "sparsek",
            Payload::Quantized { .. } => "quantized",
        }
    }

    /// Coordinates carried explicitly: d (dense), k (sparse), d
    /// (quantized — every coordinate has a code word).
    pub fn carried(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::SparseK { indices, .. } => indices.len(),
            Payload::Quantized { dim, .. } => *dim as usize,
        }
    }

    /// Exact encoded length in bytes — equals `encode_into`'s output
    /// length (property-tested), without producing the frame.
    pub fn wire_bytes(&self) -> usize {
        match self {
            // tag + u32 len + 4 bytes per value
            Payload::Dense(v) => 5 + 4 * v.len(),
            // tag + u32 k + (u32 index + f32 value) per coordinate
            Payload::SparseK { indices, .. } => 5 + 8 * indices.len(),
            // tag + u32 dim + f32 norm + u32 levels + u64 words
            Payload::Quantized { packed, .. } => 13 + 8 * packed.len(),
        }
    }

    /// Append the byte-exact little-endian frame to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.reserve(self.wire_bytes());
        match self {
            Payload::Dense(v) => {
                out.push(TAG_DENSE);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for &x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::SparseK { indices, values } => {
                assert_eq!(
                    indices.len(),
                    values.len(),
                    "ragged sparse payload"
                );
                debug_assert!(
                    indices.windows(2).all(|w| w[0] < w[1]),
                    "sparse indices must be strictly ascending"
                );
                out.push(TAG_SPARSE);
                out.extend_from_slice(&(indices.len() as u32).to_le_bytes());
                for &i in indices {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for &x in values {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::Quantized { dim, norm, levels, packed } => {
                assert_eq!(
                    packed.len(),
                    kernels::qsgd_packed_words(*dim as usize, *levels),
                    "quantized payload word count"
                );
                out.push(TAG_QUANT);
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&norm.to_le_bytes());
                out.extend_from_slice(&levels.to_le_bytes());
                for &w in packed {
                    out.extend_from_slice(&w.to_le_bytes());
                }
            }
        }
    }

    /// Decode one frame; the input must be exactly one encoded payload
    /// (trailing bytes are an error, as is truncation). Carried floats
    /// must be finite: a NaN or infinity would silently poison every
    /// coordinate it is folded into, so adversarial frames carrying them
    /// are rejected here, where the sender can still be quarantined.
    pub fn decode(bytes: &[u8]) -> Result<Payload, DecodeError> {
        let mut r = Reader { b: bytes, i: 0 };
        // pre-allocations are bounded by the bytes actually present so a
        // corrupt length prefix yields the truncation error, not an
        // attempted multi-GiB allocation
        let payload = match r.u8()? {
            TAG_DENSE => {
                let n = r.u32()? as usize;
                let mut v = Vec::with_capacity(n.min(r.remaining() / 4));
                for _ in 0..n {
                    v.push(r.finite_f32("dense value")?);
                }
                Payload::Dense(v)
            }
            TAG_SPARSE => {
                let k = r.u32()? as usize;
                let mut indices =
                    Vec::with_capacity(k.min(r.remaining() / 8));
                for _ in 0..k {
                    indices.push(r.u32()?);
                }
                // the SparseK invariant (ascending ⇒ distinct) is what
                // makes the scatter fold bit-exact to the densified
                // reference — reject frames that violate it rather than
                // letting a duplicate index double-count downstream.
                // (Index *range* is validated against the model dim by
                // `validate_for_dim`, where the dimension is known.)
                if !indices.windows(2).all(|w| w[0] < w[1]) {
                    return Err(DecodeError::UnsortedIndices);
                }
                let mut values = Vec::with_capacity(k);
                for _ in 0..k {
                    values.push(r.finite_f32("sparse value")?);
                }
                Payload::SparseK { indices, values }
            }
            TAG_QUANT => {
                let dim = r.u32()?;
                let norm = r.finite_f32("quantized norm")?;
                let levels = r.u32()?;
                let words = kernels::qsgd_packed_words(dim as usize, levels);
                let mut packed =
                    Vec::with_capacity(words.min(r.remaining() / 8));
                for _ in 0..words {
                    packed.push(r.u64()?);
                }
                Payload::Quantized { dim, norm, levels, packed }
            }
            tag => return Err(DecodeError::UnknownTag(tag)),
        };
        if r.i != bytes.len() {
            return Err(DecodeError::TrailingBytes(bytes.len() - r.i));
        }
        Ok(payload)
    }

    /// Validate the payload against the model dimension — the checks
    /// [`Payload::decode`] cannot do because a frame does not carry the
    /// model dim: sparse index range / count, dense and quantized
    /// coordinate counts. A payload passing `decode` + `validate_for_dim`
    /// is safe to fold (`densify_into` cannot panic on it).
    pub fn validate_for_dim(&self, dim: usize) -> Result<(), DecodeError> {
        match self {
            Payload::Dense(v) => {
                if v.len() != dim {
                    return Err(DecodeError::DimMismatch {
                        got: v.len(),
                        want: dim,
                    });
                }
            }
            Payload::SparseK { indices, .. } => {
                if indices.len() > dim {
                    return Err(DecodeError::DimMismatch {
                        got: indices.len(),
                        want: dim,
                    });
                }
                // ascending (decode invariant) ⇒ checking the last
                // index bounds them all
                if let Some(&last) = indices.last() {
                    if last as usize >= dim {
                        return Err(DecodeError::IndexOutOfRange {
                            index: last,
                            dim,
                        });
                    }
                }
            }
            Payload::Quantized { dim: d, .. } => {
                if *d as usize != dim {
                    return Err(DecodeError::DimMismatch {
                        got: *d as usize,
                        want: dim,
                    });
                }
            }
        }
        Ok(())
    }

    /// Largest magnitude the payload can fold into any coordinate:
    /// max |value| for dense/sparse, |norm| for quantized (a code word
    /// reconstructs as ±norm·level/levels, bounded by the norm). The
    /// round machine's integrity check uses this to quarantine
    /// corrupted-but-decodable frames whose garbage magnitudes would
    /// overflow the fixed-point aggregation ring.
    pub fn max_abs(&self) -> f32 {
        match self {
            Payload::Dense(v) => {
                v.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
            }
            Payload::SparseK { values, .. } => {
                values.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
            }
            Payload::Quantized { norm, .. } => norm.abs(),
        }
    }

    /// Reconstruct the dense decompressed-equivalent vector into a
    /// caller-owned buffer (every element is overwritten; stale scratch
    /// contents are fine). This is the *reference semantics* of every
    /// payload: the fold kernels are bit-exact to folding this vector.
    pub fn densify_into(&self, out: &mut [f32]) {
        match self {
            Payload::Dense(v) => {
                assert_eq!(out.len(), v.len(), "dense payload dim mismatch");
                out.copy_from_slice(v);
            }
            Payload::SparseK { indices, values } => {
                out.fill(0.0);
                let d = out.len();
                for (&i, &v) in indices.iter().zip(values) {
                    let i = i as usize;
                    assert!(i < d, "sparse index {i} out of dim {d}");
                    out[i] = v;
                }
            }
            Payload::Quantized { dim, norm, levels, packed } => {
                assert_eq!(
                    out.len(),
                    *dim as usize,
                    "quantized payload dim mismatch"
                );
                let bits = kernels::qsgd_bits_per_coord(*levels);
                let s = (*levels).max(1) as f32;
                for (j, o) in out.iter_mut().enumerate() {
                    let w = kernels::unpack_bits(packed, j, bits);
                    *o = kernels::qsgd_value(
                        w & 1 == 1,
                        (w >> 1) as u32,
                        *norm,
                        s,
                    );
                }
            }
        }
    }

    /// Allocating [`Payload::densify_into`].
    pub fn densify(&self, dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        self.densify_into(&mut out);
        out
    }
}

/// Little-endian frame reader with truncation errors.
struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl Reader<'_> {
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take<const N: usize>(&mut self) -> Result<[u8; N], DecodeError> {
        let end = self.i + N;
        if end > self.b.len() {
            return Err(DecodeError::Truncated { at: self.i, need: N });
        }
        let mut out = [0u8; N];
        out.copy_from_slice(&self.b[self.i..end]);
        self.i = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take::<1>()?[0])
    }

    fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take::<4>()?))
    }

    fn finite_f32(
        &mut self,
        field: &'static str,
    ) -> Result<f32, DecodeError> {
        let x = f32::from_le_bytes(self.take::<4>()?);
        if !x.is_finite() {
            return Err(DecodeError::NonFinite { field });
        }
        Ok(x)
    }

    fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take::<8>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;
    use crate::util::rng::Rng;

    /// One random payload of a random kind (indices ascending, levels
    /// bounded, packed words sized to the codec).
    fn random_payload(rng: &mut Rng) -> (Payload, usize) {
        let d = rng.range(1, 200);
        match rng.below(3) {
            0 => {
                let v: Vec<f32> =
                    (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                (Payload::Dense(v), d)
            }
            1 => {
                let k = rng.range(1, d + 1);
                let mut idx = rng.choose_k(d, k);
                idx.sort_unstable();
                (
                    Payload::SparseK {
                        indices: idx.iter().map(|&i| i as u32).collect(),
                        values: (0..k)
                            .map(|_| rng.normal_f32(0.0, 2.0))
                            .collect(),
                    },
                    d,
                )
            }
            _ => {
                let levels = rng.range(1, 40) as u32;
                let bits = kernels::qsgd_bits_per_coord(levels);
                let words = kernels::qsgd_packed_words(d, levels);
                let mut packed = vec![0u64; words];
                for j in 0..d {
                    let level = rng.below(u64::from(levels) + 1);
                    let word = (level << 1) | rng.below(2);
                    kernels::pack_bits(&mut packed, j, bits, word);
                }
                (
                    Payload::Quantized {
                        dim: d as u32,
                        norm: rng.normal_f32(1.0, 0.5).abs(),
                        levels,
                        packed,
                    },
                    d,
                )
            }
        }
    }

    #[test]
    fn prop_round_trip_is_byte_exact() {
        // decode(encode(p)) == p and wire_bytes() == encoded.len() for
        // all three kinds across random dims/k/levels
        quick("wire-round-trip", |rng, _| {
            let (p, _) = random_payload(rng);
            let mut frame = Vec::new();
            p.encode_into(&mut frame);
            if frame.len() != p.wire_bytes() {
                return Err(format!(
                    "wire_bytes {} != encoded {}",
                    p.wire_bytes(),
                    frame.len()
                ));
            }
            let q = Payload::decode(&frame)?;
            if q != p {
                return Err("decode(encode(p)) != p".into());
            }
            // re-encoding the decoded payload reproduces the same bytes
            let mut frame2 = Vec::new();
            q.encode_into(&mut frame2);
            if frame2 != frame {
                return Err("re-encode differs".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_truncation_and_trailing_bytes_are_errors() {
        quick("wire-truncation", |rng, _| {
            let (p, _) = random_payload(rng);
            let mut frame = Vec::new();
            p.encode_into(&mut frame);
            let cut = rng.range(0, frame.len());
            if Payload::decode(&frame[..cut]).is_ok() {
                return Err(format!("truncation at {cut} decoded"));
            }
            frame.push(0);
            if Payload::decode(&frame).is_ok() {
                return Err("trailing byte decoded".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_densify_matches_reference() {
        use crate::tensor::kernels::reference;
        quick("wire-densify", |rng, _| {
            let (p, d) = random_payload(rng);
            let got = p.densify(d);
            let want = match &p {
                Payload::Dense(v) => v.clone(),
                Payload::SparseK { indices, values } => {
                    reference::sparse_densify(d, indices, values)
                }
                Payload::Quantized { dim, norm, levels, packed } => {
                    reference::quantized_densify(
                        *dim as usize,
                        packed,
                        *norm,
                        *levels,
                    )
                }
            };
            // bitwise: densify is the reference semantics
            if got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits())
            {
                Ok(())
            } else {
                Err("densify diverged from reference".into())
            }
        });
    }

    #[test]
    fn special_float_bits_survive_the_frame() {
        // signed zero and denormal payloads must round-trip bit-for-bit
        // — the frame carries raw f32 bit patterns, not values
        let v = vec![0.0f32, -0.0, -1.5e-40, f32::MIN_POSITIVE];
        let p = Payload::Dense(v.clone());
        let mut frame = Vec::new();
        p.encode_into(&mut frame);
        match Payload::decode(&frame).unwrap() {
            Payload::Dense(w) => {
                for (a, b) in v.iter().zip(&w) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong kind {}", other.kind()),
        }
    }

    #[test]
    fn non_finite_floats_are_rejected_at_decode() {
        // NaN/∞ anywhere in a frame would silently poison the fold —
        // the hardened decoder refuses them with a typed error
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut frame = Vec::new();
            Payload::Dense(vec![1.0, bad, 2.0]).encode_into(&mut frame);
            assert!(matches!(
                Payload::decode(&frame),
                Err(DecodeError::NonFinite { field: "dense value" })
            ));

            let mut frame = Vec::new();
            Payload::SparseK { indices: vec![2], values: vec![bad] }
                .encode_into(&mut frame);
            assert!(matches!(
                Payload::decode(&frame),
                Err(DecodeError::NonFinite { field: "sparse value" })
            ));

            let mut frame = Vec::new();
            Payload::Quantized {
                dim: 4,
                norm: bad,
                levels: 4,
                packed: vec![0; kernels::qsgd_packed_words(4, 4)],
            }
            .encode_into(&mut frame);
            assert!(matches!(
                Payload::decode(&frame),
                Err(DecodeError::NonFinite { field: "quantized norm" })
            ));
        }
    }

    #[test]
    fn validate_for_dim_catches_range_and_count_lies() {
        let d = 10usize;
        // honest payloads pass
        assert!(Payload::Dense(vec![0.0; d]).validate_for_dim(d).is_ok());
        let sp = Payload::SparseK { indices: vec![0, 9], values: vec![1.0, 2.0] };
        assert!(sp.validate_for_dim(d).is_ok());
        // out-of-range sparse index
        let bad = Payload::SparseK { indices: vec![0, 10], values: vec![1.0, 2.0] };
        assert_eq!(
            bad.validate_for_dim(d),
            Err(DecodeError::IndexOutOfRange { index: 10, dim: d })
        );
        // more sparse coordinates than the model has
        let fat = Payload::SparseK {
            indices: (0..11).collect(),
            values: vec![0.0; 11],
        };
        assert!(matches!(
            fat.validate_for_dim(d),
            Err(DecodeError::DimMismatch { got: 11, want: 10 })
        ));
        // dense / quantized dim mismatches
        assert!(Payload::Dense(vec![0.0; 9]).validate_for_dim(d).is_err());
        let q = Payload::Quantized {
            dim: 8,
            norm: 1.0,
            levels: 4,
            packed: vec![0; kernels::qsgd_packed_words(8, 4)],
        };
        assert!(q.validate_for_dim(d).is_err());
        assert!(q.validate_for_dim(8).is_ok());
    }

    #[test]
    fn prop_mutated_frames_never_panic_or_fold_garbage() {
        // seeded byte-mutation fuzz over all three variants: every
        // mutated frame either fails decode/validation (typed error) or
        // decodes to a payload that is safe to densify and all-finite
        use crate::faults::corrupt_frame;
        quick("wire-mutation", |rng, _| {
            let (p, d) = random_payload(rng);
            let mut frame = Vec::new();
            p.encode_into(&mut frame);
            let mut mrng = Rng::new(rng.next_u64());
            corrupt_frame(&mut frame, &mut mrng);
            let Ok(q) = Payload::decode(&frame) else {
                return Ok(()); // typed rejection is the common case
            };
            if q.validate_for_dim(d).is_err() {
                return Ok(()); // quarantine path
            }
            // survived integrity checks: folding must be total + finite
            let dense = q.densify(d);
            if dense.iter().any(|v| !v.is_finite()) {
                return Err("validated payload densified non-finite".into());
            }
            Ok(())
        });
    }

    #[test]
    fn unknown_tag_is_an_error() {
        assert!(Payload::decode(&[9, 0, 0, 0, 0]).is_err());
        assert!(Payload::decode(&[]).is_err());
    }

    #[test]
    fn unsorted_or_duplicate_sparse_indices_are_rejected() {
        // a duplicate index would double-count in the scatter fold while
        // the densified reference overwrites — decode must refuse it
        let mk = |indices: Vec<u32>| {
            let mut frame = vec![TAG_SPARSE];
            frame.extend_from_slice(&(indices.len() as u32).to_le_bytes());
            for i in &indices {
                frame.extend_from_slice(&i.to_le_bytes());
            }
            for _ in &indices {
                frame.extend_from_slice(&1.0f32.to_le_bytes());
            }
            frame
        };
        assert!(Payload::decode(&mk(vec![0, 2, 2])).is_err());
        assert!(Payload::decode(&mk(vec![3, 1])).is_err());
        assert!(Payload::decode(&mk(vec![0, 2, 5])).is_ok());
    }

    #[test]
    fn corrupt_length_prefix_errors_without_huge_allocation() {
        // frames claiming u32::MAX elements but carrying none must fail
        // with the truncation error (allocation is bounded by the input)
        assert!(Payload::decode(&[TAG_DENSE, 0xff, 0xff, 0xff, 0xff])
            .is_err());
        assert!(Payload::decode(&[TAG_SPARSE, 0xff, 0xff, 0xff, 0xff])
            .is_err());
        let mut quant = vec![TAG_QUANT];
        quant.extend_from_slice(&u32::MAX.to_le_bytes()); // dim
        quant.extend_from_slice(&1.0f32.to_le_bytes()); // norm
        quant.extend_from_slice(&4u32.to_le_bytes()); // levels
        assert!(Payload::decode(&quant).is_err());
    }

    #[test]
    fn wire_bytes_formulas() {
        assert_eq!(Payload::Dense(vec![0.0; 7]).wire_bytes(), 5 + 28);
        let p = Payload::SparseK {
            indices: vec![1, 5, 6],
            values: vec![1.0, 2.0, 3.0],
        };
        assert_eq!(p.wire_bytes(), 5 + 24);
        let q = Payload::Quantized {
            dim: 10,
            norm: 1.0,
            levels: 4,
            packed: vec![0; kernels::qsgd_packed_words(10, 4)],
        };
        // 10 coords × 4 bits = 40 bits → 1 word
        assert_eq!(q.wire_bytes(), 13 + 8);
    }
}
