//! Client-pool partitioning utilities: the paper's (s, a, b) unbalancing
//! procedure (footnote 6) and a Dirichlet label partitioner.

use super::ClientData;
use crate::util::rng::Rng;

/// The paper's unbalancing procedure (footnote 6):
///
/// > Let s ∈ (0,1) and a, b ∈ N₊ with a < b. For a given client with n_c
/// > examples, we keep this client unchanged if n_c ≤ a or n_c ≥ b,
/// > otherwise we remove this client from the dataset with probability s
/// > or only keep a randomly sampled examples in this client with
/// > probability 1 − s.
pub fn unbalance(
    clients: Vec<ClientData>,
    s: f64,
    a: usize,
    b: usize,
    rng: &mut Rng,
) -> Vec<ClientData> {
    assert!(a < b, "unbalance requires a < b");
    assert!((0.0..=1.0).contains(&s));
    let mut out = Vec::with_capacity(clients.len());
    for mut c in clients {
        let n = c.len();
        if n <= a || n >= b {
            out.push(c);
        } else if rng.bernoulli(s) {
            // removed from the pool
        } else {
            // keep a randomly sampled examples: shuffle-select then truncate
            subsample_in_place(&mut c, a, rng);
            out.push(c);
        }
    }
    out
}

/// Keep `keep` uniformly chosen examples of a client (in-place rebuild).
pub fn subsample_in_place(c: &mut ClientData, keep: usize, rng: &mut Rng) {
    let n = c.len();
    if keep >= n {
        return;
    }
    let chosen = rng.choose_k(n, keep);
    let dim = c.dim;
    let mut labels = Vec::with_capacity(keep);
    if c.is_tokens() {
        let mut xt = Vec::with_capacity(keep * dim);
        for &i in &chosen {
            xt.extend_from_slice(&c.x_tokens[i * dim..(i + 1) * dim]);
            labels.push(c.labels[i]);
        }
        c.x_tokens = xt;
    } else {
        let mut xd = Vec::with_capacity(keep * dim);
        for &i in &chosen {
            xd.extend_from_slice(&c.x_dense[i * dim..(i + 1) * dim]);
            labels.push(c.labels[i]);
        }
        c.x_dense = xd;
    }
    c.labels = labels;
}

/// Dirichlet(α) non-IID label partition: split a flat labelled corpus
/// into `num_clients` shards whose class mixtures are Dirichlet draws
/// (the standard federated-benchmark partitioner; complements the
/// generative palettes in synth_image/synth_text).
pub fn dirichlet_partition(
    labels: &[u32],
    num_classes: usize,
    num_clients: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(num_clients > 0);
    // index lists per class, shuffled
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &l) in labels.iter().enumerate() {
        per_class[l as usize].push(i);
    }
    for list in &mut per_class {
        rng.shuffle(list);
    }
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); num_clients];
    for list in per_class {
        if list.is_empty() {
            continue;
        }
        let props = rng.dirichlet(alpha, num_clients);
        // convert proportions to contiguous slice boundaries
        let n = list.len();
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (ci, p) in props.iter().enumerate() {
            acc += p;
            let end = if ci + 1 == num_clients {
                n
            } else {
                (acc * n as f64).round() as usize
            }
            .clamp(start, n);
            shards[ci].extend_from_slice(&list[start..end]);
            start = end;
        }
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;

    fn client(n: usize) -> ClientData {
        ClientData {
            x_dense: vec![0.5; n * 3],
            x_tokens: vec![],
            labels: vec![1; n],
            dim: 3,
        }
    }

    #[test]
    fn keeps_small_and_large_clients() {
        let mut rng = Rng::new(1);
        let out = unbalance(vec![client(5), client(500)], 0.9, 8, 100, &mut rng);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 5);
        assert_eq!(out[1].len(), 500);
    }

    #[test]
    fn middle_clients_removed_or_truncated() {
        let mut rng = Rng::new(2);
        let clients: Vec<ClientData> = (0..200).map(|_| client(50)).collect();
        let out = unbalance(clients, 0.5, 8, 100, &mut rng);
        assert!(out.len() < 200, "some removed");
        assert!(!out.is_empty(), "some kept");
        assert!(out.iter().all(|c| c.len() == 8), "kept ones truncated to a");
        // removal fraction ≈ s
        let frac = 1.0 - out.len() as f64 / 200.0;
        assert!((frac - 0.5).abs() < 0.15, "removal fraction {frac}");
    }

    #[test]
    fn subsample_preserves_rows() {
        let mut c = ClientData {
            x_dense: (0..20).map(|i| i as f32).collect(),
            x_tokens: vec![],
            labels: (0..10).collect(),
            dim: 2,
        };
        let mut rng = Rng::new(3);
        subsample_in_place(&mut c, 4, &mut rng);
        assert_eq!(c.len(), 4);
        // each kept row must be an original (feature, label) pair
        for i in 0..4 {
            let row = c.dense_row(i);
            let label = c.labels[i];
            assert_eq!(row[0], (label * 2) as f32);
            assert_eq!(row[1], (label * 2 + 1) as f32);
        }
    }

    #[test]
    fn dirichlet_partition_is_a_partition() {
        quick("dirichlet-partition", |rng, _| {
            let n = rng.range(10, 300);
            let classes = rng.range(2, 10);
            let clients = rng.range(1, 12);
            let labels: Vec<u32> =
                (0..n).map(|_| rng.below(classes as u64) as u32).collect();
            let shards =
                dirichlet_partition(&labels, classes, clients, 0.5, rng);
            let mut all: Vec<usize> = shards.concat();
            all.sort_unstable();
            let want: Vec<usize> = (0..n).collect();
            if all == want {
                Ok(())
            } else {
                Err(format!("lost/dup indices: {} vs {}", all.len(), n))
            }
        });
    }

    #[test]
    fn low_alpha_is_skewed() {
        let mut rng = Rng::new(5);
        let labels: Vec<u32> = (0..2000).map(|i| (i % 4) as u32).collect();
        let shards = dirichlet_partition(&labels, 4, 8, 0.05, &mut rng);
        // with α=0.05 most clients should be dominated by one class
        let mut dominated = 0;
        for shard in &shards {
            if shard.is_empty() {
                continue;
            }
            let mut counts = [0usize; 4];
            for &i in shard {
                counts[labels[i] as usize] += 1;
            }
            let maxc = *counts.iter().max().unwrap();
            if maxc as f64 / shard.len() as f64 > 0.6 {
                dominated += 1;
            }
        }
        assert!(dominated >= 4, "only {dominated} skewed shards");
    }
}
