//! Synthetic image classification datasets.
//!
//! *FEMNIST-like*: 28×28 grayscale, 62 classes. Each class has a smooth
//! procedural template (sum of random 2-D gaussian blobs); each client
//! has a "writer style" (contrast/brightness/jitter) and a non-IID class
//! palette, and a heavy-tailed example count. Variants 1–3 apply the
//! paper's (s, a, b) unbalancing procedure (footnote 6) with
//! progressively milder parameters.
//!
//! *CIFAR-like*: 32×32×3, 100 classes, every client the same size
//! (Appendix G's balanced setting).

use super::{partition, ClientData, FederatedData};
use crate::util::rng::Rng;

/// Smooth class template: mixture of `blobs` gaussian bumps on a side²
/// grid, normalized to [0, 1].
fn class_template(side: usize, channels: usize, rng: &mut Rng) -> Vec<f32> {
    let blobs = 4 + rng.range(0, 3);
    let mut img = vec![0.0f32; side * side * channels];
    for _ in 0..blobs {
        let cx = rng.f64() * side as f64;
        let cy = rng.f64() * side as f64;
        let sx = 1.5 + rng.f64() * (side as f64 / 4.0);
        let sy = 1.5 + rng.f64() * (side as f64 / 4.0);
        let amp = 0.4 + rng.f64() * 0.6;
        let ch = rng.range(0, channels);
        for y in 0..side {
            for x in 0..side {
                let dx = (x as f64 - cx) / sx;
                let dy = (y as f64 - cy) / sy;
                let v = amp * (-0.5 * (dx * dx + dy * dy)).exp();
                img[(y * side + x) * channels + ch] += v as f32;
            }
        }
    }
    let max = img.iter().cloned().fold(0.0f32, f32::max).max(1e-6);
    for v in &mut img {
        *v /= max;
    }
    img
}

/// Per-client writer style.
struct Style {
    contrast: f32,
    brightness: f32,
    noise: f32,
    shift_x: isize,
    shift_y: isize,
}

impl Style {
    fn sample(rng: &mut Rng) -> Style {
        Style {
            contrast: 0.7 + 0.6 * rng.f32(),
            brightness: -0.1 + 0.2 * rng.f32(),
            noise: 0.05 + 0.15 * rng.f32(),
            shift_x: rng.range(0, 5) as isize - 2,
            shift_y: rng.range(0, 5) as isize - 2,
        }
    }

    fn render(
        &self,
        template: &[f32],
        side: usize,
        channels: usize,
        rng: &mut Rng,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; template.len()];
        for y in 0..side {
            for x in 0..side {
                let sx = x as isize - self.shift_x;
                let sy = y as isize - self.shift_y;
                for c in 0..channels {
                    let base = if sx >= 0
                        && sy >= 0
                        && (sx as usize) < side
                        && (sy as usize) < side
                    {
                        template[(sy as usize * side + sx as usize) * channels + c]
                    } else {
                        0.0
                    };
                    let v = self.contrast * base
                        + self.brightness
                        + self.noise * rng.gaussian() as f32;
                    out[(y * side + x) * channels + c] = v.clamp(0.0, 1.0);
                }
            }
        }
        out
    }
}

fn generate_pool(
    pool: usize,
    side: usize,
    channels: usize,
    num_classes: usize,
    sizes: &[usize],
    class_concentration: f64,
    seed: u64,
) -> Vec<ClientData> {
    let root = Rng::new(seed);
    let mut trng = root.fork(0xC1A5);
    let templates: Vec<Vec<f32>> = (0..num_classes)
        .map(|_| class_template(side, channels, &mut trng))
        .collect();
    let dim = side * side * channels;

    (0..pool)
        .map(|cid| {
            let mut rng = root.fork(1000 + cid as u64);
            let style = Style::sample(&mut rng);
            // non-IID class palette: Dirichlet over classes
            let palette = rng.dirichlet(class_concentration, num_classes);
            let n = sizes[cid];
            let mut x_dense = Vec::with_capacity(n * dim);
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                let class = rng.categorical(&palette);
                x_dense.extend(style.render(
                    &templates[class],
                    side,
                    channels,
                    &mut rng,
                ));
                labels.push(class as u32);
            }
            ClientData { x_dense, x_tokens: vec![], labels, dim }
        })
        .collect()
}

fn validation_split(
    side: usize,
    channels: usize,
    num_classes: usize,
    examples: usize,
    seed: u64,
) -> ClientData {
    let root = Rng::new(seed);
    let mut trng = root.fork(0xC1A5);
    let templates: Vec<Vec<f32>> = (0..num_classes)
        .map(|_| class_template(side, channels, &mut trng))
        .collect();
    let mut rng = root.fork(0x7E57);
    let dim = side * side * channels;
    let mut x_dense = Vec::with_capacity(examples * dim);
    let mut labels = Vec::with_capacity(examples);
    for i in 0..examples {
        let class = i % num_classes;
        // mild canonical style + noise
        let mut img = templates[class].clone();
        for v in &mut img {
            *v = (*v + 0.08 * rng.gaussian() as f32).clamp(0.0, 1.0);
        }
        x_dense.extend(img);
        labels.push(class as u32);
    }
    ClientData { x_dense, x_tokens: vec![], labels, dim }
}

/// The paper's three FEMNIST modifications (Figure 2). Raw per-client
/// sizes are log-normal-ish (like real FEMNIST); the (s, a, b) procedure
/// of footnote 6 is then applied with progressively milder parameters.
pub fn unbalance_params(variant: u8) -> (f64, usize, usize) {
    match variant {
        1 => (0.55, 8, 230),  // most unbalanced: many 8-example clients
        2 => (0.50, 16, 180),
        3 => (0.45, 32, 140), // mildest
        _ => (0.0, 0, 0),     // variant 0: untouched
    }
}

/// FEMNIST-like dataset: `pool` clients, 62 classes, 28×28 grayscale.
pub fn femnist_like(
    pool: usize,
    variant: u8,
    val_examples: usize,
    seed: u64,
) -> FederatedData {
    let num_classes = 62;
    let side = 28;
    let mut rng = Rng::new(seed ^ 0xFE31157);
    // raw sizes: log-normal, median ≈ 110 examples (FEMNIST-like)
    let sizes: Vec<usize> = (0..pool)
        .map(|_| {
            let z = rng.gaussian();
            (110.0 * (0.6 * z).exp()).round().clamp(12.0, 400.0) as usize
        })
        .collect();
    let mut clients =
        generate_pool(pool, side, 1, num_classes, &sizes, 0.5, seed);
    let (s, a, b) = unbalance_params(variant);
    if variant >= 1 && variant <= 3 {
        clients = partition::unbalance(clients, s, a, b, &mut rng);
    }
    FederatedData {
        validation: validation_split(side, 1, num_classes, val_examples, seed),
        clients,
        num_classes,
        input_dim: side * side,
        is_tokens: false,
    }
}

/// CIFAR100-like balanced dataset: every client holds `per_client`
/// examples (Appendix G).
pub fn cifar_like(
    pool: usize,
    per_client: usize,
    val_examples: usize,
    seed: u64,
) -> FederatedData {
    let num_classes = 100;
    let side = 32;
    let channels = 3;
    let sizes = vec![per_client; pool];
    let clients = generate_pool(
        pool,
        side,
        channels,
        num_classes,
        &sizes,
        1.0,
        seed ^ 0xC1FA_0100,
    );
    FederatedData {
        validation: validation_split(
            side,
            channels,
            num_classes,
            val_examples,
            seed ^ 0xC1FA_0100,
        ),
        clients,
        num_classes,
        input_dim: side * side * channels,
        is_tokens: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn femnist_shapes_and_ranges() {
        let fd = femnist_like(12, 1, 62, 5);
        assert_eq!(fd.num_classes, 62);
        assert_eq!(fd.input_dim, 784);
        assert!(!fd.is_tokens);
        for c in &fd.clients {
            assert_eq!(c.dim, 784);
            assert_eq!(c.x_dense.len(), c.len() * 784);
            assert!(c.labels.iter().all(|&l| l < 62));
            assert!(c.x_dense.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = femnist_like(6, 1, 32, 9);
        let b = femnist_like(6, 1, 32, 9);
        assert_eq!(a.client_sizes(), b.client_sizes());
        assert_eq!(a.clients[0].x_dense, b.clients[0].x_dense);
        let c = femnist_like(6, 1, 32, 10);
        assert_ne!(a.clients[0].x_dense, c.clients[0].x_dense);
    }

    #[test]
    fn variants_increasingly_balanced() {
        // coefficient of variation of client sizes shrinks 1 → 3
        let cv = |v: u8| {
            let fd = femnist_like(120, v, 16, 3);
            let sizes: Vec<f64> =
                fd.client_sizes().iter().map(|&s| s as f64).collect();
            let m = sizes.iter().sum::<f64>() / sizes.len() as f64;
            let var = sizes.iter().map(|s| (s - m) * (s - m)).sum::<f64>()
                / sizes.len() as f64;
            var.sqrt() / m
        };
        let (c1, c3) = (cv(1), cv(3));
        assert!(c1 > c3, "cv1={c1} cv3={c3}");
    }

    #[test]
    fn unbalanced_variant_creates_small_clients() {
        let fd = femnist_like(100, 1, 16, 3);
        let (_, a, _) = unbalance_params(1);
        let small = fd.client_sizes().iter().filter(|&&s| s <= a).count();
        assert!(small > 0, "expected truncated {a}-example clients");
    }

    #[test]
    fn cifar_balanced() {
        let fd = cifar_like(10, 50, 100, 4);
        assert_eq!(fd.num_classes, 100);
        assert_eq!(fd.input_dim, 3072);
        assert!(fd.client_sizes().iter().all(|&s| s == 50));
    }

    #[test]
    fn validation_covers_classes() {
        let fd = femnist_like(4, 0, 124, 6);
        let mut seen = vec![false; 62];
        for &l in &fd.validation.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "validation misses classes");
    }

    #[test]
    fn templates_are_distinguishable() {
        // different classes must differ substantially or training is moot
        let fd = femnist_like(1, 0, 62, 8);
        let v = &fd.validation;
        let a = v.dense_row(0);
        let b = v.dense_row(1);
        let diff: f32 =
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>();
        assert!(diff > 10.0, "templates nearly identical: {diff}");
    }
}
