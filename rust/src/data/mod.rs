//! Synthetic federated datasets (the LEAF substitution — DESIGN.md §3).
//!
//! The sampling math only ever sees *update norms*, which are driven by
//! per-client example counts and data heterogeneity; these generators
//! reproduce exactly those properties of FEMNIST / Shakespeare / CIFAR100
//! while staying procedurally generated and fully deterministic.

pub mod partition;
pub mod synth_image;
pub mod synth_text;

use crate::config::DataSpec;
use crate::util::rng::Rng;

/// One client's local dataset. Dense features (images) and token
/// sequences (text) share the struct; exactly one of `x_dense`/`x_tokens`
/// is populated.
#[derive(Clone, Debug, Default)]
pub struct ClientData {
    /// row-major `len × dim` dense features
    pub x_dense: Vec<f32>,
    /// row-major `len × dim` token ids
    pub x_tokens: Vec<i32>,
    /// class labels, `len` entries
    pub labels: Vec<u32>,
    /// feature dimension (dense) or sequence length (tokens)
    pub dim: usize,
}

impl ClientData {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn is_tokens(&self) -> bool {
        !self.x_tokens.is_empty()
    }

    /// Dense feature row i.
    pub fn dense_row(&self, i: usize) -> &[f32] {
        &self.x_dense[i * self.dim..(i + 1) * self.dim]
    }

    /// Token row i.
    pub fn token_row(&self, i: usize) -> &[i32] {
        &self.x_tokens[i * self.dim..(i + 1) * self.dim]
    }

    /// Truncate to the first `keep` examples (the paper's unbalancing op).
    pub fn truncate(&mut self, keep: usize) {
        let keep = keep.min(self.len());
        self.labels.truncate(keep);
        if self.is_tokens() {
            self.x_tokens.truncate(keep * self.dim);
        } else {
            self.x_dense.truncate(keep * self.dim);
        }
    }

    /// Write a freshly shuffled epoch order into `idx`, reusing its
    /// allocation across epochs. Exactly the RNG draws the historical
    /// `epoch_batches` shuffle performed — the FedAvg inner loop walks
    /// this buffer in `batch`-sized windows (see `sim::NativeEngine`)
    /// without materializing per-batch vectors.
    pub fn epoch_order_into(&self, idx: &mut Vec<usize>, rng: &mut Rng) {
        idx.clear();
        idx.extend(0..self.len());
        rng.shuffle(idx);
    }

    /// Shuffled epoch batches of `batch` indices; a final partial batch
    /// wraps around (sampling with replacement for the tail), matching
    /// the fixed-batch AOT entry points.
    pub fn epoch_batches(&self, batch: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        assert!(batch > 0);
        if self.is_empty() {
            return Vec::new();
        }
        let mut idx: Vec<usize> = Vec::new();
        self.epoch_order_into(&mut idx, rng);
        let mut out = Vec::new();
        let mut i = 0;
        while i < idx.len() {
            let mut b: Vec<usize> = idx[i..(i + batch).min(idx.len())].to_vec();
            while b.len() < batch {
                b.push(idx[rng.range(0, idx.len())]);
            }
            out.push(b);
            i += batch;
        }
        out
    }
}

/// A federated dataset: client pool + held-out validation split.
#[derive(Clone, Debug)]
pub struct FederatedData {
    pub clients: Vec<ClientData>,
    pub validation: ClientData,
    pub num_classes: usize,
    pub input_dim: usize,
    /// sequence data (GRU models) vs dense data (MLP/CNN models)
    pub is_tokens: bool,
}

impl FederatedData {
    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    pub fn client_sizes(&self) -> Vec<usize> {
        self.clients.iter().map(ClientData::len).collect()
    }

    pub fn total_examples(&self) -> usize {
        self.client_sizes().iter().sum()
    }
}

/// Build the dataset described by a [`DataSpec`] (deterministic in seed).
pub fn build(spec: &DataSpec, val_examples: usize, seed: u64) -> FederatedData {
    match spec {
        DataSpec::FemnistLike { pool, variant } => {
            synth_image::femnist_like(*pool, *variant, val_examples, seed)
        }
        DataSpec::ShakespeareLike { pool } => {
            synth_text::shakespeare_like(*pool, val_examples, seed)
        }
        DataSpec::CifarLike { pool, per_client } => {
            synth_image::cifar_like(*pool, *per_client, val_examples, seed)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_client(n: usize, dim: usize) -> ClientData {
        ClientData {
            x_dense: (0..n * dim).map(|i| i as f32).collect(),
            x_tokens: vec![],
            labels: (0..n as u32).collect(),
            dim,
        }
    }

    #[test]
    fn rows_are_views() {
        let c = dense_client(3, 4);
        assert_eq!(c.dense_row(1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn truncate_consistent() {
        let mut c = dense_client(5, 2);
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.x_dense.len(), 4);
        c.truncate(10); // no-op beyond length
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn epoch_batches_cover_all_and_pad() {
        let c = dense_client(7, 1);
        let mut rng = Rng::new(3);
        let batches = c.epoch_batches(3, &mut rng);
        assert_eq!(batches.len(), 3); // ceil(7/3)
        assert!(batches.iter().all(|b| b.len() == 3));
        let mut seen: Vec<usize> = batches.concat();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn epoch_order_walk_replays_epoch_batches_stream() {
        // the streaming FedAvg walk (epoch_order_into + window + pad
        // draws) must consume the identical RNG sequence epoch_batches
        // did — this is what keeps the kernelized sim on the seed
        // trajectory
        let c = dense_client(7, 1);
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let batches = c.epoch_batches(3, &mut r1);
        let mut idx = Vec::new();
        c.epoch_order_into(&mut idx, &mut r2);
        let flat: Vec<usize> = batches.concat();
        assert_eq!(&flat[..7], &idx[..]);
        // the tail pads continue from the same stream state
        let pads: Vec<usize> =
            (0..2).map(|_| idx[r2.range(0, idx.len())]).collect();
        assert_eq!(&flat[7..], &pads[..]);
    }

    #[test]
    fn empty_client_no_batches() {
        let c = ClientData::default();
        let mut rng = Rng::new(1);
        assert!(c.epoch_batches(4, &mut rng).is_empty());
    }

    #[test]
    fn build_dispatches_all_specs() {
        for spec in [
            DataSpec::FemnistLike { pool: 20, variant: 1 },
            DataSpec::ShakespeareLike { pool: 10 },
            DataSpec::CifarLike { pool: 8, per_client: 16 },
        ] {
            let fd = build(&spec, 64, 7);
            assert!(fd.num_clients() > 0, "{spec:?}");
            assert!(fd.validation.len() >= 32, "{spec:?}");
            assert!(fd.total_examples() > 0);
        }
    }
}
