//! Synthetic character-sequence dataset (the Shakespeare substitution).
//!
//! A shared order-2 Markov chain over an 86-symbol vocabulary plays the
//! role of "the English language"; each client (≈ a speaking role in the
//! plays) samples text from the chain with a private style perturbation
//! (temperature + preferred-symbol bias), and holds a heavy-tailed number
//! of characters — reproducing the per-client sequence-count and
//! distribution-shift heterogeneity that drives the paper's update-norm
//! profiles. Examples are (5-char window → next char), batch 8 (§5.3).

use super::{ClientData, FederatedData};
use crate::util::rng::Rng;

pub const VOCAB: usize = 86;
pub const SEQ_LEN: usize = 5;

/// Sparse-ish order-2 transition model: for each context (a, b) a small
/// set of likely successors. Stored dense (86² × 86 f32 ≈ 2.5 MB).
pub struct MarkovChain {
    probs: Vec<f32>, // [a * VOCAB + b][c]
}

impl MarkovChain {
    /// Build a *structured* order-2 chain: the successor is mostly a
    /// context-shifted offset, `c = (b + offset + (a mod 3)) mod V`,
    /// with a shared offset palette across all contexts. Unlike an
    /// iid-random transition table (7396 independent rows, pure
    /// memorization), this is a compositional rule a small GRU — or a
    /// positional-one-hot logistic — actually *generalizes*; the
    /// offset-weight entropy (~2.1 bits) caps top-1 accuracy near 0.45.
    pub fn generate(seed: u64) -> MarkovChain {
        let mut rng = Rng::new(seed ^ 0x5EA5_0000);
        // shared offset palette (deterministic in seed)
        let mut offsets = [0usize; 6];
        let weights = [0.42f32, 0.22, 0.14, 0.09, 0.05, 0.03];
        let mut used = std::collections::BTreeSet::new();
        for o in offsets.iter_mut() {
            loop {
                let cand = 1 + rng.range(0, VOCAB - 1);
                if used.insert(cand) {
                    *o = cand;
                    break;
                }
            }
        }
        let contexts = VOCAB * VOCAB;
        let mut probs = vec![0.0f32; contexts * VOCAB];
        for a in 0..VOCAB {
            for b in 0..VOCAB {
                let row_start = (a * VOCAB + b) * VOCAB;
                let row = &mut probs[row_start..row_start + VOCAB];
                for (o, w) in offsets.iter().zip(weights) {
                    row[(b + o + (a % 3)) % VOCAB] += w;
                }
                // smoothing mass so every char is possible
                for v in row.iter_mut() {
                    *v += 0.05 / VOCAB as f32;
                }
                let total: f32 = row.iter().sum();
                for v in row.iter_mut() {
                    *v /= total;
                }
            }
        }
        MarkovChain { probs }
    }

    fn row(&self, a: usize, b: usize) -> &[f32] {
        let ctx = a * VOCAB + b;
        &self.probs[ctx * VOCAB..(ctx + 1) * VOCAB]
    }

    /// Sample `len` characters with a per-client style: logits are scaled
    /// by 1/temperature and biased toward the client's preferred symbols.
    pub fn sample_text(
        &self,
        len: usize,
        temperature: f64,
        bias: &[f32],
        rng: &mut Rng,
    ) -> Vec<i32> {
        assert_eq!(bias.len(), VOCAB);
        let mut out = Vec::with_capacity(len);
        let (mut a, mut b) = (rng.range(0, VOCAB), rng.range(0, VOCAB));
        let inv_t = 1.0 / temperature.max(0.05);
        let mut weights = vec![0.0f64; VOCAB];
        for _ in 0..len {
            let row = self.row(a, b);
            for (w, (&p, &bi)) in
                weights.iter_mut().zip(row.iter().zip(bias)) {
                *w = ((p as f64).max(1e-9).ln() * inv_t + bi as f64).exp();
            }
            let c = rng.categorical(&weights);
            out.push(c as i32);
            a = b;
            b = c;
        }
        out
    }
}

/// Slide a window over text: (tokens[i..i+SEQ_LEN] → tokens[i+SEQ_LEN]).
pub fn windows(text: &[i32]) -> (Vec<i32>, Vec<u32>) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    if text.len() <= SEQ_LEN {
        return (xs, ys);
    }
    for i in 0..text.len() - SEQ_LEN {
        xs.extend_from_slice(&text[i..i + SEQ_LEN]);
        ys.push(text[i + SEQ_LEN] as u32);
    }
    (xs, ys)
}

/// Shakespeare-like federated dataset: `pool` clients (paper: 715 roles).
pub fn shakespeare_like(
    pool: usize,
    val_examples: usize,
    seed: u64,
) -> FederatedData {
    let chain = MarkovChain::generate(seed);
    let root = Rng::new(seed ^ 0x5834_83);

    let clients: Vec<ClientData> = (0..pool)
        .map(|cid| {
            let mut rng = root.fork(cid as u64);
            // role sizes: log-normal — a few protagonists, many bit parts
            let z = rng.gaussian();
            let chars =
                (160.0 * (1.0 * z).exp()).round().clamp(20.0, 4000.0) as usize;
            let temperature = 0.8 + 0.4 * rng.f64();
            let bias: Vec<f32> =
                (0..VOCAB).map(|_| 0.3 * rng.gaussian() as f32).collect();
            let text = chain.sample_text(chars, temperature, &bias, &mut rng);
            let (x_tokens, labels) = windows(&text);
            ClientData { x_dense: vec![], x_tokens, labels, dim: SEQ_LEN }
        })
        .filter(|c| !c.is_empty())
        .collect();

    // validation: neutral style straight from the chain
    let mut vrng = root.fork(0xFFFF_FFFF);
    let neutral_bias = vec![0.0f32; VOCAB];
    let vtext = chain.sample_text(
        val_examples + SEQ_LEN,
        1.0,
        &neutral_bias,
        &mut vrng,
    );
    let (vx, vy) = windows(&vtext);
    let validation =
        ClientData { x_dense: vec![], x_tokens: vx, labels: vy, dim: SEQ_LEN };

    FederatedData {
        clients,
        validation,
        num_classes: VOCAB,
        input_dim: SEQ_LEN,
        is_tokens: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_shapes() {
        let text: Vec<i32> = (0..10).collect();
        let (xs, ys) = windows(&text);
        assert_eq!(ys.len(), 5);
        assert_eq!(xs.len(), 5 * SEQ_LEN);
        assert_eq!(&xs[0..5], &[0, 1, 2, 3, 4]);
        assert_eq!(ys[0], 5);
    }

    #[test]
    fn windows_short_text_empty() {
        let (xs, ys) = windows(&[1, 2, 3]);
        assert!(xs.is_empty() && ys.is_empty());
    }

    #[test]
    fn dataset_shapes_and_vocab() {
        let fd = shakespeare_like(20, 128, 11);
        assert!(fd.is_tokens);
        assert_eq!(fd.num_classes, VOCAB);
        assert_eq!(fd.input_dim, SEQ_LEN);
        for c in &fd.clients {
            assert_eq!(c.dim, SEQ_LEN);
            assert_eq!(c.x_tokens.len(), c.len() * SEQ_LEN);
            assert!(c.x_tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
            assert!(c.labels.iter().all(|&l| l < VOCAB as u32));
        }
        assert!(fd.validation.len() >= 128);
    }

    #[test]
    fn client_sizes_heterogeneous() {
        let fd = shakespeare_like(120, 32, 13);
        let sizes = fd.client_sizes();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max > 8 * min.max(1), "sizes too uniform: {min}..{max}");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = shakespeare_like(5, 32, 17);
        let b = shakespeare_like(5, 32, 17);
        assert_eq!(a.clients[0].x_tokens, b.clients[0].x_tokens);
    }

    #[test]
    fn chain_is_learnable_structure() {
        // next-char entropy must be well below uniform (log2 86 ≈ 6.4):
        // a model can actually learn something
        let chain = MarkovChain::generate(3);
        let mut rng = Rng::new(4);
        let bias = vec![0.0f32; VOCAB];
        let text = chain.sample_text(5000, 1.0, &bias, &mut rng);
        // empirical conditional entropy via the true chain rows
        let mut h = 0.0f64;
        let mut count = 0;
        for w in text.windows(3) {
            let row = chain.row(w[0] as usize, w[1] as usize);
            let p = row[w[2] as usize] as f64;
            h -= p.max(1e-9).ln() / std::f64::consts::LN_2;
            count += 1;
        }
        let bits = h / count as f64;
        assert!(bits < 5.0, "conditional entropy too high: {bits}");
    }
}
