//! The sharded round coordinator — the paper's L3 master/client protocol
//! (norm collection, optimal-probability negotiation, secure aggregation)
//! as an explicit, scalable subsystem.
//!
//! Structure:
//!
//! * [`registry`] — sharded client registry (round-robin ownership,
//!   cohort partitioning);
//! * [`round`] — the round state machine
//!   `Announce → LocalCompute → NormReport → Negotiate → SecureAggregate
//!   → Repair → Commit`, one phase per method, seed-trajectory-faithful
//!   (Repair is the chaos layer's recovery phase — a pass-through decode
//!   when no faults fire);
//! * [`shard`] — execution backends: [`EngineRunner`] adapts any legacy
//!   [`ClientEngine`], [`ParallelRunner`] fans shard cohorts — and the
//!   secure-aggregation masked folds — over a persistent
//!   worker-thread pool;
//! * [`aggregate`] — per-shard partial aggregation with a deterministic
//!   tree combine (the combine stage reduces O(shards) partials instead
//!   of folding O(clients) vectors — the seam a streaming master
//!   plugs into).
//!
//! `fl::train` is now a thin adapter over a single-shard [`Coordinator`]
//! — the sim and XLA paths both run through this subsystem — and the
//! single-shard trajectory is bit-identical to the historical sequential
//! loop. Under `secure_updates` the multi-shard trajectory is *also*
//! bit-identical (fixed-point ring sums commute); the plain-f32 path may
//! differ in the last ulp across shard counts.
//!
//! Deadline/straggler handling sits on top of `fl::availability`: a
//! shard that misses the round deadline contributes nothing that round.
//! AOCS tolerates this because the negotiation only consumes aggregates
//! of thresholded norms from whoever reported in time.
//!
//! The scenario engine (DESIGN.md §8) rides the same seams: cohort
//! selection is the **streaming** O(cohort)-memory draw of
//! `fl::availability` (bitwise identical to the seed dense draw), the
//! availability model may be a time-varying trace — diurnal schedules,
//! session churn and correlated shard outages compose with the deadline
//! drops above — and [`CoordinatorOptions::sharded_negotiation`] moves
//! the AOCS probability negotiation onto per-shard secure partial sums
//! over the same worker pool.
//!
//! ```
//! use fedsamp::coordinator::Registry;
//! let r = Registry::new(1_000_000, 64); // O(1) state at any pool size
//! assert_eq!(r.shard_of(7), 7 % 64);
//! let part = r.split_cohort(&[7, 2, 999_999]);
//! assert_eq!(part.clients.iter().map(Vec::len).sum::<usize>(), 3);
//! ```
//!
//! [`ClientEngine`]: crate::fl::ClientEngine

pub mod aggregate;
pub mod registry;
pub mod round;
pub mod shard;

pub use registry::{CohortPartition, Registry};
pub use round::{Phase, RoundMachine};
pub use shard::{ClientCompute, EngineRunner, LocalRunner, ParallelRunner};

use crate::config::{Algorithm, ExperimentConfig};
use crate::faults::{FaultCounters, FaultCtx};
use crate::fl::availability::Availability;
use crate::fl::comm::BitMeter;
use crate::fl::TrainOptions;
use crate::metrics::RunResult;
use crate::sampling::Sampler;
use crate::telemetry::Telemetry;
use crate::util::rng::Rng;

/// Straggler model: each shard independently misses the round deadline
/// with probability `miss_prob` (drawn from a dedicated seed stream, so
/// enabling it never perturbs cohort/selection RNG).
#[derive(Clone, Debug)]
pub struct DeadlinePolicy {
    pub miss_prob: f64,
}

/// How the coordinator is sharded. Worker-thread provisioning lives
/// with the execution backend (the `workers` argument of
/// [`ParallelRunner::new`]) — the coordinator itself is agnostic to how
/// a runner parallelizes.
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// Client-registry shards (clamped to the pool size).
    pub shards: usize,
    /// Optional per-round shard deadline model.
    pub deadline: Option<DeadlinePolicy>,
    /// Run the AOCS probability negotiation per shard with secure
    /// partial sums over the runner's worker pool (Algorithm 2's
    /// aggregates arrive as O(shards) masked scalars instead of a
    /// central scan — see [`RoundMachine::negotiate`]). Off by default:
    /// the partial sums travel as f32 through the fixed-point ring, so
    /// trajectories match the central negotiation's fixed point but not
    /// its last ulps.
    pub sharded_negotiation: bool,
}

impl Default for CoordinatorOptions {
    fn default() -> CoordinatorOptions {
        CoordinatorOptions {
            shards: 1,
            deadline: None,
            sharded_negotiation: false,
        }
    }
}

impl CoordinatorOptions {
    /// The configuration `fl::train` uses: one shard — trajectory-
    /// identical to the seed sequential loop.
    pub fn single_shard() -> CoordinatorOptions {
        CoordinatorOptions::default()
    }
}

/// Aggregate observability counters for one coordinator run.
#[derive(Clone, Debug, Default)]
pub struct CoordStats {
    /// Shard-rounds lost to missed deadlines.
    pub shards_dropped: usize,
    /// Shard-rounds lost to correlated availability-trace outages
    /// (removed before cohort selection, unlike deadline drops).
    pub shards_outaged: usize,
    /// Rounds that ended with an empty cohort (no-op rounds).
    pub noop_rounds: usize,
    /// Rounds the coordinator actually drove (no-op rounds included).
    pub rounds_run: usize,
    /// Chaos-layer tally: faults injected and repairs performed. All
    /// zero unless the config carries a non-zero
    /// [`crate::faults::FaultPlan`].
    pub faults: FaultCounters,
}

/// The master-side driver: owns the shard registry and round loop and
/// walks the [`RoundMachine`] through its phases each round.
pub struct Coordinator {
    pub opts: CoordinatorOptions,
    pub stats: CoordStats,
}

impl Coordinator {
    pub fn new(opts: CoordinatorOptions) -> Coordinator {
        Coordinator { opts, stats: CoordStats::default() }
    }

    /// Run a full federated experiment over `runner`.
    pub fn run(
        &mut self,
        cfg: &ExperimentConfig,
        runner: &mut dyn LocalRunner,
        opts: &TrainOptions,
    ) -> Result<RunResult, String> {
        cfg.validate()?;
        // the config-level compressor is the default; an explicit
        // TrainOptions compressor (the ablation hook) wins —
        // Some(Compressor::None) is the "explicitly uncompressed" state,
        // only a None option inherits. After precedence, the resolved
        // Compressor::None normalizes to no compressor: identical
        // semantics (no RNG draws, same dense payload and metered
        // bytes), but the upload path then *moves* each delta instead
        // of cloning it through `compress`
        let mut opts = opts.clone();
        if opts.compressor.is_none() {
            opts.compressor = cfg.compressor.clone();
        }
        if opts.compressor == Some(crate::compress::Compressor::None) {
            opts.compressor = None;
        }
        let opts = &opts;
        let sampler = Sampler::from_strategy(&cfg.strategy);
        let pool = runner.num_clients();
        if pool == 0 {
            return Err("empty client pool".into());
        }
        let avail = match &cfg.availability_trace {
            Some(t) => Availability::Trace(t.clone()),
            None => Availability::from_probability(cfg.availability),
        };
        let eta_g = match cfg.algorithm {
            Algorithm::FedAvg { eta_g, .. } => eta_g,
            // DSGD folds its step size into the master update (Eq. 2)
            Algorithm::Dsgd { eta } => eta,
        };
        let registry = Registry::new(pool, self.opts.shards);

        let rng = Rng::new(cfg.seed).fork(0xF1);
        let mut x = runner.init_params(cfg.seed);
        let mut meter = BitMeter::new();
        let mut result = RunResult::new(&cfg.name, sampler.name());

        // Telemetry sits entirely outside the protocol: it never reads
        // an RNG stream, so trajectories are bit-identical with it on or
        // off. A disabled recorder records nothing and installs no clock.
        let mut tel = Telemetry::from_config(&opts.telemetry)?;
        if tel.enabled() {
            runner.set_clock(Some(tel.clock()));
        }

        // the chaos context exists only when a plan can actually fire —
        // a zero-rate (or absent) plan stays on the bitwise fault-free
        // path (see `faults::FaultCtx::from_plan`)
        let mut faults = FaultCtx::from_plan(cfg.fault_plan.as_ref());

        for round in 0..cfg.rounds {
            self.stats.rounds_run += 1;
            let mut round_rng = rng.fork(round as u64);
            let mut machine = RoundMachine::new(round);
            self.stats.shards_dropped += machine.announce(
                cfg,
                &avail,
                &registry,
                self.opts.deadline.as_ref(),
                &mut round_rng,
                &mut tel,
            );
            self.stats.shards_outaged += machine.outaged_shards();
            if machine.cohort().is_empty() {
                self.stats.noop_rounds += 1;
                result.push(round::noop_record(round, &meter));
                tel.flush_round(round);
                continue;
            }
            machine.local_compute(runner, &x, &mut tel);
            machine.norm_report(&mut tel);
            machine.negotiate(
                &sampler,
                cfg,
                if self.opts.sharded_negotiation {
                    Some(&mut *runner)
                } else {
                    None
                },
                faults.as_mut(),
                &mut meter,
                &mut round_rng,
                &mut tel,
            );
            machine.secure_aggregate(
                cfg,
                opts,
                &registry,
                runner,
                faults.as_mut(),
                &mut meter,
                &mut round_rng,
                &mut tel,
            );
            machine.repair(cfg, faults.as_mut(), &mut tel);
            result.push(machine.commit(
                cfg,
                opts,
                eta_g,
                &mut x,
                runner,
                &meter,
                &mut tel,
            )?);
        }
        if tel.enabled() {
            runner.set_clock(None);
        }
        if let Some(ctx) = &faults {
            self.stats.faults = ctx.counters;
        }
        result.telemetry = tel.finish();
        Ok(result)
    }
}
