//! The sharded round coordinator — the paper's L3 master/client protocol
//! (norm collection, optimal-probability negotiation, secure aggregation)
//! as an explicit, scalable subsystem.
//!
//! Structure:
//!
//! * [`registry`] — sharded client registry (round-robin ownership,
//!   cohort partitioning);
//! * [`round`] — the round state machine
//!   `Announce → LocalCompute → NormReport → Negotiate → SecureAggregate
//!   → Repair → Commit`, one phase per method, seed-trajectory-faithful
//!   (Repair is the chaos layer's recovery phase — a pass-through decode
//!   when no faults fire);
//! * [`shard`] — execution backends: [`EngineRunner`] adapts any legacy
//!   [`ClientEngine`], [`ParallelRunner`] fans shard cohorts — and the
//!   secure-aggregation masked folds — over a persistent
//!   worker-thread pool;
//! * [`aggregate`] — per-shard partial aggregation with a deterministic
//!   tree combine (the combine stage reduces O(shards) partials instead
//!   of folding O(clients) vectors — the seam a streaming master
//!   plugs into).
//!
//! `fl::train` is now a thin adapter over a single-shard [`Coordinator`]
//! — the sim and XLA paths both run through this subsystem — and the
//! single-shard trajectory is bit-identical to the historical sequential
//! loop. Under `secure_updates` the multi-shard trajectory is *also*
//! bit-identical (fixed-point ring sums commute); the plain-f32 path may
//! differ in the last ulp across shard counts.
//!
//! Deadline/straggler handling sits on top of `fl::availability`: a
//! shard that misses the round deadline contributes nothing that round.
//! AOCS tolerates this because the negotiation only consumes aggregates
//! of thresholded norms from whoever reported in time.
//!
//! The scenario engine (DESIGN.md §8) rides the same seams: cohort
//! selection is the **streaming** O(cohort)-memory draw of
//! `fl::availability` (bitwise identical to the seed dense draw), the
//! availability model may be a time-varying trace — diurnal schedules,
//! session churn and correlated shard outages compose with the deadline
//! drops above — and [`CoordinatorOptions::sharded_negotiation`] moves
//! the AOCS probability negotiation onto per-shard secure partial sums
//! over the same worker pool.
//!
//! ```
//! use fedsamp::coordinator::Registry;
//! let r = Registry::new(1_000_000, 64); // O(1) state at any pool size
//! assert_eq!(r.shard_of(7), 7 % 64);
//! let part = r.split_cohort(&[7, 2, 999_999]);
//! assert_eq!(part.clients.iter().map(Vec::len).sum::<usize>(), 3);
//! ```
//!
//! [`ClientEngine`]: crate::fl::ClientEngine

pub mod aggregate;
pub mod registry;
pub mod round;
pub mod shard;

pub use registry::{round_robin_slot, CohortPartition, Registry};
pub use round::{Phase, RoundMachine};
pub use shard::{ClientCompute, EngineRunner, LocalRunner, ParallelRunner};

use crate::checkpoint::{self, CheckpointError, CheckpointOptions, Snapshot};
use crate::config::{Algorithm, ExperimentConfig};
use crate::faults::{FaultCounters, FaultCtx, MASTERKILL_ERR_PREFIX};
use crate::fl::availability::Availability;
use crate::fl::comm::BitMeter;
use crate::fl::TrainOptions;
use crate::metrics::RunResult;
use crate::sampling::Sampler;
use crate::telemetry::{PhaseSpan, Telemetry};
use crate::util::rng::Rng;

/// Straggler model: each shard independently misses the round deadline
/// with probability `miss_prob` (drawn from a dedicated seed stream, so
/// enabling it never perturbs cohort/selection RNG).
#[derive(Clone, Debug)]
pub struct DeadlinePolicy {
    pub miss_prob: f64,
}

/// How the coordinator is sharded. Worker-thread provisioning lives
/// with the execution backend (the `workers` argument of
/// [`ParallelRunner::new`]) — the coordinator itself is agnostic to how
/// a runner parallelizes.
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// Client-registry shards (clamped to the pool size).
    pub shards: usize,
    /// Optional per-round shard deadline model.
    pub deadline: Option<DeadlinePolicy>,
    /// Run the AOCS probability negotiation per shard with secure
    /// partial sums over the runner's worker pool (Algorithm 2's
    /// aggregates arrive as O(shards) masked scalars instead of a
    /// central scan — see [`RoundMachine::negotiate`]). Off by default:
    /// the partial sums travel as f32 through the fixed-point ring, so
    /// trajectories match the central negotiation's fixed point but not
    /// its last ulps.
    pub sharded_negotiation: bool,
}

impl Default for CoordinatorOptions {
    fn default() -> CoordinatorOptions {
        CoordinatorOptions {
            shards: 1,
            deadline: None,
            sharded_negotiation: false,
        }
    }
}

impl CoordinatorOptions {
    /// The configuration `fl::train` uses: one shard — trajectory-
    /// identical to the seed sequential loop.
    pub fn single_shard() -> CoordinatorOptions {
        CoordinatorOptions::default()
    }
}

/// Aggregate observability counters for one coordinator run.
#[derive(Clone, Debug, Default)]
pub struct CoordStats {
    /// Shard-rounds lost to missed deadlines.
    pub shards_dropped: usize,
    /// Shard-rounds lost to correlated availability-trace outages
    /// (removed before cohort selection, unlike deadline drops).
    pub shards_outaged: usize,
    /// Rounds that ended with an empty cohort (no-op rounds).
    pub noop_rounds: usize,
    /// Rounds the coordinator actually drove (no-op rounds included).
    pub rounds_run: usize,
    /// Chaos-layer tally: faults injected and repairs performed. All
    /// zero unless the config carries a non-zero
    /// [`crate::faults::FaultPlan`].
    pub faults: FaultCounters,
}

/// The master-side driver: owns the shard registry and round loop and
/// walks the [`RoundMachine`] through its phases each round.
pub struct Coordinator {
    pub opts: CoordinatorOptions,
    pub stats: CoordStats,
}

impl Coordinator {
    pub fn new(opts: CoordinatorOptions) -> Coordinator {
        Coordinator { opts, stats: CoordStats::default() }
    }

    /// Run a full federated experiment over `runner`.
    pub fn run(
        &mut self,
        cfg: &ExperimentConfig,
        runner: &mut dyn LocalRunner,
        opts: &TrainOptions,
    ) -> Result<RunResult, String> {
        cfg.validate()?;
        // the config-level compressor is the default; an explicit
        // TrainOptions compressor (the ablation hook) wins —
        // Some(Compressor::None) is the "explicitly uncompressed" state,
        // only a None option inherits. After precedence, the resolved
        // Compressor::None normalizes to no compressor: identical
        // semantics (no RNG draws, same dense payload and metered
        // bytes), but the upload path then *moves* each delta instead
        // of cloning it through `compress`
        let mut opts = opts.clone();
        if opts.compressor.is_none() {
            opts.compressor = cfg.compressor.clone();
        }
        if opts.compressor == Some(crate::compress::Compressor::None) {
            opts.compressor = None;
        }
        let opts = &opts;
        let sampler = Sampler::from_strategy(&cfg.strategy);
        let pool = runner.num_clients();
        if pool == 0 {
            return Err("empty client pool".into());
        }
        let avail = match &cfg.availability_trace {
            Some(t) => Availability::Trace(t.clone()),
            None => Availability::from_probability(cfg.availability),
        };
        let eta_g = match cfg.algorithm {
            Algorithm::FedAvg { eta_g, .. } => eta_g,
            // DSGD folds its step size into the master update (Eq. 2)
            Algorithm::Dsgd { eta } => eta,
        };
        let registry = Registry::new(pool, self.opts.shards);

        let rng = Rng::new(cfg.seed).fork(0xF1);
        let mut x = runner.init_params(cfg.seed);
        let mut meter = BitMeter::new();
        let mut result = RunResult::new(&cfg.name, sampler.name());

        // Telemetry sits entirely outside the protocol: it never reads
        // an RNG stream, so trajectories are bit-identical with it on or
        // off. A disabled recorder records nothing and installs no clock.
        let mut tel = Telemetry::from_config(&opts.telemetry)?;
        if tel.enabled() {
            runner.set_clock(Some(tel.clock()));
        }

        // the chaos context exists only when a plan can actually fire —
        // a zero-rate (or absent) plan stays on the bitwise fault-free
        // path (see `faults::FaultCtx::from_plan`)
        let mut faults = FaultCtx::from_plan(cfg.fault_plan.as_ref());

        // Checkpointing (crate::checkpoint) sits outside the protocol
        // like telemetry: snapshots are taken after Commit and restores
        // happen before round 0, so the trajectory is bit-identical with
        // it on or off. The fingerprint binds snapshots to this exact
        // config; it is only computed when the subsystem is in play.
        let ck = &opts.checkpoint;
        ck.validate()?;
        let fingerprint = if ck.every > 0 || ck.resume.is_some() {
            checkpoint::config_fingerprint(cfg)
        } else {
            0
        };
        let mut start_round = 0usize;
        let mut resumed = false;
        if let Some(path) = &ck.resume {
            let snap = Snapshot::load(path).map_err(String::from)?;
            if snap.config_fingerprint != fingerprint {
                return Err(CheckpointError::ConfigMismatch {
                    got: snap.config_fingerprint,
                    want: fingerprint,
                }
                .into());
            }
            if snap.x.len() != x.len() {
                return Err(CheckpointError::DimMismatch {
                    got: snap.x.len(),
                    want: x.len(),
                }
                .into());
            }
            x.copy_from_slice(&snap.x);
            meter = BitMeter::with_bytes(snap.meter_bytes);
            result.rounds = snap.records.clone();
            self.stats = snap.stats.clone();
            if let (Some(ctx), Some(fs)) = (faults.as_mut(), &snap.fault) {
                ctx.counters = fs.counters;
                ctx.last_probs = fs.last_probs.iter().copied().collect();
            }
            tel.restore_counters(&snap.tel_counters, snap.tel_rounds as usize);
            start_round = snap.next_round as usize;
            tel.resumed(start_round);
            resumed = true;
        }

        // master-side chaos: kill the coordinator at the top of this
        // round. One-shot — disarmed on resume (the kill already
        // happened; the cadence may lag the kill round, so re-arming
        // would re-die forever).
        let masterkill = if resumed {
            None
        } else {
            cfg.fault_plan.as_ref().and_then(|p| p.masterkill)
        };

        for round in start_round..cfg.rounds {
            if masterkill == Some(round as u64) {
                return Err(format!(
                    "{MASTERKILL_ERR_PREFIX} fault plan killed the \
                     coordinator at round {round}"
                ));
            }
            self.stats.rounds_run += 1;
            let mut round_rng = rng.fork(round as u64);
            let mut machine = RoundMachine::new(round);
            self.stats.shards_dropped += machine.announce(
                cfg,
                &avail,
                &registry,
                self.opts.deadline.as_ref(),
                &mut round_rng,
                &mut tel,
            );
            self.stats.shards_outaged += machine.outaged_shards();
            if machine.cohort().is_empty() {
                self.stats.noop_rounds += 1;
                result.push(round::noop_record(round, &meter));
                tel.flush_round(round);
                self.maybe_snapshot(ck, fingerprint, round, &x, &meter, &result, &faults, &mut tel)?;
                continue;
            }
            machine.local_compute(runner, &x, &mut tel);
            machine.norm_report(&mut tel);
            machine.negotiate(
                &sampler,
                cfg,
                if self.opts.sharded_negotiation {
                    Some(&mut *runner)
                } else {
                    None
                },
                opts.compressor.as_ref(),
                faults.as_mut(),
                &mut meter,
                &mut round_rng,
                &mut tel,
            );
            machine.secure_aggregate(
                cfg,
                opts,
                &registry,
                runner,
                faults.as_mut(),
                &mut meter,
                &mut round_rng,
                &mut tel,
            );
            machine.repair(cfg, faults.as_mut(), &mut tel);
            result.push(machine.commit(
                cfg,
                opts,
                eta_g,
                &mut x,
                runner,
                &meter,
                &mut tel,
            )?);
            self.maybe_snapshot(ck, fingerprint, round, &x, &meter, &result, &faults, &mut tel)?;
        }
        if tel.enabled() {
            runner.set_clock(None);
        }
        if let Some(ctx) = &faults {
            self.stats.faults = ctx.counters;
        }
        result.telemetry = tel.finish();
        Ok(result)
    }

    /// Write a durable snapshot if this round is on the checkpoint
    /// cadence — called after Commit (and after no-op rounds), so the
    /// snapshot captures exactly the state the next round starts from.
    #[allow(clippy::too_many_arguments)]
    fn maybe_snapshot(
        &self,
        ck: &CheckpointOptions,
        fingerprint: u64,
        round: usize,
        x: &[f32],
        meter: &BitMeter,
        result: &RunResult,
        faults: &Option<FaultCtx>,
        tel: &mut Telemetry,
    ) -> Result<(), String> {
        if ck.every == 0 || (round + 1) % ck.every != 0 {
            return Ok(());
        }
        let Some(path) = &ck.out else { return Ok(()) };
        tel.span_begin(round, PhaseSpan::Checkpoint);
        let fault = faults.as_ref().map(|ctx| {
            // HashMap iteration order is nondeterministic — sort by
            // client id so the snapshot bytes are reproducible
            let mut last_probs: Vec<(u64, f64)> =
                ctx.last_probs.iter().map(|(&c, &p)| (c, p)).collect();
            last_probs.sort_unstable_by_key(|&(c, _)| c);
            checkpoint::FaultState { counters: ctx.counters, last_probs }
        });
        let mut stats = self.stats.clone();
        if let Some(ctx) = faults {
            // the live tally only lands in self.stats at end of run
            stats.faults = ctx.counters;
        }
        let (tel_counters, tel_rounds) = tel.checkpoint_state();
        let snap = Snapshot {
            config_fingerprint: fingerprint,
            next_round: (round + 1) as u64,
            x: x.to_vec(),
            meter_bytes: meter.total_bytes(),
            records: result.rounds.clone(),
            stats,
            fault,
            tel_counters,
            tel_rounds: tel_rounds as u64,
        };
        let bytes = snap.write_atomic(path).map_err(String::from)?;
        tel.checkpoint_written(round, bytes as u64);
        tel.span_end(round, PhaseSpan::Checkpoint);
        Ok(())
    }
}
