//! Sharded client registry: which shard owns which slice of the client
//! pool, and how a round cohort splits across shards.
//!
//! Sharding is round-robin (`client % shards`): deterministic, balanced
//! to within one client, and stable under pool growth at the tail (new
//! clients land on existing shards without reshuffling earlier ids —
//! the property a production registry needs for incremental scale-out).
//!
//! The registry itself is O(1) state — two integers — so it describes a
//! million-client pool as cheaply as a ten-client one; membership is
//! arithmetic ([`Registry::shard_of`]), never a lookup table, and
//! [`Registry::shard_members`] iterates a shard's clients without
//! materializing them. That is what lets the streaming cohort draw
//! (`fl::availability::sample_round_cohort`) stay O(cohort) per round.
//!
//! ```
//! use fedsamp::coordinator::Registry;
//! let r = Registry::new(10, 4);
//! // client 7 lives on shard 7 % 4 == 3
//! assert_eq!(r.shard_of(7), 3);
//! let part = r.split_cohort(&[7, 2, 9, 4]);
//! assert_eq!(part.clients.iter().map(Vec::len).sum::<usize>(), 4);
//! ```

/// The registry's ownership arithmetic (`client % groups`), factored
/// out so *virtual* groupings can share the exact shard-map rule
/// without carrying a registry: the clustered sampler seeds its
/// centroids from `k` virtual round-robin shards through this function
/// ([`crate::sampling::clustered`]), which keeps cluster trajectories
/// independent of the physical shard count — the property that makes
/// them bitwise stable across provisioning. `groups == 0` is treated
/// as one group (the same clamp [`Registry::new`] applies).
pub fn round_robin_slot(client: usize, groups: usize) -> usize {
    client % groups.max(1)
}

/// Shard assignment over a fixed client pool.
#[derive(Clone, Debug)]
pub struct Registry {
    pool: usize,
    shards: usize,
}

/// A round cohort split by owning shard. `clients[s]` are shard `s`'s
/// cohort members (in cohort order) and `positions[s]` their positions
/// in the global cohort, so per-shard results can be reassembled into
/// the exact order the protocol saw.
#[derive(Clone, Debug)]
pub struct CohortPartition {
    pub clients: Vec<Vec<usize>>,
    pub positions: Vec<Vec<usize>>,
}

impl Registry {
    /// Build a registry of `shards` shards over `pool` clients. The shard
    /// count is clamped to `[1, pool]` — more shards than clients would
    /// leave permanently idle shards.
    pub fn new(pool: usize, shards: usize) -> Registry {
        assert!(pool > 0, "registry needs a non-empty client pool");
        Registry { pool, shards: shards.clamp(1, pool) }
    }

    pub fn pool(&self) -> usize {
        self.pool
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The shard owning `client`.
    pub fn shard_of(&self, client: usize) -> usize {
        assert!(
            client < self.pool,
            "client {client} outside pool of {}",
            self.pool
        );
        round_robin_slot(client, self.shards)
    }

    /// Iterate `shard`'s pool clients in ascending order without
    /// materializing them — the streaming counterpart of
    /// [`Registry::clients_of`].
    pub fn shard_members(
        &self,
        shard: usize,
    ) -> impl Iterator<Item = usize> {
        assert!(shard < self.shards, "shard {shard} out of range");
        (shard..self.pool).step_by(self.shards)
    }

    /// All pool clients owned by `shard`, ascending.
    pub fn clients_of(&self, shard: usize) -> Vec<usize> {
        self.shard_members(shard).collect()
    }

    /// Number of pool clients owned by `shard`.
    pub fn shard_size(&self, shard: usize) -> usize {
        assert!(shard < self.shards, "shard {shard} out of range");
        (self.pool - shard + self.shards - 1) / self.shards
    }

    /// Split a cohort by owning shard, preserving cohort order within
    /// each shard and remembering global cohort positions.
    pub fn split_cohort(&self, cohort: &[usize]) -> CohortPartition {
        let mut clients = vec![Vec::new(); self.shards];
        let mut positions = vec![Vec::new(); self.shards];
        for (pos, &c) in cohort.iter().enumerate() {
            let s = self.shard_of(c);
            clients[s].push(c);
            positions[s].push(pos);
        }
        CohortPartition { clients, positions }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_count_clamped_to_pool() {
        assert_eq!(Registry::new(3, 8).shards(), 3);
        assert_eq!(Registry::new(10, 0).shards(), 1);
        assert_eq!(Registry::new(10, 4).shards(), 4);
    }

    #[test]
    fn shards_partition_the_pool() {
        let r = Registry::new(10, 4);
        let mut seen = vec![0usize; 10];
        for s in 0..r.shards() {
            assert_eq!(r.clients_of(s).len(), r.shard_size(s));
            for c in r.clients_of(s) {
                assert_eq!(r.shard_of(c), s);
                seen[c] += 1;
            }
        }
        assert!(seen.iter().all(|&k| k == 1), "{seen:?}");
        // balanced to within one client
        let sizes: Vec<usize> = (0..4).map(|s| r.shard_size(s)).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn assignment_is_stable_under_pool_growth() {
        let small = Registry::new(10, 4);
        let big = Registry::new(1000, 4);
        for c in 0..10 {
            assert_eq!(small.shard_of(c), big.shard_of(c));
        }
    }

    #[test]
    fn split_cohort_reassembles_exactly() {
        let r = Registry::new(20, 3);
        let cohort = [7usize, 2, 19, 4, 11, 0];
        let part = r.split_cohort(&cohort);
        assert_eq!(part.clients.len(), 3);
        let mut rebuilt = vec![usize::MAX; cohort.len()];
        for (cs, ps) in part.clients.iter().zip(&part.positions) {
            assert_eq!(cs.len(), ps.len());
            for (&c, &p) in cs.iter().zip(ps) {
                assert_eq!(r.shard_of(c), r.shard_of(cs[0]));
                rebuilt[p] = c;
            }
        }
        assert_eq!(rebuilt, cohort);
    }

    #[test]
    fn split_preserves_cohort_order_within_shards() {
        let r = Registry::new(12, 2);
        let cohort = [1usize, 3, 5, 7, 9, 11, 0, 2];
        let part = r.split_cohort(&cohort);
        for ps in &part.positions {
            assert!(ps.windows(2).all(|w| w[0] < w[1]), "{ps:?}");
        }
    }

    #[test]
    #[should_panic(expected = "outside pool")]
    fn out_of_pool_client_rejected() {
        Registry::new(4, 2).shard_of(4);
    }

    #[test]
    fn virtual_slots_match_physical_shards() {
        // the factored-out arithmetic IS the registry rule: a virtual
        // k-group map over any pool agrees with a k-shard registry
        let r = Registry::new(40, 4);
        for c in 0..40 {
            assert_eq!(round_robin_slot(c, 4), r.shard_of(c));
        }
        assert_eq!(round_robin_slot(7, 0), 0, "0 groups clamps to 1");
    }
}
