//! Per-shard partial aggregation with a deterministic pairwise tree
//! combine: the *combine stage* reduces O(shards) intermediate buffers
//! instead of folding O(clients) update vectors one by one. (In this
//! in-process implementation the upload vectors themselves still sit in
//! host memory; the partial/tree seam is what a streaming or networked
//! master plugs into to make the whole pipeline O(shards).)
//!
//! Two partial kinds, mirroring the two aggregation modes of the round
//! protocol:
//!
//! * [`ShardPartial::Masked`] — secure-aggregation ring vectors
//!   (`Z_2^64` fixed point). Wrapping addition is commutative and
//!   associative, so the sharded combine is **bit-identical** to a flat
//!   sum regardless of shard count — this is what makes the sharded
//!   coordinator trajectory-exact under `secure_updates`.
//! * [`ShardPartial::Plain`] — f32 vectors. Floating addition is not
//!   associative, so different shard counts may differ in the last ulp;
//!   the tree order is still fixed by shard index, so any given shard
//!   count is deterministic run-to-run.

use crate::secure_agg::SecureAggregator;
use crate::tensor;
use crate::tensor::kernels::{self, Scratch};
use crate::wire::Payload;

/// One shard's partial aggregate.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardPartial {
    Plain(Vec<f32>),
    Masked(Vec<u64>),
}

impl ShardPartial {
    pub fn len(&self) -> usize {
        match self {
            ShardPartial::Plain(v) => v.len(),
            ShardPartial::Masked(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Combine two partials of the same kind (panics on kind or length
    /// mismatch — shards must agree on the aggregation mode).
    pub fn merge(self, other: ShardPartial) -> ShardPartial {
        match (self, other) {
            (ShardPartial::Plain(mut a), ShardPartial::Plain(b)) => {
                tensor::axpy(&mut a, 1.0, &b);
                ShardPartial::Plain(a)
            }
            (ShardPartial::Masked(mut a), ShardPartial::Masked(b)) => {
                assert_eq!(a.len(), b.len(), "partial length mismatch");
                kernels::wrapping_accumulate(&mut a, &[b.as_slice()]);
                ShardPartial::Masked(a)
            }
            _ => panic!("cannot merge plain and masked shard partials"),
        }
    }
}

/// Fold one shard's member update vectors (in shard-member order) into a
/// plain f32 partial. Runs the fused chunked accumulate — members are
/// added per element in member order, bit-identical to the seed's
/// sequential `axpy` fold (see `tensor::kernels::accumulate`).
pub fn plain_partial<'a, I>(dim: usize, members: I) -> ShardPartial
where
    I: IntoIterator<Item = &'a [f32]>,
{
    let mut acc = vec![0.0f32; dim];
    let vecs: Vec<&[f32]> = members.into_iter().collect();
    kernels::accumulate(&mut acc, &vecs);
    ShardPartial::Plain(acc)
}

/// Fold one shard's member update vectors with per-member weights:
/// `acc += w_k · v_k` in member order — the fused form of the seed's
/// scale-then-axpy upload (bit-identical: the f32 product rounds the
/// same whether it is stored and then added or fused into the
/// accumulate), via the chunked `tensor::kernels::weighted_accumulate`.
pub fn weighted_partial(
    dim: usize,
    members: &[&[f32]],
    weights: &[f32],
) -> ShardPartial {
    let mut acc = vec![0.0f32; dim];
    kernels::weighted_accumulate(&mut acc, members, weights);
    ShardPartial::Plain(acc)
}

/// Fold one shard's member *payloads* with per-member upload factors:
/// `acc += w_k · densify(p_k)` in member order, without densifying —
/// dense members ride the fused [`kernels::axpy`], sparse members
/// scatter-add only their retained coordinates
/// ([`kernels::sparse_weighted_accumulate`]), quantized members fuse
/// unpack + fold ([`kernels::quantized_accumulate`]). Per output
/// element the member-order add sequence is identical to the
/// densify-then-accumulate reference (skipped sparse lanes would add
/// `w·(±0.0)`, the f32 identity here — see the kernel docs), so this is
/// bit-exact to [`densified_weighted_partial`] — pinned by the property
/// test below and end-to-end by
/// `payload_native_folds_match_the_densified_reference_end_to_end`.
pub fn payload_weighted_partial(
    dim: usize,
    members: &[&Payload],
    weights: &[f32],
) -> ShardPartial {
    assert_eq!(
        members.len(),
        weights.len(),
        "payload_weighted_partial arity"
    );
    let mut acc = vec![0.0f32; dim];
    for (p, &w) in members.iter().zip(weights) {
        match p {
            Payload::Dense(v) => {
                assert_eq!(v.len(), dim, "dense payload dim mismatch");
                kernels::axpy(&mut acc, w, v);
            }
            Payload::SparseK { indices, values } => {
                kernels::sparse_weighted_accumulate(
                    &mut acc, indices, values, w,
                );
            }
            Payload::Quantized { dim: d, norm, levels, packed } => {
                assert_eq!(
                    *d as usize, dim,
                    "quantized payload dim mismatch"
                );
                kernels::quantized_accumulate(
                    &mut acc, packed, *norm, *levels, w,
                );
            }
        }
    }
    ShardPartial::Plain(acc)
}

/// The retained reference fold: densify every member payload, then run
/// the pre-wire chunked weighted fold ([`weighted_partial`]). The
/// baseline arm of `fedsamp bench comm` and the oracle the native
/// payload fold is pinned against (also reachable end-to-end through
/// `TrainOptions::densify_folds`).
pub fn densified_weighted_partial(
    dim: usize,
    members: &[&Payload],
    weights: &[f32],
) -> ShardPartial {
    let dense: Vec<Vec<f32>> =
        members.iter().map(|p| p.densify(dim)).collect();
    let refs: Vec<&[f32]> = dense.iter().map(|v| v.as_slice()).collect();
    weighted_partial(dim, &refs, weights)
}

/// Fold one shard's masked ring vectors into a masked partial (wrapping
/// sums — exact). Members are consumed one at a time, so only the
/// accumulator and the member being folded are alive (the vectors are
/// produced lazily by the masking stage; materializing a whole shard
/// would cost O(members·dim)).
pub fn masked_partial<I>(dim: usize, members: I) -> ShardPartial
where
    I: IntoIterator<Item = Vec<u64>>,
{
    let mut acc = vec![0u64; dim];
    for v in members {
        assert_eq!(v.len(), dim, "masked vector length mismatch");
        kernels::wrapping_accumulate(&mut acc, &[v.as_slice()]);
    }
    ShardPartial::Masked(acc)
}

/// One participant's upload staged for the masked fold: the owned wire
/// payload (uncompressed deltas are moved out of the round outcomes —
/// the protocol no longer needs them once staged, so staging costs a
/// pointer move, not a copy), the upload factor w_i/p_i, and the client
/// id the pair mask streams derive from.
#[derive(Clone, Debug)]
pub struct MaskUpload {
    pub client: u64,
    pub factor: f32,
    pub payload: Payload,
}

/// One round's secure-aggregation work order: the agreed roster and
/// round seed the pair streams derive from, and the participant uploads
/// grouped by owning shard (cohort order within each group; shards with
/// no participants already dropped). Shared read-only by every pool
/// worker during the masked fan-out.
#[derive(Clone, Debug)]
pub struct MaskBatch {
    pub dim: usize,
    pub round_seed: u64,
    pub roster: Vec<u64>,
    pub groups: Vec<Vec<MaskUpload>>,
}

/// Mask + fold one shard group into a ring partial with the fused
/// scale → encode → net-mask → accumulate kernel: one chunked pass per
/// member, block PRG streams, no scaled copy and no per-member mask
/// vector. Ring addition commutes and each pair stream is consumed in
/// element order, so the partial is bit-identical to the scalar
/// mask-then-[`masked_partial`] pipeline for any block size — which is
/// what keeps the sharded secure trajectory exact.
///
/// **Dense-only constraint (the densify boundary).** The pairwise masks
/// cover every coordinate, so the ring fold consumes dense values only:
/// a sparse or quantized payload densifies *here*, at the shard
/// boundary, into the worker's reused `scratch.dense` buffer
/// (`Payload::densify_into` — bit-exact to the payload's reference
/// semantics, so the masked trajectory matches the dense pipeline
/// exactly). Dense payloads are borrowed in place, no copy.
pub fn fused_masked_partial(
    batch: &MaskBatch,
    group: &[MaskUpload],
    scratch: &mut Scratch,
) -> Vec<u64> {
    let agg = SecureAggregator::new(batch.round_seed);
    let mut acc = vec![0u64; batch.dim];
    for m in group {
        agg.pair_streams_into(m.client, &batch.roster, &mut scratch.streams);
        let values: &[f32] = match &m.payload {
            Payload::Dense(v) => {
                assert_eq!(v.len(), batch.dim, "dense upload dim mismatch");
                v
            }
            p => {
                Scratch::ensure(&mut scratch.dense, batch.dim);
                p.densify_into(&mut scratch.dense);
                &scratch.dense
            }
        };
        kernels::scale_encode_mask_accumulate(
            &mut acc,
            values,
            m.factor,
            &mut scratch.streams,
            &mut scratch.ring,
        );
    }
    acc
}

/// Pairwise tree reduction over shard partials. The combine order is
/// fixed by shard index — (0,1), (2,3), … then recursively — so results
/// are deterministic for any shard count. Returns `None` on no shards.
pub fn tree_reduce(mut parts: Vec<ShardPartial>) -> Option<ShardPartial> {
    if parts.is_empty() {
        return None;
    }
    while parts.len() > 1 {
        let mut next = Vec::with_capacity((parts.len() + 1) / 2);
        let mut it = parts.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(a.merge(b)),
                None => next.push(a),
            }
        }
        parts = next;
    }
    parts.pop()
}

/// Decode a combined partial into the f32 aggregate the master applies.
pub fn finish(partial: ShardPartial) -> Vec<f32> {
    match partial {
        ShardPartial::Plain(v) => v,
        ShardPartial::Masked(v) => SecureAggregator::decode_sum(&v),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vectors(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect())
            .collect()
    }

    /// Split `items` round-robin into `k` groups (stand-in for a shard
    /// partition of cohort members).
    fn round_robin<T: Clone>(items: &[T], k: usize) -> Vec<Vec<T>> {
        let mut out = vec![Vec::new(); k];
        for (i, x) in items.iter().enumerate() {
            out[i % k].push(x.clone());
        }
        out
    }

    #[test]
    fn masked_tree_is_exactly_the_flat_sum() {
        let dim = 37;
        let data = vectors(9, dim, 3);
        let agg = SecureAggregator::new(77);
        let roster: Vec<u64> = (0..9).collect();
        let masked: Vec<Vec<u64>> = roster
            .iter()
            .zip(&data)
            .map(|(&id, v)| agg.mask(id, &roster, v))
            .collect();
        let flat = SecureAggregator::sum(&masked);
        for shards in [1usize, 2, 3, 4, 9] {
            let partials: Vec<ShardPartial> = round_robin(&masked, shards)
                .into_iter()
                .map(|group| masked_partial(dim, group))
                .collect();
            let combined = tree_reduce(partials).unwrap();
            assert_eq!(
                combined,
                ShardPartial::Masked(flat.clone()),
                "shards={shards}"
            );
        }
    }

    #[test]
    fn fused_masked_partial_matches_scale_mask_fold_bitwise() {
        // the fused kernel path vs the scalar pipeline it replaced:
        // materialize the scaled copy, encode+mask per pair stream, fold
        // member by member — must agree bitwise (dim spans ring blocks)
        use crate::tensor::kernels::reference;
        let dim = 700;
        let data = vectors(5, dim, 21);
        let roster: Vec<u64> = (0..5).collect();
        let factors: Vec<f32> =
            (0..5).map(|i| 0.4 + i as f32 * 0.21).collect();
        let batch = MaskBatch {
            dim,
            round_seed: 77,
            roster: roster.clone(),
            groups: vec![roster
                .iter()
                .zip(&data)
                .zip(&factors)
                .map(|((&client, v), &factor)| MaskUpload {
                    client,
                    factor,
                    payload: Payload::Dense(v.clone()),
                })
                .collect()],
        };
        let got = fused_masked_partial(
            &batch,
            &batch.groups[0],
            &mut Scratch::new(),
        );

        let agg = SecureAggregator::new(77);
        let mut want = vec![0u64; dim];
        for ((&client, v), &factor) in roster.iter().zip(&data).zip(&factors)
        {
            let mut streams = Vec::new();
            agg.pair_streams_into(client, &roster, &mut streams);
            let masked = reference::scale_encode_mask(v, factor, &mut streams);
            for (a, &m) in want.iter_mut().zip(&masked) {
                *a = a.wrapping_add(m);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn plain_single_shard_matches_sequential_fold_bitwise() {
        let dim = 21;
        let data = vectors(7, dim, 5);
        let mut seq = vec![0.0f32; dim];
        for v in &data {
            tensor::axpy(&mut seq, 1.0, v);
        }
        let p = plain_partial(dim, data.iter().map(|v| v.as_slice()));
        let got = finish(tree_reduce(vec![p]).unwrap());
        assert_eq!(got, seq);
    }

    #[test]
    fn weighted_partial_is_bit_exact_to_scale_then_fold() {
        // the seed upload semantics: scale each vector by w_i/p_i, then
        // fold in member order — the fused weighted partial must agree
        // bitwise
        let dim = 33;
        let data = vectors(5, dim, 13);
        let weights: Vec<f32> = (0..5).map(|i| 0.3 + i as f32 * 0.17).collect();
        let mut want = vec![0.0f32; dim];
        for (v, &w) in data.iter().zip(&weights) {
            let mut s = v.clone();
            tensor::scale(&mut s, w);
            tensor::axpy(&mut want, 1.0, &s);
        }
        let members: Vec<&[f32]> = data.iter().map(|v| v.as_slice()).collect();
        let got = finish(
            tree_reduce(vec![weighted_partial(dim, &members, &weights)])
                .unwrap(),
        );
        assert_eq!(got, want);
    }

    #[test]
    fn plain_tree_is_deterministic_and_close_across_shard_counts() {
        let dim = 64;
        let data = vectors(16, dim, 9);
        let reduce = |shards: usize| -> Vec<f32> {
            let partials: Vec<ShardPartial> = round_robin(&data, shards)
                .into_iter()
                .map(|group| {
                    plain_partial(dim, group.iter().map(|v| v.as_slice()))
                })
                .collect();
            finish(tree_reduce(partials).unwrap())
        };
        // deterministic: identical invocations agree bitwise
        assert_eq!(reduce(4), reduce(4));
        // close: reorder error stays at float-noise level
        let a = reduce(1);
        let b = reduce(4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    /// A random payload of a random kind over dimension `d`.
    fn random_payload(rng: &mut crate::util::rng::Rng, d: usize) -> Payload {
        use crate::compress::Compressor;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let c = match rng.below(3) {
            0 => Compressor::None,
            1 => Compressor::RandK { k: rng.range(1, d + 1) },
            _ => Compressor::QsgdQuant { levels: rng.range(1, 16) as u32 },
        };
        c.compress(&x, rng)
    }

    #[test]
    fn prop_payload_fold_bit_exact_to_densified_reference() {
        // the wire-layer fold contract: the payload-native scatter fold
        // equals the retained densify-then-accumulate reference bitwise
        // for any mix of payload kinds, dims and factors
        use crate::util::prop::quick;
        quick("payload-weighted-partial", |rng, _| {
            let d = rng.range(1, 1500); // spans CHUNK windows
            let members = rng.range(1, 6);
            let payloads: Vec<Payload> =
                (0..members).map(|_| random_payload(rng, d)).collect();
            let weights: Vec<f32> =
                (0..members).map(|_| rng.normal_f32(1.0, 0.5)).collect();
            let refs: Vec<&Payload> = payloads.iter().collect();
            let native = payload_weighted_partial(d, &refs, &weights);
            let densified = densified_weighted_partial(d, &refs, &weights);
            let (ShardPartial::Plain(a), ShardPartial::Plain(b)) =
                (&native, &densified)
            else {
                return Err("plain partials expected".into());
            };
            let same = a
                .iter()
                .zip(b)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            if same {
                Ok(())
            } else {
                Err("payload fold diverged from densified reference".into())
            }
        });
    }

    #[test]
    fn fused_masked_partial_densifies_compressed_payloads_exactly() {
        // the shard-boundary densify: masking a compressed payload must
        // equal masking its dense equivalent, bit for bit
        let dim = 700; // spans ring blocks
        let mut rng = Rng::new(77);
        let roster: Vec<u64> = (0..6).collect();
        let uploads: Vec<MaskUpload> = roster
            .iter()
            .map(|&client| MaskUpload {
                client,
                factor: 0.3 + client as f32 * 0.17,
                payload: random_payload(&mut rng, dim),
            })
            .collect();
        let dense_twin: Vec<MaskUpload> = uploads
            .iter()
            .map(|m| MaskUpload {
                client: m.client,
                factor: m.factor,
                payload: Payload::Dense(m.payload.densify(dim)),
            })
            .collect();
        let mk_batch = |groups: Vec<Vec<MaskUpload>>| MaskBatch {
            dim,
            round_seed: 31,
            roster: roster.clone(),
            groups,
        };
        let a = mk_batch(vec![uploads]);
        let b = mk_batch(vec![dense_twin]);
        assert_eq!(
            fused_masked_partial(&a, &a.groups[0], &mut Scratch::new()),
            fused_masked_partial(&b, &b.groups[0], &mut Scratch::new()),
        );
    }

    #[test]
    fn empty_reduce_is_none() {
        assert!(tree_reduce(Vec::new()).is_none());
    }

    #[test]
    #[should_panic(expected = "plain and masked")]
    fn kind_mismatch_panics() {
        let a = ShardPartial::Plain(vec![0.0; 2]);
        let b = ShardPartial::Masked(vec![0; 2]);
        let _ = a.merge(b);
    }
}
