//! The explicit round state machine:
//!
//! ```text
//! Announce → LocalCompute → NormReport → Negotiate → SecureAggregate
//!          → Repair → Commit
//! ```
//!
//! Each phase is a method on [`RoundMachine`] that asserts it runs in
//! order, consumes exactly the inputs the seed `fl::train` loop consumed
//! (same RNG draw order, same float-op order on the master), and stores
//! its outputs for the next phase. With one shard the trajectory is
//! bit-identical to the historical sequential loop; with many shards the
//! masked (fixed-point) aggregation path remains bit-identical because
//! ring sums commute — see [`super::aggregate`].
//!
//! Deadline handling rides on `Announce`: a shard that misses the round
//! deadline contributes nothing that round (its cohort members are
//! dropped before norm collection). AOCS tolerates this by design — the
//! negotiation only ever consumes aggregates of the surviving cohort.
//!
//! **Repair** is the chaos layer's recovery phase (DESIGN.md §10). When
//! a [`crate::faults::FaultPlan`] injects mid-round failures, the phase
//! (a) reconstructs and subtracts the uncancelled pairwise-mask residue
//! of clients that crashed *after* mask commitment
//! ([`crate::secure_agg::SecureAggregator::recover`]), (b) renormalizes
//! the w_i/p_i estimator over the surviving participant set, and the
//! upload loops quarantine clients whose frames fail the hardened wire
//! integrity checks. On the secure path the decode of the combined ring
//! sum is deferred from `SecureAggregate` into `Repair` so the residue
//! subtraction happens in the exact ring; with no faults the phase is a
//! pass-through decode — bitwise identical to the pre-chaos pipeline.

use crate::compress::Compressor;
use crate::config::{ExperimentConfig, Strategy};
use crate::faults::{self, FaultCtx};
use crate::fl::availability::{sample_round_cohort, Availability};
use crate::fl::comm::BitMeter;
use crate::fl::{EvalOutcome, LocalOutcome, TrainOptions};
use crate::metrics::RoundRecord;
use crate::sampling::{aocs, cyclic, probability, variance, Decision, Sampler};
use crate::secure_agg::SecureAggregator;
use crate::telemetry::{Counter, PhaseSpan, Telemetry};
use crate::tensor;
use crate::tensor::kernels;
use crate::util::rng::Rng;
use crate::wire::Payload;

use super::aggregate::{self, MaskBatch, MaskUpload, ShardPartial};
use super::registry::Registry;
use super::shard::LocalRunner;
use super::DeadlinePolicy;

/// Seed-stream label for the straggler draws: independent of the round
/// RNG so enabling a deadline never perturbs cohort/selection streams.
const STRAGGLER_STREAM: u64 = 0x57A6_61E5;

/// Seed-stream label for the sharded AOCS negotiation's pairwise masks:
/// independent of the vector-masking round seed so the two secure
/// exchanges of a round never share mask streams.
const NEGOTIATION_STREAM: u64 = 0x4E60_71A7;

/// Seed-stream label for the caocs compression *preview*: clients
/// evaluate `‖C(U_i)‖` on a dedicated stream so the negotiation never
/// consumes (or perturbs) the upload compressor's own draws — the
/// transmitted payloads stay bitwise identical to an AOCS run.
const CAOCS_STREAM: u64 = 0xCA0C_5EED;

/// Integrity bound on a decoded upload's fold magnitudes: the
/// fixed-point ring represents |x| < 2^39 per element, so a
/// corrupted-but-decodable frame whose values (after the w_i/p_i upload
/// scale) could reach that range is quarantined rather than folded — in
/// production the master rejects implausible updates the same way.
/// Honest updates sit many orders of magnitude below this.
const RING_SAFE_MAGNITUDE: f32 = 1.0e9;

/// The protocol phases, in execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Announce,
    LocalCompute,
    NormReport,
    Negotiate,
    SecureAggregate,
    Repair,
    Commit,
    Done,
}

/// One round's worth of protocol state, advanced phase by phase.
pub struct RoundMachine {
    round: usize,
    phase: Phase,
    /// surviving cohort, global client ids in selection order
    cohort: Vec<usize>,
    /// per-shard cohort slices (cohort order within each shard)
    shard_clients: Vec<Vec<usize>>,
    /// global cohort position of each shard-slice member
    shard_positions: Vec<Vec<usize>>,
    dropped_shards: usize,
    /// shards removed wholesale by a correlated trace outage
    outaged_shards: usize,
    /// local outcomes, reassembled into cohort order
    outcomes: Vec<LocalOutcome>,
    weights: Vec<f64>,
    norms: Vec<f64>,
    decision: Option<Decision>,
    selected: Vec<bool>,
    alpha: f64,
    gamma: f64,
    aggregate: Vec<f32>,
    transmitted: usize,
    /// combined (still-masked) ring sum, awaiting the Repair phase's
    /// residue subtraction + decode (secure path only)
    masked_sum: Option<Vec<u64>>,
    /// the agreed mask roster, including post-commit dropouts
    mask_roster: Vec<u64>,
    /// roster members whose upload never arrived (crash-after-commit or
    /// quarantined): their uncancelled mask residue is repaired
    post_dropped: Vec<u64>,
    /// Σ w_i/p_i over every *selected* client (the estimator's intended
    /// mass this round)
    sel_mass: f64,
    /// Σ w_i/p_i over selected clients whose contribution was lost to a
    /// fault — exactly 0.0 on the fault-free path (no float ops run)
    lost_mass: f64,
}

impl RoundMachine {
    pub fn new(round: usize) -> RoundMachine {
        RoundMachine {
            round,
            phase: Phase::Announce,
            cohort: Vec::new(),
            shard_clients: Vec::new(),
            shard_positions: Vec::new(),
            dropped_shards: 0,
            outaged_shards: 0,
            outcomes: Vec::new(),
            weights: Vec::new(),
            norms: Vec::new(),
            decision: None,
            selected: Vec::new(),
            alpha: f64::NAN,
            gamma: f64::NAN,
            aggregate: Vec::new(),
            transmitted: 0,
            masked_sum: None,
            mask_roster: Vec::new(),
            post_dropped: Vec::new(),
            sel_mass: 0.0,
            lost_mass: 0.0,
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn cohort(&self) -> &[usize] {
        &self.cohort
    }

    pub fn dropped_shards(&self) -> usize {
        self.dropped_shards
    }

    /// Shards a correlated availability-trace outage removed this round
    /// (disjoint accounting from deadline [`RoundMachine::dropped_shards`]:
    /// outages act *before* cohort selection, deadlines after).
    pub fn outaged_shards(&self) -> usize {
        self.outaged_shards
    }

    fn expect(&self, phase: Phase) {
        assert_eq!(
            self.phase, phase,
            "round {}: phase {phase:?} invoked out of order",
            self.round
        );
    }

    /// (1) Cohort selection from the available pool, partitioned over the
    /// shard registry; shards that miss the round deadline are dropped
    /// wholesale. Returns the number of dropped shards.
    ///
    /// Selection is the **streaming** draw of `fl::availability`:
    /// O(cohort) memory at any pool size, bitwise identical to the seed
    /// dense draw. Unavailability composes in protocol order — trace
    /// shard outages and per-client unavailability remove clients
    /// *before* the uniform draw; deadline misses drop whole shards
    /// *after* it (a selected client on a straggling shard contributes
    /// nothing that round).
    pub fn announce(
        &mut self,
        cfg: &ExperimentConfig,
        avail: &Availability,
        registry: &Registry,
        deadline: Option<&DeadlinePolicy>,
        round_rng: &mut Rng,
        tel: &mut Telemetry,
    ) -> usize {
        self.expect(Phase::Announce);
        tel.span_begin(self.round, PhaseSpan::Announce);
        let draw = sample_round_cohort(
            avail,
            registry,
            self.round,
            cfg.cohort,
            round_rng,
        );
        self.outaged_shards = draw.outaged_shards;
        let mut cohort = draw.cohort;
        // cyclic participation: only the round's scheduled group enters
        // the cohort. Membership is a pure hash of (seed, client), so
        // the restriction is O(cohort), never consumes RNG, and is
        // identical across shard/worker provisioning. Applied before
        // the announce count — unscheduled clients were never invited,
        // which is different from being deadline-dropped.
        if let Strategy::Cyclic { g } = cfg.strategy {
            cohort.retain(|&c| {
                cyclic::is_scheduled(cfg.seed, c, self.round, g)
            });
        }
        let announced = cohort.len();
        if let Some(policy) = deadline {
            if policy.miss_prob > 0.0 {
                let stream = Rng::new(cfg.seed ^ STRAGGLER_STREAM)
                    .fork(self.round as u64);
                let missed: Vec<bool> = (0..registry.shards())
                    .map(|shard| {
                        stream
                            .fork(shard as u64)
                            .bernoulli(policy.miss_prob)
                    })
                    .collect();
                self.dropped_shards =
                    missed.iter().filter(|&&m| m).count();
                cohort.retain(|&c| !missed[registry.shard_of(c)]);
            }
        }
        tel.add(Counter::ClientsAnnounced, announced as u64);
        tel.add(
            Counter::ClientsDeadlineDropped,
            (announced - cohort.len()) as u64,
        );
        tel.add(Counter::ShardsOutaged, self.outaged_shards as u64);
        tel.add(Counter::ShardsDeadlineDropped, self.dropped_shards as u64);
        let part = registry.split_cohort(&cohort);
        self.cohort = cohort;
        self.shard_clients = part.clients;
        self.shard_positions = part.positions;
        self.phase = if self.cohort.is_empty() {
            Phase::Done // no reachable clients: the round is a no-op
        } else {
            Phase::LocalCompute
        };
        tel.span_end(self.round, PhaseSpan::Announce);
        self.dropped_shards
    }

    /// (2) Every surviving shard runs its cohort slice's local work; the
    /// outcomes are reassembled into global cohort order.
    pub fn local_compute(
        &mut self,
        runner: &mut dyn LocalRunner,
        global: &[f32],
        tel: &mut Telemetry,
    ) {
        self.expect(Phase::LocalCompute);
        tel.span_begin(self.round, PhaseSpan::LocalCompute);
        let by_shard =
            runner.run_shards(self.round, global, &self.shard_clients);
        tel.collect_jobs(self.round, &mut |buf| runner.drain_timings(buf));
        assert_eq!(
            by_shard.len(),
            self.shard_clients.len(),
            "runner shard arity mismatch"
        );
        let mut slots: Vec<Option<LocalOutcome>> =
            vec![None; self.cohort.len()];
        for ((outs, clients), positions) in by_shard
            .into_iter()
            .zip(&self.shard_clients)
            .zip(&self.shard_positions)
        {
            assert_eq!(outs.len(), clients.len(), "engine cohort mismatch");
            for (o, &pos) in outs.into_iter().zip(positions) {
                slots[pos] = Some(o);
            }
        }
        self.outcomes = slots
            .into_iter()
            .map(|s| s.expect("shard left a cohort position unfilled"))
            .collect();
        self.phase = Phase::NormReport;
        tel.span_end(self.round, PhaseSpan::LocalCompute);
    }

    /// (3) Cohort weights `w_i ∝ n_i` and weighted norms `ũ_i = w_i‖U_i‖`.
    /// Example counts combine per shard first (integer partial sums are
    /// order-independent, so this matches the flat sum exactly); the
    /// master then touches only O(cohort) scalars, never update vectors.
    pub fn norm_report(&mut self, tel: &mut Telemetry) {
        self.expect(Phase::NormReport);
        tel.span_begin(self.round, PhaseSpan::NormReport);
        let shard_examples: Vec<usize> = self
            .shard_positions
            .iter()
            .map(|ps| ps.iter().map(|&p| self.outcomes[p].examples).sum())
            .collect();
        let total_examples: usize = shard_examples.iter().sum();
        self.weights = self
            .outcomes
            .iter()
            .map(|o| o.examples as f64 / total_examples.max(1) as f64)
            .collect();
        self.norms = self
            .outcomes
            .iter()
            .zip(&self.weights)
            .map(|(o, &w)| w * tensor::norm(&o.delta))
            .collect();
        self.phase = Phase::Negotiate;
        tel.span_end(self.round, PhaseSpan::NormReport);
    }

    /// (4)+(5) Sampling negotiation (Eq. 7 / Alg. 2) and the independent
    /// transmission draw, with the α/γ diagnostics of the round.
    ///
    /// With `sharded = Some(runner)` and an AOCS sampler, Algorithm 2
    /// runs **per shard**: every aggregate it consumes (u, then (I, P)
    /// per rescaling iteration) is computed as per-shard secure partial
    /// sums — masked scalar folds over the runner's worker pool
    /// ([`LocalRunner::negotiation_partials`]) — which the master
    /// combines as O(shards) scalars. Opt-in because the partial sums
    /// travel as f32 through the fixed-point ring and reorder the
    /// central f64 fold: the fixed point is the same, the last ulps are
    /// not, so seed-exact trajectories need the central path.
    ///
    /// With a chaos context, each sharded exchange's partial delivery
    /// may stall ([`crate::faults::FaultPlan::stalls`]); the master
    /// retries with a bounded backoff budget and, when every attempt of
    /// an exchange stalls, degrades that shard to its members'
    /// last-good probabilities (uniform m/n before any succeed) — the
    /// other shards' aggregates are untouched.
    #[allow(clippy::too_many_arguments)]
    pub fn negotiate(
        &mut self,
        sampler: &Sampler,
        cfg: &ExperimentConfig,
        sharded: Option<&mut dyn LocalRunner>,
        compressor: Option<&Compressor>,
        faults: Option<&mut FaultCtx>,
        meter: &mut BitMeter,
        round_rng: &mut Rng,
        tel: &mut Telemetry,
    ) {
        self.expect(Phase::Negotiate);
        tel.span_begin(self.round, PhaseSpan::Negotiate);
        let m = cfg.budget.min(self.cohort.len());
        let decision = match (sampler, sharded) {
            // compression-aware AOCS: the same Algorithm-2 solver, fed
            // the norms of the payloads clients would actually send
            // (`w_i‖C(U_i)‖`, previewed on a dedicated seed stream).
            // Central-path only — the sharded sum-only negotiation
            // stays AOCS over raw norms.
            (Sampler::Caocs { j_max }, _) => {
                let cnorms = self.compressed_norms(cfg, compressor);
                Decision::from_aocs(aocs::aocs_probabilities(
                    &cnorms, m, *j_max,
                ))
            }
            (Sampler::Aocs { j_max }, Some(runner)) => {
                let groups: Vec<Vec<(u64, usize)>> = self
                    .shard_clients
                    .iter()
                    .zip(&self.shard_positions)
                    .map(|(cs, ps)| {
                        cs.iter()
                            .zip(ps)
                            .map(|(&c, &p)| (c as u64, p))
                            .collect()
                    })
                    .collect();
                let base =
                    cfg.seed ^ (self.round as u64) ^ NEGOTIATION_STREAM;
                // fresh mask streams per exchange: reusing one seed
                // across the negotiation's 1 + 2j secure sums would make
                // every client's pairwise masks identical one-time pads,
                // and subtracting a client's masked I-upload from its
                // masked P-upload would reveal its individual p_i — the
                // value the sum-only protocol exists to hide
                let mut exchange: u64 = 0;
                // chaos: stall draws per (shard, exchange, attempt) —
                // accounting only; the partial's value is still computed
                // (retries deliver the same deterministic sum), so other
                // shards' aggregates never shift
                let plan = faults.as_ref().map(|f| f.plan.clone());
                let round = self.round as u64;
                let mut stalls: u64 = 0;
                let mut retries: u64 = 0;
                let mut degraded = vec![false; groups.len()];
                let r = aocs::aocs_probabilities_sharded(
                    &self.norms,
                    &groups,
                    m,
                    *j_max,
                    &mut |scalars: &[Vec<(u64, f32)>]| {
                        let seed = base
                            ^ exchange.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                        let ex = exchange;
                        exchange += 1;
                        if let Some(p) = &plan {
                            for (g, group) in scalars.iter().enumerate() {
                                if group.is_empty() {
                                    continue;
                                }
                                let mut attempt: u64 = 0;
                                loop {
                                    if !p.stalls(
                                        g as u64, round, ex, attempt,
                                    ) {
                                        break;
                                    }
                                    stalls += 1;
                                    if attempt >= p.max_retries as u64 {
                                        degraded[g] = true;
                                        break;
                                    }
                                    retries += 1;
                                    attempt += 1;
                                }
                            }
                        }
                        runner.negotiation_partials(seed, scalars)
                    },
                );
                tel.collect_jobs(self.round, &mut |buf| {
                    runner.drain_timings(buf)
                });
                let mut decision = Decision::from_aocs(r);
                if let Some(ctx) = faults {
                    ctx.counters.stalls += stalls;
                    ctx.counters.retries += retries;
                    tel.add(Counter::FaultsStalled, stalls);
                    tel.add(Counter::NegotiationRetries, retries);
                    let uniform = m as f64 / self.cohort.len() as f64;
                    let mut shards_degraded = 0u64;
                    for (g, members) in groups.iter().enumerate() {
                        if !degraded[g] {
                            continue;
                        }
                        shards_degraded += 1;
                        for &(c, p) in members {
                            decision.probs[p] = ctx
                                .last_probs
                                .get(&c)
                                .copied()
                                .unwrap_or(uniform)
                                .min(1.0);
                        }
                    }
                    ctx.counters.shards_degraded += shards_degraded;
                    tel.add(Counter::ShardsDegraded, shards_degraded);
                    // cache last-good probabilities for future fallbacks
                    for (g, members) in groups.iter().enumerate() {
                        if degraded[g] {
                            continue;
                        }
                        for &(c, p) in members {
                            ctx.last_probs.insert(c, decision.probs[p]);
                        }
                    }
                }
                decision
            }
            _ => sampler.decide_for_round(&self.cohort, &self.norms, m),
        };
        meter.add_negotiation(
            self.cohort.len(),
            decision.extra_uplink_floats_per_client,
        );
        tel.add(
            Counter::NegotiationRounds,
            decision.negotiation_rounds as u64,
        );
        tel.add(
            Counter::NegotiationUplinkFloats,
            (self.cohort.len() * decision.extra_uplink_floats_per_client)
                as u64,
        );

        // diagnostics: α^k / γ^k for this round's norm profile. For the
        // OCS/AOCS arms the decision probabilities already *are* (≈) the
        // optimal ones, so reuse them instead of solving Eq. (7) a second
        // time (§Perf L3-2); full/uniform arms still pay one solve.
        self.alpha = if self.cohort.len() > m {
            match sampler {
                // norm-adaptive arms: the decision probabilities are
                // already (≈) the round's best-effort ones — report
                // their realized variance ratio instead of solving
                // Eq. (7) a second time
                Sampler::Ocs
                | Sampler::Aocs { .. }
                | Sampler::Caocs { .. }
                | Sampler::Clustered { .. } => {
                    let vu = variance::uniform_variance(&self.norms, m);
                    if vu <= 0.0 {
                        0.0
                    } else {
                        (variance::sampling_variance(
                            &self.norms,
                            &decision.probs,
                        ) / vu)
                            .clamp(0.0, 1.0)
                    }
                }
                _ => variance::improvement_factor(&self.norms, m),
            }
        } else {
            0.0
        };
        self.gamma = variance::gamma(self.alpha, self.cohort.len(), m);
        self.selected =
            probability::draw_independent(&decision.probs, round_rng);
        tel.add(
            Counter::ClientsSelected,
            self.selected.iter().filter(|&&s| s).count() as u64,
        );
        self.decision = Some(decision);
        self.phase = Phase::SecureAggregate;
        tel.span_end(self.round, PhaseSpan::Negotiate);
    }

    /// Weighted norms of the *compressed* updates, `w_i‖C(U_i)‖` — the
    /// caocs negotiation input. Each cohort client previews its upload
    /// compression on the dedicated [`CAOCS_STREAM`] (forked per
    /// (round, client), so the evaluation order can never matter and
    /// the real upload compressor's stream is untouched). With no
    /// compressor configured the preview is the identity and caocs
    /// degrades to exactly AOCS.
    fn compressed_norms(
        &self,
        cfg: &ExperimentConfig,
        compressor: Option<&Compressor>,
    ) -> Vec<f64> {
        let Some(comp) = compressor else {
            return self.norms.clone();
        };
        let stream = Rng::new(cfg.seed ^ CAOCS_STREAM)
            .fork(self.round as u64);
        let mut dense: Vec<f32> = Vec::new();
        self.cohort
            .iter()
            .zip(&self.outcomes)
            .zip(&self.weights)
            .map(|((&c, o), &w)| {
                let mut rng = stream.fork(c as u64);
                let payload = comp.compress(&o.delta, &mut rng);
                dense.clear();
                dense.resize(o.delta.len(), 0.0);
                payload.densify_into(&mut dense);
                w * tensor::norm(&dense)
            })
            .collect()
    }

    /// (6) Participants upload `(w_i/p_i)·U_i`; shards fold their members
    /// into partial aggregates which the master tree-combines — the
    /// combine stage reduces O(shards) partials rather than folding
    /// O(participants) vectors directly. Under `secure_updates` the
    /// per-shard masked folds fan out over the runner's worker pool.
    #[allow(clippy::too_many_arguments)]
    pub fn secure_aggregate(
        &mut self,
        cfg: &ExperimentConfig,
        opts: &TrainOptions,
        registry: &Registry,
        runner: &mut dyn LocalRunner,
        faults: Option<&mut FaultCtx>,
        meter: &mut BitMeter,
        round_rng: &mut Rng,
        tel: &mut Telemetry,
    ) {
        self.expect(Phase::SecureAggregate);
        tel.span_begin(self.round, PhaseSpan::SecureAggregate);
        let dim = runner.dim();
        if cfg.secure_updates {
            // the combined ring sum stays masked-domain until Repair
            // decodes it (after any mask-residue subtraction)
            self.masked_aggregate(
                cfg, opts, registry, runner, faults, meter, round_rng, tel,
            );
        } else {
            self.aggregate = self.plain_aggregate(
                opts, registry, dim, faults, meter, round_rng, tel,
            );
        }
        tel.add(Counter::ClientsTransmitted, self.transmitted as u64);
        self.phase = Phase::Repair;
        tel.span_end(self.round, PhaseSpan::SecureAggregate);
    }

    /// The secure path: stage each participant's upload — the typed wire
    /// [`Payload`]; uncompressed deltas are moved out of their outcomes
    /// (dead after this phase) so no copy is made — into a [`MaskBatch`]
    /// grouped by owning shard, then let the runner mask + fold every
    /// group through the fused scale → encode → mask → accumulate kernel
    /// (on its worker pool if it has one). The mask fold is dense-only
    /// (pairwise masks cover every coordinate), so compressed payloads
    /// densify at the shard boundary, into each worker's scratch arena —
    /// see `aggregate::fused_masked_partial`. Ring sums commute, so the
    /// tree combine over the returned partials is bit-identical to the
    /// seed's flat sum for any shard/worker count. The compressor
    /// consumes the round RNG sequentially in cohort order, exactly as
    /// the seed protocol did; the meter records each payload's measured
    /// frame length (charging the *compressed* frame even though the
    /// simulated mask fold is dense — the accounting models a
    /// compression-compatible secure scheme, the seed's semantics; see
    /// DESIGN.md §7).
    ///
    /// Fault injection happens in the upload loop, at the point each
    /// failure occurs in a deployment: crash-before-upload skips the
    /// client entirely; crash-after-commitment keeps it in the mask
    /// roster (its pairwise masks are woven into everyone's uploads)
    /// but withholds its upload; corruption mangles the encoded frame
    /// in flight — frames failing the hardened decode or the integrity
    /// bounds quarantine the sender (also a roster member whose residue
    /// needs repair), frames that still parse fold as garbage, exactly
    /// as they would in production. The combined ring sum is stored
    /// still-masked in `masked_sum` for [`RoundMachine::repair`].
    #[allow(clippy::too_many_arguments)]
    fn masked_aggregate(
        &mut self,
        cfg: &ExperimentConfig,
        opts: &TrainOptions,
        registry: &Registry,
        runner: &mut dyn LocalRunner,
        mut faults: Option<&mut FaultCtx>,
        meter: &mut BitMeter,
        round_rng: &mut Rng,
        tel: &mut Telemetry,
    ) {
        let dim = runner.dim();
        let decision = self.decision.as_ref().expect("negotiate ran");
        let round = self.round as u64;
        let mut batch = MaskBatch {
            dim,
            round_seed: cfg.seed ^ round,
            roster: Vec::new(),
            groups: vec![Vec::new(); registry.shards()],
        };
        for (i, o) in self.outcomes.iter_mut().enumerate() {
            if !self.selected[i] {
                continue;
            }
            let mass = self.weights[i] / decision.probs[i];
            let factor = mass as f32;
            self.sel_mass += mass;
            let client = self.cohort[i] as u64;
            if let Some(ctx) = faults.as_deref_mut() {
                if ctx.plan.crash_pre(client, round) {
                    // died before upload: no masks, no bytes
                    ctx.counters.crash_pre += 1;
                    tel.add(Counter::FaultsCrashPre, 1);
                    self.lost_mass += mass;
                    continue;
                }
            }
            let payload = match &opts.compressor {
                Some(c) => c.compress(&o.delta, round_rng),
                None => Payload::Dense(std::mem::take(&mut o.delta)),
            };
            if let Some(ctx) = faults.as_deref_mut() {
                if ctx.plan.crash_post(client, round) {
                    // masks committed, upload never arrives: the roster
                    // keeps the client (everyone already wove its pair
                    // masks in); Repair subtracts the residue
                    ctx.counters.crash_post += 1;
                    tel.add(Counter::FaultsCrashPost, 1);
                    self.lost_mass += mass;
                    batch.roster.push(client);
                    self.post_dropped.push(client);
                    continue;
                }
                if ctx.plan.corrupts(client, round) {
                    ctx.counters.corrupt += 1;
                    tel.add(Counter::FaultsCorrupt, 1);
                    let mut frame = Vec::new();
                    payload.encode_into(&mut frame);
                    let mut frng = ctx.plan.corruption_rng(client, round);
                    faults::corrupt_frame(&mut frame, &mut frng);
                    let checked = Payload::decode(&frame)
                        .and_then(|p| p.validate_for_dim(dim).map(|_| p))
                        .ok()
                        .filter(|p| {
                            p.max_abs() * factor.abs()
                                < RING_SAFE_MAGNITUDE
                        });
                    match checked {
                        Some(p) => {
                            // mutation survived every integrity check:
                            // it folds (and is metered) like any upload
                            meter.add_payload(&p);
                            tel.payload(&p);
                            batch.roster.push(client);
                            batch.groups
                                [registry.shard_of(self.cohort[i])]
                            .push(MaskUpload {
                                client,
                                factor,
                                payload: p,
                            });
                        }
                        None => {
                            // quarantined — but its masks committed, so
                            // like a post-commit dropout it stays on the
                            // roster and leaves residue to repair
                            ctx.counters.quarantined += 1;
                            tel.add(Counter::ClientsQuarantined, 1);
                            self.lost_mass += mass;
                            batch.roster.push(client);
                            self.post_dropped.push(client);
                        }
                    }
                    continue;
                }
            }
            meter.add_payload(&payload);
            tel.payload(&payload);
            batch.roster.push(client);
            batch.groups[registry.shard_of(self.cohort[i])]
                .push(MaskUpload { client, factor, payload });
        }
        self.transmitted = batch.roster.len() - self.post_dropped.len();
        if batch.roster.is_empty() {
            self.aggregate = vec![0.0; dim];
            return;
        }
        self.mask_roster = batch.roster.clone();
        // shards with no participants are dropped — their partials would
        // merge as no-ops
        batch.groups.retain(|g| !g.is_empty());
        if batch.groups.is_empty() {
            // every roster member dropped after committing masks: no
            // upload exists, so there is no ring sum to repair — the
            // round contributes nothing
            self.aggregate = vec![0.0; dim];
            return;
        }
        let partials: Vec<ShardPartial> = runner
            .secure_partials(batch)
            .into_iter()
            .map(ShardPartial::Masked)
            .collect();
        tel.collect_jobs(self.round, &mut |buf| runner.drain_timings(buf));
        match aggregate::tree_reduce(partials)
            .expect("some shard has a participant")
        {
            ShardPartial::Masked(sum) => self.masked_sum = Some(sum),
            ShardPartial::Plain(_) => {
                unreachable!("masked path produced a plain partial")
            }
        }
    }

    /// The plain-f32 path: uploads in cohort order (cohort position,
    /// wire payload, upload factor w_i/p_i). Uncompressed deltas are
    /// moved into dense payloads, not cloned; compressed uploads stay
    /// native end to end — sparse/quantized payloads fold into the shard
    /// partials through the scatter-add kernels without ever densifying
    /// (`aggregate::payload_weighted_partial`; bit-exact to the retained
    /// densify-then-accumulate reference, selectable via
    /// `TrainOptions::densify_folds` as the baseline arm). The meter
    /// records each payload's measured frame length.
    ///
    /// Fault injection mirrors the masked path, minus the mask-roster
    /// bookkeeping (no masks exist here): crashed clients simply never
    /// upload, quarantined clients are excluded, and mutations that
    /// survive the integrity checks fold as garbage.
    #[allow(clippy::too_many_arguments)]
    fn plain_aggregate(
        &mut self,
        opts: &TrainOptions,
        registry: &Registry,
        dim: usize,
        mut faults: Option<&mut FaultCtx>,
        meter: &mut BitMeter,
        round_rng: &mut Rng,
        tel: &mut Telemetry,
    ) -> Vec<f32> {
        let decision = self.decision.as_ref().expect("negotiate ran");
        let round = self.round as u64;
        let mut uploads: Vec<(usize, Payload, f32)> = Vec::new();
        for (i, o) in self.outcomes.iter_mut().enumerate() {
            if !self.selected[i] {
                continue;
            }
            let mass = self.weights[i] / decision.probs[i];
            let factor = mass as f32;
            self.sel_mass += mass;
            let client = self.cohort[i] as u64;
            if let Some(ctx) = faults.as_deref_mut() {
                if ctx.plan.crash_pre(client, round) {
                    ctx.counters.crash_pre += 1;
                    tel.add(Counter::FaultsCrashPre, 1);
                    self.lost_mass += mass;
                    continue;
                }
            }
            let payload = match &opts.compressor {
                Some(c) => c.compress(&o.delta, round_rng),
                None => Payload::Dense(std::mem::take(&mut o.delta)),
            };
            if let Some(ctx) = faults.as_deref_mut() {
                if ctx.plan.crash_post(client, round) {
                    // no masks on this path: the crash is pure absence
                    ctx.counters.crash_post += 1;
                    tel.add(Counter::FaultsCrashPost, 1);
                    self.lost_mass += mass;
                    continue;
                }
                if ctx.plan.corrupts(client, round) {
                    ctx.counters.corrupt += 1;
                    tel.add(Counter::FaultsCorrupt, 1);
                    let mut frame = Vec::new();
                    payload.encode_into(&mut frame);
                    let mut frng = ctx.plan.corruption_rng(client, round);
                    faults::corrupt_frame(&mut frame, &mut frng);
                    let checked = Payload::decode(&frame)
                        .and_then(|p| p.validate_for_dim(dim).map(|_| p))
                        .ok()
                        .filter(|p| {
                            p.max_abs() * factor.abs()
                                < RING_SAFE_MAGNITUDE
                        });
                    match checked {
                        Some(p) => {
                            meter.add_payload(&p);
                            tel.payload(&p);
                            uploads.push((i, p, factor));
                        }
                        None => {
                            ctx.counters.quarantined += 1;
                            tel.add(Counter::ClientsQuarantined, 1);
                            self.lost_mass += mass;
                        }
                    }
                    continue;
                }
            }
            meter.add_payload(&payload);
            tel.payload(&payload);
            uploads.push((i, payload, factor));
        }
        let transmitted = uploads.len();

        let out = if uploads.is_empty() {
            vec![0.0; dim]
        } else {
            // group participants by owning shard in one pass (cohort
            // order preserved within each group); empty shards skipped
            let cohort = &self.cohort;
            let mut by_shard: Vec<Vec<usize>> =
                vec![Vec::new(); registry.shards()];
            for (k, (i, _, _)) in uploads.iter().enumerate() {
                by_shard[registry.shard_of(cohort[*i])].push(k);
            }
            let partials: Vec<ShardPartial> = by_shard
                .iter()
                .filter(|group| !group.is_empty())
                .map(|group| {
                    let members: Vec<&Payload> =
                        group.iter().map(|&k| &uploads[k].1).collect();
                    let weights: Vec<f32> =
                        group.iter().map(|&k| uploads[k].2).collect();
                    if opts.densify_folds {
                        aggregate::densified_weighted_partial(
                            dim, &members, &weights,
                        )
                    } else {
                        aggregate::payload_weighted_partial(
                            dim, &members, &weights,
                        )
                    }
                })
                .collect();
            aggregate::finish(
                aggregate::tree_reduce(partials)
                    .expect("some shard has a participant"),
            )
        };
        self.transmitted = transmitted;
        out
    }

    /// (7) Repair: recover from whatever the round's faults broke, then
    /// hand the (now plain-f32) aggregate to Commit. Three actions, each
    /// a no-op when its trigger is absent:
    ///
    /// * **Mask-residue subtraction** — roster members whose upload never
    ///   arrived (crash-after-commitment, quarantine) left uncancelled
    ///   pairwise masks in the ring sum; reconstruct each survivor↔drop
    ///   pair stream and subtract it
    ///   ([`SecureAggregator::recover`]), then decode. The subtraction
    ///   happens in the exact ring, so the repaired aggregate is
    ///   **bitwise** the plain fixed-point aggregation over the
    ///   survivors.
    /// * **Estimator renormalization** — the w_i/p_i estimator lost the
    ///   mass of failed participants; rescale the aggregate by
    ///   `sel_mass / surviving_mass` so its expectation stays anchored
    ///   to the full selected set.
    /// * **Empty-survivor guard** — when no participant's contribution
    ///   survived, the round commits a no-op update (zero aggregate)
    ///   rather than renormalizing over an empty set.
    ///
    /// With no faults the phase decodes the ring sum and nothing else —
    /// bitwise identical to the pre-Repair pipeline (`lost_mass` is
    /// exactly 0.0, so not a single float op touches the aggregate).
    pub fn repair(
        &mut self,
        cfg: &ExperimentConfig,
        faults: Option<&mut FaultCtx>,
        tel: &mut Telemetry,
    ) {
        self.expect(Phase::Repair);
        tel.span_begin(self.round, PhaseSpan::Repair);
        if let Some(mut sum) = self.masked_sum.take() {
            if !self.post_dropped.is_empty() {
                let survivors: Vec<u64> = self
                    .mask_roster
                    .iter()
                    .copied()
                    .filter(|c| !self.post_dropped.contains(c))
                    .collect();
                SecureAggregator::new(cfg.seed ^ self.round as u64)
                    .recover(&mut sum, &survivors, &self.post_dropped);
                let repairs = self.post_dropped.len() as u64;
                if let Some(ctx) = faults {
                    ctx.counters.mask_repairs += repairs;
                }
                tel.add(Counter::MaskRepairs, repairs);
            }
            self.aggregate = SecureAggregator::decode_sum(&sum);
        }
        if self.lost_mass > 0.0 {
            let surviving = self.sel_mass - self.lost_mass;
            if surviving <= 0.0 || self.transmitted == 0 {
                // nothing survived: a no-op round, not a division by the
                // empty set
                self.aggregate.iter_mut().for_each(|v| *v = 0.0);
            } else {
                let scale = (self.sel_mass / surviving) as f32;
                tensor::scale(&mut self.aggregate, scale);
            }
        }
        self.phase = Phase::Commit;
        tel.span_end(self.round, PhaseSpan::Repair);
    }

    /// (8)+(9) Master update, divergence guard, metrics and (periodic)
    /// evaluation. Consumes the phase; the machine ends in `Done`.
    #[allow(clippy::too_many_arguments)]
    pub fn commit(
        &mut self,
        cfg: &ExperimentConfig,
        opts: &TrainOptions,
        eta_g: f64,
        x: &mut [f32],
        runner: &mut dyn LocalRunner,
        meter: &BitMeter,
        tel: &mut Telemetry,
    ) -> Result<RoundRecord, String> {
        self.expect(Phase::Commit);
        tel.span_begin(self.round, PhaseSpan::Commit);
        let round = self.round;
        // fused master update + finiteness probe: Σx'² is finite iff
        // every updated parameter is (finite f32 squares cannot overflow
        // the f64 accumulator; NaN/Inf poison it)
        let updated_norm_sq =
            kernels::axpy_norm_sq(x, -(eta_g as f32), &self.aggregate);
        if !updated_norm_sq.is_finite() {
            return Err(format!(
                "{}: divergence at round {round} (non-finite parameters); \
                 reduce the step size",
                cfg.name
            ));
        }

        let train_loss: f64 = self
            .outcomes
            .iter()
            .zip(&self.weights)
            .map(|(o, &w)| w * o.train_loss)
            .sum();
        let val = if round % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            runner.evaluate(x)
        } else {
            EvalOutcome { loss: f64::NAN, accuracy: f64::NAN }
        };
        let transmitted = self.transmitted;
        let alpha = self.alpha;
        if opts.verbose_every > 0 && round % opts.verbose_every == 0 {
            println!(
                "[{}] round {round:>4}  loss {train_loss:.4}  acc {}  \
                 bits {:.3e}  sent {transmitted}/{} α {alpha:.3}",
                cfg.name,
                if val.accuracy.is_nan() {
                    "  -  ".to_string()
                } else {
                    format!("{:.3}", val.accuracy)
                },
                meter.total_bits() as f64,
                self.cohort.len(),
            );
        }
        let decision = self.decision.as_ref().expect("negotiate ran");
        self.phase = Phase::Done;
        tel.span_end(self.round, PhaseSpan::Commit);
        tel.flush_round(round);
        Ok(RoundRecord {
            round,
            train_loss,
            val_accuracy: val.accuracy,
            uplink_bits: meter.total_bits(),
            uplink_bytes: meter.total_bytes(),
            transmitted,
            expected_budget: probability::expected_size(&decision.probs),
            alpha,
            gamma: self.gamma,
        })
    }
}

/// The record a round with no reachable clients leaves behind (identical
/// to the seed protocol's no-op round). No-op rounds still hit the
/// checkpoint cadence: the snapshot after a no-op captures this record,
/// so a resume replays hostile-availability stretches bit-exactly.
pub fn noop_record(round: usize, meter: &BitMeter) -> RoundRecord {
    RoundRecord {
        round,
        train_loss: f64::NAN,
        val_accuracy: f64::NAN,
        uplink_bits: meter.total_bits(),
        uplink_bytes: meter.total_bytes(),
        transmitted: 0,
        expected_budget: 0.0,
        alpha: f64::NAN,
        gamma: f64::NAN,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, DataSpec, Strategy};
    use crate::faults::{FaultCtx, FaultPlan};

    struct FixedRunner {
        dim: usize,
        n: usize,
    }

    impl LocalRunner for FixedRunner {
        fn dim(&self) -> usize {
            self.dim
        }
        fn num_clients(&self) -> usize {
            self.n
        }
        fn init_params(&mut self, _seed: u64) -> Vec<f32> {
            vec![0.0; self.dim]
        }
        fn run_shards(
            &mut self,
            _round: usize,
            _global: &[f32],
            shard_cohorts: &[Vec<usize>],
        ) -> Vec<Vec<LocalOutcome>> {
            shard_cohorts
                .iter()
                .map(|cs| {
                    cs.iter()
                        .map(|&c| LocalOutcome {
                            delta: vec![(c + 1) as f32; self.dim],
                            train_loss: 1.0 + c as f64,
                            examples: 10 + c,
                        })
                        .collect()
                })
                .collect()
        }
        fn evaluate(&mut self, _global: &[f32]) -> EvalOutcome {
            EvalOutcome { loss: 0.25, accuracy: 0.75 }
        }
    }

    fn cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "round_test".into(),
            seed: 5,
            rounds: 4,
            cohort: 6,
            budget: 3,
            strategy: Strategy::Ocs,
            algorithm: Algorithm::Dsgd { eta: 0.1 },
            data: DataSpec::FemnistLike { pool: 0, variant: 0 },
            model: "native:test".into(),
            batch_size: 1,
            eval_every: 1,
            eval_examples: 1,
            workers: 1,
            secure_updates: true,
            availability: 1.0,
            availability_trace: None,
            compressor: None,
            fault_plan: None,
        }
    }

    fn run_one_round(shards: usize) -> (RoundRecord, Vec<f32>) {
        run_one_round_with(shards, None)
    }

    fn run_one_round_with(
        shards: usize,
        mut faults: Option<&mut FaultCtx>,
    ) -> (RoundRecord, Vec<f32>) {
        let c = cfg();
        let mut runner = FixedRunner { dim: 4, n: 12 };
        let registry = Registry::new(12, shards);
        let avail = Availability::AlwaysOn;
        let sampler = Sampler::from_strategy(&c.strategy);
        let mut meter = BitMeter::new();
        let rng = Rng::new(c.seed).fork(0xF1);
        let mut round_rng = rng.fork(0);
        let mut x = runner.init_params(c.seed);
        let opts = TrainOptions::default();

        let mut tel = Telemetry::disabled();
        let mut m = RoundMachine::new(0);
        assert_eq!(m.phase(), Phase::Announce);
        m.announce(&c, &avail, &registry, None, &mut round_rng, &mut tel);
        assert_eq!(m.phase(), Phase::LocalCompute);
        m.local_compute(&mut runner, &x, &mut tel);
        assert_eq!(m.phase(), Phase::NormReport);
        m.norm_report(&mut tel);
        assert_eq!(m.phase(), Phase::Negotiate);
        m.negotiate(
            &sampler,
            &c,
            None,
            None,
            faults.as_deref_mut(),
            &mut meter,
            &mut round_rng,
            &mut tel,
        );
        assert_eq!(m.phase(), Phase::SecureAggregate);
        m.secure_aggregate(
            &c,
            &opts,
            &registry,
            &mut runner,
            faults.as_deref_mut(),
            &mut meter,
            &mut round_rng,
            &mut tel,
        );
        assert_eq!(m.phase(), Phase::Repair);
        m.repair(&c, faults.as_deref_mut(), &mut tel);
        assert_eq!(m.phase(), Phase::Commit);
        let rec = m
            .commit(&c, &opts, 0.1, &mut x, &mut runner, &meter, &mut tel)
            .unwrap();
        assert_eq!(m.phase(), Phase::Done);
        (rec, x)
    }

    #[test]
    fn phases_run_in_declared_order() {
        let (rec, x) = run_one_round(1);
        assert_eq!(rec.round, 0);
        assert!(rec.train_loss.is_finite());
        assert_eq!(rec.val_accuracy, 0.75);
        assert!(rec.expected_budget <= 3.0 + 1e-9);
        assert!(rec.transmitted <= 6);
        assert_eq!(x.len(), 4);
    }

    #[test]
    fn sharding_preserves_the_masked_round_exactly() {
        let (r1, x1) = run_one_round(1);
        let (r4, x4) = run_one_round(4);
        assert_eq!(r1.train_loss, r4.train_loss);
        assert_eq!(r1.uplink_bits, r4.uplink_bits);
        assert_eq!(r1.transmitted, r4.transmitted);
        assert_eq!(x1, x4);
    }

    /// Drive a round through Negotiate under `strategy` (single shard,
    /// no compressor) and return the decision probabilities.
    fn negotiated_probs(strategy: Strategy) -> Vec<f64> {
        let mut c = cfg();
        c.strategy = strategy;
        let mut runner = FixedRunner { dim: 4, n: 12 };
        let registry = Registry::new(12, 1);
        let avail = Availability::AlwaysOn;
        let sampler = Sampler::from_strategy(&c.strategy);
        let mut meter = BitMeter::new();
        let mut round_rng = Rng::new(c.seed).fork(0xF1).fork(0);
        let x = runner.init_params(c.seed);
        let mut tel = Telemetry::disabled();
        let mut m = RoundMachine::new(0);
        m.announce(&c, &avail, &registry, None, &mut round_rng, &mut tel);
        m.local_compute(&mut runner, &x, &mut tel);
        m.norm_report(&mut tel);
        m.negotiate(
            &sampler,
            &c,
            None,
            None,
            None,
            &mut meter,
            &mut round_rng,
            &mut tel,
        );
        m.decision.clone().expect("negotiated").probs
    }

    #[test]
    fn caocs_without_compressor_negotiates_exactly_as_aocs() {
        // the preview is the identity when no compressor is configured,
        // so the two strategies must be bitwise indistinguishable
        let a = negotiated_probs(Strategy::Aocs { j_max: 4 });
        let ca = negotiated_probs(Strategy::Caocs { j_max: 4 });
        assert_eq!(a, ca);
    }

    #[test]
    fn cyclic_announce_admits_exactly_the_scheduled_group() {
        let g = 3usize;
        let mut c = cfg();
        c.strategy = Strategy::Cyclic { g };
        c.cohort = 12; // cohort == pool + always-on: no uniform draw
        let registry = Registry::new(12, 2);
        let avail = Availability::AlwaysOn;
        let mut tel = Telemetry::disabled();
        let mut seen = vec![0usize; 12];
        for round in 0..g {
            let mut rng = Rng::new(c.seed).fork(round as u64);
            let mut m = RoundMachine::new(round);
            m.announce(&c, &avail, &registry, None, &mut rng, &mut tel);
            for &client in &m.cohort {
                assert_eq!(
                    cyclic::group_of(c.seed, client, g),
                    cyclic::active_group(round, g),
                    "client {client} admitted off-schedule in round {round}"
                );
                seen[client] += 1;
            }
        }
        // conservation: one full cycle visits every client exactly once
        assert_eq!(seen, vec![1usize; 12], "{seen:?}");
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn out_of_order_phase_panics() {
        let c = cfg();
        let sampler = Sampler::Ocs;
        let mut meter = BitMeter::new();
        let mut rng = Rng::new(1);
        let mut m = RoundMachine::new(0);
        // negotiate before announce/local_compute must refuse
        m.negotiate(
            &sampler,
            &c,
            None,
            None,
            None,
            &mut meter,
            &mut rng,
            &mut Telemetry::disabled(),
        );
    }

    /// Drive a full secure round (single shard) with a chaos context,
    /// stopping after Repair so the machine's internals stay inspectable.
    fn drive_secure_round(
        c: &ExperimentConfig,
        ctx: &mut FaultCtx,
    ) -> RoundMachine {
        let mut runner = FixedRunner { dim: 4, n: 12 };
        let registry = Registry::new(12, 1);
        let avail = Availability::AlwaysOn;
        let sampler = Sampler::from_strategy(&c.strategy);
        let mut meter = BitMeter::new();
        let rng = Rng::new(c.seed).fork(0xF1);
        let mut round_rng = rng.fork(0);
        let opts = TrainOptions::default();
        let mut tel = Telemetry::disabled();
        let mut m = RoundMachine::new(0);
        m.announce(c, &avail, &registry, None, &mut round_rng, &mut tel);
        m.local_compute(&mut runner, &[0.0; 4], &mut tel);
        m.norm_report(&mut tel);
        m.negotiate(
            &sampler,
            c,
            None,
            None,
            Some(ctx),
            &mut meter,
            &mut round_rng,
            &mut tel,
        );
        m.secure_aggregate(
            c,
            &opts,
            &registry,
            &mut runner,
            Some(ctx),
            &mut meter,
            &mut round_rng,
            &mut tel,
        );
        m.repair(c, Some(ctx), &mut tel);
        m
    }

    #[test]
    fn zero_rate_chaos_context_is_bitwise_inert() {
        let (rec_ref, x_ref) = run_one_round(1);
        let mut ctx = FaultCtx::new(FaultPlan::new(123));
        let (rec, x) = run_one_round_with(1, Some(&mut ctx));
        assert_eq!(rec.train_loss.to_bits(), rec_ref.train_loss.to_bits());
        assert_eq!(rec.uplink_bits, rec_ref.uplink_bits);
        assert_eq!(rec.transmitted, rec_ref.transmitted);
        let a: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = x_ref.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!(ctx.counters.injected(), 0);
        assert_eq!(ctx.counters.repaired(), 0);
    }

    #[test]
    fn chaos_wipeout_commits_a_noop_update() {
        // every selected client crashes before upload: the round must
        // commit an unchanged model, not renormalize over an empty set
        let plan = FaultPlan { crash_pre: 1.0, ..FaultPlan::new(1) };
        let mut ctx = FaultCtx::new(plan);
        let (rec, x) = run_one_round_with(1, Some(&mut ctx));
        assert!(ctx.counters.crash_pre > 0);
        assert_eq!(rec.transmitted, 0);
        assert!(rec.train_loss.is_finite());
        assert_eq!(x, vec![0.0; 4], "zero aggregate must not move x");
    }

    #[test]
    fn post_commit_dropout_repair_is_bitwise_survivor_aggregation() {
        // the tentpole's secure-path acceptance property: subtracting
        // the uncancelled mask residue of post-commit dropouts leaves
        // exactly the plain fixed-point fold over the survivors
        let c = cfg();
        let mut found = false;
        for seed in 0..64 {
            let plan =
                FaultPlan { crash_post: 0.5, ..FaultPlan::new(seed) };
            let mut ctx = FaultCtx::new(plan.clone());
            let m = drive_secure_round(&c, &mut ctx);
            if ctx.counters.crash_post == 0 || m.transmitted == 0 {
                continue; // need a partial dropout, not none/all
            }
            found = true;
            assert_eq!(
                ctx.counters.mask_repairs,
                ctx.counters.crash_post
            );
            // expected: survivors' uploads encode-folded with no masks
            // at all, then the same surviving-mass renormalization
            let probs = &m.decision.as_ref().unwrap().probs;
            let mut ring = vec![0u64; 4];
            let mut streams = Vec::new();
            let mut block = Vec::new();
            for (i, &sel) in m.selected.iter().enumerate() {
                if !sel {
                    continue;
                }
                let client = m.cohort[i] as u64;
                if plan.crash_post(client, 0) {
                    continue;
                }
                let factor = (m.weights[i] / probs[i]) as f32;
                let delta = vec![(m.cohort[i] + 1) as f32; 4];
                kernels::scale_encode_mask_accumulate(
                    &mut ring, &delta, factor, &mut streams, &mut block,
                );
            }
            let mut want = SecureAggregator::decode_sum(&ring);
            let scale =
                (m.sel_mass / (m.sel_mass - m.lost_mass)) as f32;
            tensor::scale(&mut want, scale);
            let got: Vec<u32> =
                m.aggregate.iter().map(|v| v.to_bits()).collect();
            let want: Vec<u32> =
                want.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "plan seed {seed}");
            break;
        }
        assert!(found, "no plan seed produced a partial dropout");
    }

    #[test]
    fn full_shard_dropout_yields_noop_round() {
        let c = cfg();
        let registry = Registry::new(12, 3);
        let avail = Availability::AlwaysOn;
        let rng = Rng::new(c.seed).fork(0xF1);
        let mut round_rng = rng.fork(0);
        let mut m = RoundMachine::new(0);
        let policy = DeadlinePolicy { miss_prob: 1.0 };
        let dropped = m.announce(
            &c,
            &avail,
            &registry,
            Some(&policy),
            &mut round_rng,
            &mut Telemetry::disabled(),
        );
        assert_eq!(dropped, 3);
        assert!(m.cohort().is_empty());
        assert_eq!(m.phase(), Phase::Done);
        let rec = noop_record(0, &BitMeter::new());
        assert!(rec.train_loss.is_nan());
        assert_eq!(rec.transmitted, 0);
    }
}
