//! Shard execution: how a shard's slice of the cohort actually runs its
//! local work.
//!
//! Two runners implement [`LocalRunner`]:
//!
//! * [`EngineRunner`] — adapts any legacy [`ClientEngine`] (the XLA
//!   engine, test toys). Shards run sequentially through the engine's
//!   own `run_local`; the XLA engine parallelizes internally with its
//!   PJRT worker pool, so nothing is lost.
//! * [`ParallelRunner`] — owns a persistent worker-thread pool (the
//!   channel pattern of [`crate::runtime::engine`]: shared job queue
//!   behind a mutex, plain-data replies) over a [`ClientCompute`]
//!   backend. Results are placed by (shard, position), so trajectories
//!   are independent of thread scheduling.
//!
//! The pool runs two job kinds: a client's local pass, and a shard
//! group's secure-aggregation masked fold (`LocalRunner::secure_partials`
//! — ring sums commute, so fanning the folds across workers is
//! bit-exact; see DESIGN.md §6).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::fl::{ClientEngine, EvalOutcome, LocalOutcome};
use crate::secure_agg::SecureAggregator;
use crate::tensor::kernels::Scratch;

use super::aggregate::{fused_masked_partial, MaskBatch};

/// One shard's sharded-negotiation inputs: `(client id, scalar)` pairs
/// to be securely summed (see [`LocalRunner::negotiation_partials`]).
pub type ScalarGroup = Vec<(u64, f32)>;

/// What the round state machine needs from an execution backend.
pub trait LocalRunner {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;
    /// Total pool size.
    fn num_clients(&self) -> usize;
    /// Initial global parameters.
    fn init_params(&mut self, seed: u64) -> Vec<f32>;
    /// Run local work for every shard's cohort slice; the result must be
    /// aligned with `shard_cohorts` (outer: shard, inner: member order).
    fn run_shards(
        &mut self,
        round: usize,
        global: &[f32],
        shard_cohorts: &[Vec<usize>],
    ) -> Vec<Vec<LocalOutcome>>;
    /// Secure-aggregation fan-out: mask + fold every shard group of
    /// `batch` into a ring partial (one per group, aligned with
    /// `batch.groups`). Ring sums commute, so *where* each group is
    /// folded never changes the combined bits. The default runs the
    /// fused kernel sequentially on the calling thread; pooled runners
    /// distribute groups over their workers.
    fn secure_partials(&mut self, batch: MaskBatch) -> Vec<Vec<u64>> {
        let mut scratch = Scratch::new();
        batch
            .groups
            .iter()
            .map(|g| fused_masked_partial(&batch, g, &mut scratch))
            .collect()
    }
    /// Sharded-AOCS negotiation fan-out (Algorithm 2 run shard-locally):
    /// securely sum each shard group's `(client id, scalar)` pairs —
    /// masked through [`crate::secure_agg::SecureAggregator`] with the
    /// group as the roster, so the master only ever sees per-shard sums
    /// — returning one partial per group, aligned with `groups`.
    /// Fixed-point ring sums are exact, so *where* a group is folded
    /// never changes its bits. The default runs sequentially on the
    /// calling thread; pooled runners distribute groups over their
    /// workers.
    fn negotiation_partials(
        &mut self,
        round_seed: u64,
        groups: &[ScalarGroup],
    ) -> Vec<f32> {
        let agg = SecureAggregator::new(round_seed);
        groups.iter().map(|g| agg.aggregate_scalars(g)).collect()
    }
    /// Evaluate global parameters on the validation split.
    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome;
}

/// A thread-shareable per-client compute backend (the sim engines). One
/// client's local pass must depend only on `(round, client, global)` so
/// any worker can run any job. `scratch` is the caller-owned buffer
/// arena — each pool worker owns exactly one, allocated at spawn and
/// reused for every job it runs (results must not depend on prior
/// scratch contents).
pub trait ClientCompute: Send + Sync + 'static {
    fn dim(&self) -> usize;
    fn num_clients(&self) -> usize;
    fn init_params(&self, seed: u64) -> Vec<f32>;
    fn local_one(
        &self,
        round: usize,
        global: &[f32],
        client: usize,
        scratch: &mut Scratch,
    ) -> LocalOutcome;
    fn evaluate(&self, global: &[f32]) -> EvalOutcome;
}

// ---------------------------------------------------------------------------
// legacy-engine adapter
// ---------------------------------------------------------------------------

/// [`LocalRunner`] over a `&mut dyn ClientEngine` (single-threaded per
/// shard; the engine may parallelize internally). Owns one scratch arena
/// for the masked fold, allocated once for the runner's lifetime.
pub struct EngineRunner<'a> {
    engine: &'a mut dyn ClientEngine,
    scratch: Scratch,
}

impl<'a> EngineRunner<'a> {
    pub fn new(engine: &'a mut dyn ClientEngine) -> EngineRunner<'a> {
        EngineRunner { engine, scratch: Scratch::new() }
    }
}

impl LocalRunner for EngineRunner<'_> {
    fn dim(&self) -> usize {
        self.engine.dim()
    }

    fn num_clients(&self) -> usize {
        self.engine.num_clients()
    }

    fn init_params(&mut self, seed: u64) -> Vec<f32> {
        self.engine.init_params(seed)
    }

    fn run_shards(
        &mut self,
        round: usize,
        global: &[f32],
        shard_cohorts: &[Vec<usize>],
    ) -> Vec<Vec<LocalOutcome>> {
        shard_cohorts
            .iter()
            .map(|clients| {
                if clients.is_empty() {
                    return Vec::new();
                }
                let outs = self.engine.run_local(round, global, clients);
                assert_eq!(
                    outs.len(),
                    clients.len(),
                    "engine cohort mismatch"
                );
                outs
            })
            .collect()
    }

    fn secure_partials(&mut self, batch: MaskBatch) -> Vec<Vec<u64>> {
        batch
            .groups
            .iter()
            .map(|g| fused_masked_partial(&batch, g, &mut self.scratch))
            .collect()
    }

    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome {
        self.engine.evaluate(global)
    }
}

// ---------------------------------------------------------------------------
// worker pool (channel pattern from runtime::engine)
// ---------------------------------------------------------------------------

/// The job kinds a pool worker runs: one client's local pass, one shard
/// group's masked vector fold (secure aggregation), or one shard
/// group's masked scalar fold (the sharded AOCS negotiation). The first
/// two use the worker's own scratch arena.
enum ShardJob {
    Local {
        shard: usize,
        pos: usize,
        client: usize,
        round: usize,
        global: Arc<Vec<f32>>,
    },
    MaskFold {
        group: usize,
        batch: Arc<MaskBatch>,
    },
    ScalarFold {
        group: usize,
        round_seed: u64,
        groups: Arc<Vec<ScalarGroup>>,
    },
}

enum ShardReply {
    Local {
        shard: usize,
        pos: usize,
        outcome: LocalOutcome,
    },
    MaskFold {
        group: usize,
        partial: Vec<u64>,
    },
    ScalarFold {
        group: usize,
        sum: f32,
    },
}

struct ShardPool {
    jobs: mpsc::Sender<ShardJob>,
    replies: mpsc::Receiver<ShardReply>,
    handles: Vec<JoinHandle<()>>,
}

fn recv_job(
    rx: &Arc<Mutex<mpsc::Receiver<ShardJob>>>,
) -> Result<ShardJob, mpsc::RecvError> {
    rx.lock().expect("shard job queue poisoned").recv()
}

impl ShardPool {
    fn spawn<C: ClientCompute>(workers: usize, compute: Arc<C>) -> ShardPool {
        let (job_tx, job_rx) = mpsc::channel::<ShardJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (rep_tx, rep_rx) = mpsc::channel::<ShardReply>();
        let handles = (0..workers)
            .map(|_| {
                let job_rx = Arc::clone(&job_rx);
                let rep_tx = rep_tx.clone();
                let compute = Arc::clone(&compute);
                std::thread::spawn(move || {
                    // one arena per worker, alive for the pool's lifetime
                    let mut scratch = Scratch::new();
                    while let Ok(job) = recv_job(&job_rx) {
                        let reply = match job {
                            ShardJob::Local {
                                shard,
                                pos,
                                client,
                                round,
                                global,
                            } => {
                                let outcome = compute.local_one(
                                    round,
                                    &global,
                                    client,
                                    &mut scratch,
                                );
                                ShardReply::Local { shard, pos, outcome }
                            }
                            ShardJob::MaskFold { group, batch } => {
                                let partial = fused_masked_partial(
                                    &batch,
                                    &batch.groups[group],
                                    &mut scratch,
                                );
                                ShardReply::MaskFold { group, partial }
                            }
                            ShardJob::ScalarFold {
                                group,
                                round_seed,
                                groups,
                            } => {
                                let sum = SecureAggregator::new(round_seed)
                                    .aggregate_scalars(&groups[group]);
                                ShardReply::ScalarFold { group, sum }
                            }
                        };
                        if rep_tx.send(reply).is_err() {
                            break;
                        }
                    }
                })
            })
            .collect();
        ShardPool { jobs: job_tx, replies: rep_rx, handles }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // closing the channel stops the workers
        let (dead_tx, _) = mpsc::channel();
        self.jobs = dead_tx;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// parallel runner
// ---------------------------------------------------------------------------

/// [`LocalRunner`] that fans shard cohorts out over a persistent worker
/// pool. `workers <= 1` runs inline on the calling thread (identical
/// results — placement is by index, never by completion order).
pub struct ParallelRunner<C: ClientCompute> {
    compute: Arc<C>,
    pool: Option<ShardPool>,
    /// arena for the inline (workers <= 1) path
    scratch: Scratch,
}

impl<C: ClientCompute> ParallelRunner<C> {
    pub fn new(compute: C, workers: usize) -> ParallelRunner<C> {
        let compute = Arc::new(compute);
        let pool = if workers > 1 {
            Some(ShardPool::spawn(workers, Arc::clone(&compute)))
        } else {
            None
        };
        ParallelRunner { compute, pool, scratch: Scratch::new() }
    }

    /// Shared access to the underlying compute backend.
    pub fn compute(&self) -> &C {
        &self.compute
    }
}

impl<C: ClientCompute> LocalRunner for ParallelRunner<C> {
    fn dim(&self) -> usize {
        self.compute.dim()
    }

    fn num_clients(&self) -> usize {
        self.compute.num_clients()
    }

    fn init_params(&mut self, seed: u64) -> Vec<f32> {
        self.compute.init_params(seed)
    }

    fn run_shards(
        &mut self,
        round: usize,
        global: &[f32],
        shard_cohorts: &[Vec<usize>],
    ) -> Vec<Vec<LocalOutcome>> {
        let Some(pool) = &self.pool else {
            // inline path: one scratch arena, owned by the runner
            let mut out = Vec::with_capacity(shard_cohorts.len());
            for clients in shard_cohorts {
                let mut shard_out = Vec::with_capacity(clients.len());
                for &c in clients {
                    shard_out.push(self.compute.local_one(
                        round,
                        global,
                        c,
                        &mut self.scratch,
                    ));
                }
                out.push(shard_out);
            }
            return out;
        };
        let global = Arc::new(global.to_vec());
        let mut total = 0usize;
        for (shard, clients) in shard_cohorts.iter().enumerate() {
            for (pos, &client) in clients.iter().enumerate() {
                pool.jobs
                    .send(ShardJob::Local {
                        shard,
                        pos,
                        client,
                        round,
                        global: Arc::clone(&global),
                    })
                    .expect("shard pool dead");
                total += 1;
            }
        }
        let mut out: Vec<Vec<Option<LocalOutcome>>> =
            shard_cohorts.iter().map(|c| vec![None; c.len()]).collect();
        for _ in 0..total {
            match pool.replies.recv().expect("shard pool dead") {
                ShardReply::Local { shard, pos, outcome } => {
                    debug_assert!(out[shard][pos].is_none());
                    out[shard][pos] = Some(outcome);
                }
                _ => panic!("fold reply during local compute"),
            }
        }
        out.into_iter()
            .map(|v| v.into_iter().map(Option::unwrap).collect())
            .collect()
    }

    /// Fan the per-shard masked folds out over the worker pool: one
    /// `MaskFold` job per group, each worker folding its group
    /// into one ring accumulator with its own scratch arena. Partials
    /// land by group index, and ring sums commute, so the combined
    /// result is bit-identical to the sequential fold for any worker
    /// count or completion order.
    fn secure_partials(&mut self, batch: MaskBatch) -> Vec<Vec<u64>> {
        let Some(pool) = &self.pool else {
            // inline path: the runner-owned arena, as in run_shards
            let mut out = Vec::with_capacity(batch.groups.len());
            for g in &batch.groups {
                out.push(fused_masked_partial(&batch, g, &mut self.scratch));
            }
            return out;
        };
        let total = batch.groups.len();
        let batch = Arc::new(batch);
        for group in 0..total {
            pool.jobs
                .send(ShardJob::MaskFold {
                    group,
                    batch: Arc::clone(&batch),
                })
                .expect("shard pool dead");
        }
        let mut out: Vec<Option<Vec<u64>>> = vec![None; total];
        for _ in 0..total {
            match pool.replies.recv().expect("shard pool dead") {
                ShardReply::MaskFold { group, partial } => {
                    debug_assert!(out[group].is_none());
                    out[group] = Some(partial);
                }
                _ => panic!("unexpected reply during mask fold"),
            }
        }
        out.into_iter().map(Option::unwrap).collect()
    }

    /// Fan the sharded-negotiation scalar folds out over the worker
    /// pool: one `ScalarFold` job per shard group, partials landing by
    /// group index. Fixed-point masking is exact in the ring, so the
    /// pooled result is bit-identical to the sequential default for any
    /// worker count or completion order.
    fn negotiation_partials(
        &mut self,
        round_seed: u64,
        groups: &[ScalarGroup],
    ) -> Vec<f32> {
        let Some(pool) = &self.pool else {
            let agg = SecureAggregator::new(round_seed);
            return groups.iter().map(|g| agg.aggregate_scalars(g)).collect();
        };
        let total = groups.len();
        let groups: Arc<Vec<ScalarGroup>> = Arc::new(groups.to_vec());
        for group in 0..total {
            pool.jobs
                .send(ShardJob::ScalarFold {
                    group,
                    round_seed,
                    groups: Arc::clone(&groups),
                })
                .expect("shard pool dead");
        }
        let mut out: Vec<Option<f32>> = vec![None; total];
        for _ in 0..total {
            match pool.replies.recv().expect("shard pool dead") {
                ShardReply::ScalarFold { group, sum } => {
                    debug_assert!(out[group].is_none());
                    out[group] = Some(sum);
                }
                _ => panic!("unexpected reply during negotiation fold"),
            }
        }
        out.into_iter().map(Option::unwrap).collect()
    }

    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome {
        self.compute.evaluate(global)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Compute whose outcome encodes (round, client) so placement errors
    /// are visible.
    struct TagCompute {
        n: usize,
        dim: usize,
    }

    impl ClientCompute for TagCompute {
        fn dim(&self) -> usize {
            self.dim
        }
        fn num_clients(&self) -> usize {
            self.n
        }
        fn init_params(&self, _seed: u64) -> Vec<f32> {
            vec![0.0; self.dim]
        }
        fn local_one(
            &self,
            round: usize,
            global: &[f32],
            client: usize,
            _scratch: &mut Scratch,
        ) -> LocalOutcome {
            LocalOutcome {
                delta: vec![
                    (round * 1000 + client) as f32 + global[0];
                    self.dim
                ],
                train_loss: client as f64,
                examples: client + 1,
            }
        }
        fn evaluate(&self, _global: &[f32]) -> EvalOutcome {
            EvalOutcome { loss: 0.0, accuracy: 1.0 }
        }
    }

    fn shard_cohorts() -> Vec<Vec<usize>> {
        vec![vec![0, 4, 8], vec![1, 5], vec![], vec![3, 7, 11, 15]]
    }

    #[test]
    fn inline_and_pooled_runners_agree() {
        let global = vec![0.5f32; 3];
        let mut inline =
            ParallelRunner::new(TagCompute { n: 16, dim: 3 }, 1);
        let mut pooled =
            ParallelRunner::new(TagCompute { n: 16, dim: 3 }, 4);
        let a = inline.run_shards(2, &global, &shard_cohorts());
        let b = pooled.run_shards(2, &global, &shard_cohorts());
        assert_eq!(a.len(), b.len());
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.len(), sb.len());
            for (oa, ob) in sa.iter().zip(sb) {
                assert_eq!(oa.delta, ob.delta);
                assert_eq!(oa.train_loss, ob.train_loss);
                assert_eq!(oa.examples, ob.examples);
            }
        }
    }

    #[test]
    fn pooled_results_land_at_their_positions() {
        let mut pooled =
            ParallelRunner::new(TagCompute { n: 16, dim: 2 }, 3);
        let cohorts = shard_cohorts();
        let out = pooled.run_shards(1, &[0.0, 0.0], &cohorts);
        for (shard, clients) in cohorts.iter().enumerate() {
            assert_eq!(out[shard].len(), clients.len());
            for (pos, &client) in clients.iter().enumerate() {
                assert_eq!(
                    out[shard][pos].delta[0],
                    (1000 + client) as f32,
                    "shard {shard} pos {pos}"
                );
                assert_eq!(out[shard][pos].examples, client + 1);
            }
        }
    }

    #[test]
    fn pooled_and_inline_secure_partials_agree_bitwise() {
        use super::super::aggregate::MaskUpload;
        use crate::util::rng::Rng;
        use crate::wire::Payload;
        let dim = 300; // spans ring blocks
        let mut rng = Rng::new(41);
        let roster: Vec<u64> = (0..7).collect();
        let mut groups = vec![Vec::new(), Vec::new(), Vec::new()];
        for (k, &client) in roster.iter().enumerate() {
            groups[k % 3].push(MaskUpload {
                client,
                factor: 0.5 + k as f32 * 0.1,
                payload: Payload::Dense(
                    (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                ),
            });
        }
        let batch = MaskBatch {
            dim,
            round_seed: 99,
            roster,
            groups,
        };
        let mut inline = ParallelRunner::new(TagCompute { n: 8, dim }, 1);
        let mut pooled = ParallelRunner::new(TagCompute { n: 8, dim }, 3);
        let a = inline.secure_partials(batch.clone());
        let b = pooled.secure_partials(batch);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn pooled_and_inline_negotiation_partials_agree_bitwise() {
        let groups: Vec<ScalarGroup> = vec![
            (0..5u64).map(|i| (i, 0.25 + i as f32 * 0.5)).collect(),
            vec![(7, -3.5)],
            Vec::new(),
            (10..14u64).map(|i| (i, (i as f32).sin())).collect(),
        ];
        let mut inline = ParallelRunner::new(TagCompute { n: 8, dim: 2 }, 1);
        let mut pooled = ParallelRunner::new(TagCompute { n: 8, dim: 2 }, 3);
        let a = inline.negotiation_partials(77, &groups);
        let b = pooled.negotiation_partials(77, &groups);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // and matches the direct secure scalar aggregation
        use crate::secure_agg::SecureAggregator;
        let agg = SecureAggregator::new(77);
        for (g, &got) in groups.iter().zip(&a) {
            assert_eq!(got.to_bits(), agg.aggregate_scalars(g).to_bits());
        }
        // masked sums track the plain sums up to fixed-point precision
        for (g, &got) in groups.iter().zip(&a) {
            let plain: f32 = g.iter().map(|&(_, x)| x).sum();
            assert!((got - plain).abs() < 1e-4, "{got} vs {plain}");
        }
    }

    #[test]
    fn pool_survives_many_rounds() {
        let mut pooled =
            ParallelRunner::new(TagCompute { n: 16, dim: 1 }, 2);
        for round in 0..50 {
            let out = pooled.run_shards(round, &[0.0], &shard_cohorts());
            assert_eq!(out.iter().map(Vec::len).sum::<usize>(), 9);
        }
    }
}
