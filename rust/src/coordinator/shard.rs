//! Shard execution: how a shard's slice of the cohort actually runs its
//! local work.
//!
//! Two runners implement [`LocalRunner`]:
//!
//! * [`EngineRunner`] — adapts any legacy [`ClientEngine`] (the XLA
//!   engine, test toys). Shards run sequentially through the engine's
//!   own `run_local`; the XLA engine parallelizes internally with its
//!   PJRT worker pool, so nothing is lost.
//! * [`ParallelRunner`] — owns a persistent worker-thread pool (the
//!   channel pattern of [`crate::runtime::engine`]: shared job queue
//!   behind a mutex, plain-data replies) over a [`ClientCompute`]
//!   backend. Results are placed by (shard, position), so trajectories
//!   are independent of thread scheduling.
//!
//! The pool runs two job kinds: a client's local pass, and a shard
//! group's secure-aggregation masked fold (`LocalRunner::secure_partials`
//! — ring sums commute, so fanning the folds across workers is
//! bit-exact; see DESIGN.md §6).
//!
//! Both job kinds parallelize *within* a shard, not just across shards:
//! local passes are dispatched per client, and a group's masked fold is
//! sub-chunked over its members when there are more idle workers than
//! non-empty groups ([`chunk_ranges`]), so a 1-shard/N-worker run keeps
//! all N workers busy. Chunk partials merge in ascending chunk order —
//! and Z_2^64 addition commutes, so the merged bits equal the
//! sequential member-order fold regardless (DESIGN.md §12).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::fl::{ClientEngine, EvalOutcome, LocalOutcome};
use crate::secure_agg::SecureAggregator;
use crate::telemetry::{Clock, JobKind, JobTiming};
use crate::tensor::kernels;
use crate::tensor::kernels::Scratch;

use super::aggregate::{fused_masked_partial, MaskBatch};

/// One shard's sharded-negotiation inputs: `(client id, scalar)` pairs
/// to be securely summed (see [`LocalRunner::negotiation_partials`]).
pub type ScalarGroup = Vec<(u64, f32)>;

/// What the round state machine needs from an execution backend.
pub trait LocalRunner {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;
    /// Total pool size.
    fn num_clients(&self) -> usize;
    /// Initial global parameters.
    fn init_params(&mut self, seed: u64) -> Vec<f32>;
    /// Run local work for every shard's cohort slice; the result must be
    /// aligned with `shard_cohorts` (outer: shard, inner: member order).
    fn run_shards(
        &mut self,
        round: usize,
        global: &[f32],
        shard_cohorts: &[Vec<usize>],
    ) -> Vec<Vec<LocalOutcome>>;
    /// Secure-aggregation fan-out: mask + fold every shard group of
    /// `batch` into a ring partial (one per group, aligned with
    /// `batch.groups`). Ring sums commute, so *where* each group is
    /// folded never changes the combined bits. The default runs the
    /// fused kernel sequentially on the calling thread; pooled runners
    /// distribute groups over their workers.
    fn secure_partials(&mut self, batch: MaskBatch) -> Vec<Vec<u64>> {
        let mut scratch = Scratch::new();
        batch
            .groups
            .iter()
            .map(|g| fused_masked_partial(&batch, g, &mut scratch))
            .collect()
    }
    /// Sharded-AOCS negotiation fan-out (Algorithm 2 run shard-locally):
    /// securely sum each shard group's `(client id, scalar)` pairs —
    /// masked through [`crate::secure_agg::SecureAggregator`] with the
    /// group as the roster, so the master only ever sees per-shard sums
    /// — returning one partial per group, aligned with `groups`.
    /// Fixed-point ring sums are exact, so *where* a group is folded
    /// never changes its bits. The default runs sequentially on the
    /// calling thread; pooled runners distribute groups over their
    /// workers.
    fn negotiation_partials(
        &mut self,
        round_seed: u64,
        groups: &[ScalarGroup],
    ) -> Vec<f32> {
        let agg = SecureAggregator::new(round_seed);
        groups.iter().map(|g| agg.aggregate_scalars(g)).collect()
    }
    /// Evaluate global parameters on the validation split.
    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome;
    /// Install (or clear) a telemetry clock. Runners that support job
    /// timing start stamping [`JobTiming`]s for [`drain_timings`]
    /// while a clock is installed; the default runner records nothing.
    ///
    /// [`drain_timings`]: LocalRunner::drain_timings
    fn set_clock(&mut self, _clock: Option<Arc<dyn Clock>>) {}
    /// Append and clear accumulated job timings into `out`. Timings
    /// never influence results — purely observational.
    fn drain_timings(&mut self, _out: &mut Vec<JobTiming>) {}
}

/// A thread-shareable per-client compute backend (the sim engines). One
/// client's local pass must depend only on `(round, client, global)` so
/// any worker can run any job. `scratch` is the caller-owned buffer
/// arena — each pool worker owns exactly one, allocated at spawn and
/// reused for every job it runs (results must not depend on prior
/// scratch contents).
pub trait ClientCompute: Send + Sync + 'static {
    fn dim(&self) -> usize;
    fn num_clients(&self) -> usize;
    fn init_params(&self, seed: u64) -> Vec<f32>;
    fn local_one(
        &self,
        round: usize,
        global: &[f32],
        client: usize,
        scratch: &mut Scratch,
    ) -> LocalOutcome;
    fn evaluate(&self, global: &[f32]) -> EvalOutcome;
}

// ---------------------------------------------------------------------------
// legacy-engine adapter
// ---------------------------------------------------------------------------

/// Run `f`, stamping a [`JobTiming`] into `timings` when a clock is
/// installed. Inline execution never waits in a queue (queue_ns = 0)
/// and always runs on the calling thread (worker 0).
fn time_inline<R>(
    clock: &Option<Arc<dyn Clock>>,
    timings: &mut Vec<JobTiming>,
    kind: JobKind,
    items: u64,
    f: impl FnOnce() -> R,
) -> R {
    let Some(c) = clock else { return f() };
    let t0 = c.now_ns();
    let r = f();
    timings.push(JobTiming {
        kind,
        worker: 0,
        start_ns: t0,
        queue_ns: 0,
        exec_ns: c.now_ns().saturating_sub(t0),
        items,
    });
    r
}

/// [`LocalRunner`] over a `&mut dyn ClientEngine` (single-threaded per
/// shard; the engine may parallelize internally). Owns one scratch arena
/// for the masked fold, allocated once for the runner's lifetime. With a
/// telemetry clock installed, each shard's `run_local` is timed as one
/// `Local` job (items = shard cohort size) and each fold group as one
/// `MaskFold`/`ScalarFold` job.
pub struct EngineRunner<'a> {
    engine: &'a mut dyn ClientEngine,
    scratch: Scratch,
    clock: Option<Arc<dyn Clock>>,
    timings: Vec<JobTiming>,
}

impl<'a> EngineRunner<'a> {
    pub fn new(engine: &'a mut dyn ClientEngine) -> EngineRunner<'a> {
        EngineRunner {
            engine,
            scratch: Scratch::new(),
            clock: None,
            timings: Vec::new(),
        }
    }
}

impl LocalRunner for EngineRunner<'_> {
    fn dim(&self) -> usize {
        self.engine.dim()
    }

    fn num_clients(&self) -> usize {
        self.engine.num_clients()
    }

    fn init_params(&mut self, seed: u64) -> Vec<f32> {
        self.engine.init_params(seed)
    }

    fn run_shards(
        &mut self,
        round: usize,
        global: &[f32],
        shard_cohorts: &[Vec<usize>],
    ) -> Vec<Vec<LocalOutcome>> {
        let Self { engine, clock, timings, .. } = self;
        let mut out = Vec::with_capacity(shard_cohorts.len());
        for clients in shard_cohorts {
            if clients.is_empty() {
                out.push(Vec::new());
                continue;
            }
            let outs = time_inline(
                clock,
                timings,
                JobKind::Local,
                clients.len() as u64,
                || engine.run_local(round, global, clients),
            );
            assert_eq!(outs.len(), clients.len(), "engine cohort mismatch");
            out.push(outs);
        }
        out
    }

    fn secure_partials(&mut self, batch: MaskBatch) -> Vec<Vec<u64>> {
        let Self { scratch, clock, timings, .. } = self;
        let mut out = Vec::with_capacity(batch.groups.len());
        for g in &batch.groups {
            out.push(time_inline(
                clock,
                timings,
                JobKind::MaskFold,
                g.len() as u64,
                || fused_masked_partial(&batch, g, scratch),
            ));
        }
        out
    }

    fn negotiation_partials(
        &mut self,
        round_seed: u64,
        groups: &[ScalarGroup],
    ) -> Vec<f32> {
        let Self { clock, timings, .. } = self;
        let agg = SecureAggregator::new(round_seed);
        groups
            .iter()
            .map(|g| {
                time_inline(
                    clock,
                    timings,
                    JobKind::ScalarFold,
                    g.len() as u64,
                    || agg.aggregate_scalars(g),
                )
            })
            .collect()
    }

    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome {
        self.engine.evaluate(global)
    }

    fn set_clock(&mut self, clock: Option<Arc<dyn Clock>>) {
        self.clock = clock;
    }

    fn drain_timings(&mut self, out: &mut Vec<JobTiming>) {
        out.append(&mut self.timings);
    }
}

// ---------------------------------------------------------------------------
// worker pool (channel pattern from runtime::engine)
// ---------------------------------------------------------------------------

/// The job kinds a pool worker runs: one client's local pass, one
/// member sub-range of a shard group's masked vector fold (secure
/// aggregation), or one shard group's masked scalar fold (the sharded
/// AOCS negotiation). The first two use the worker's own scratch arena.
///
/// `ScalarFold` is never sub-chunked: a group folds dim-1 scalars, so
/// one job is already cheaper than the dispatch it would take to split
/// it.
enum ShardJob {
    Local {
        shard: usize,
        pos: usize,
        client: usize,
        round: usize,
        global: Arc<Vec<f32>>,
    },
    MaskFold {
        group: usize,
        /// member sub-range `lo..hi` of `batch.groups[group]` this job
        /// folds (the whole group when the split plan is one chunk)
        lo: usize,
        hi: usize,
        /// position of this sub-range in the group's split plan — the
        /// merge slot the partial lands in
        chunk: usize,
        batch: Arc<MaskBatch>,
    },
    ScalarFold {
        group: usize,
        round_seed: u64,
        groups: Arc<Vec<ScalarGroup>>,
    },
}

/// Split `len` members into `parts` contiguous near-equal ranges (the
/// first `len % parts` ranges take the extra member). Never returns
/// more ranges than members; `len == 0` yields one empty range so an
/// empty group still produces its zero partial (and its one job, as
/// before sub-chunking).
fn chunk_ranges(len: usize, parts: usize) -> Vec<(usize, usize)> {
    if len == 0 {
        return vec![(0, 0)];
    }
    let parts = parts.clamp(1, len);
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        let hi = lo + base + usize::from(p < extra);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// A queued job plus the telemetry context it travels with: the enqueue
/// timestamp (for queue-wait measurement) and the clock the executing
/// worker stamps with. `clock` is `None` when telemetry is off, making
/// dispatch overhead a single `Option` move.
struct Dispatch {
    job: ShardJob,
    enqueued_ns: u64,
    clock: Option<Arc<dyn Clock>>,
}

enum ShardReply {
    Local {
        shard: usize,
        pos: usize,
        outcome: LocalOutcome,
    },
    MaskFold {
        group: usize,
        chunk: usize,
        partial: Vec<u64>,
    },
    ScalarFold {
        group: usize,
        sum: f32,
    },
}

/// A worker's reply plus its job timing (when a clock was installed).
struct Reply {
    reply: ShardReply,
    timing: Option<JobTiming>,
}

struct ShardPool {
    jobs: mpsc::Sender<Dispatch>,
    replies: mpsc::Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
}

fn recv_job(
    rx: &Arc<Mutex<mpsc::Receiver<Dispatch>>>,
) -> Result<Dispatch, mpsc::RecvError> {
    rx.lock().expect("shard job queue poisoned").recv()
}

impl ShardPool {
    fn spawn<C: ClientCompute>(workers: usize, compute: Arc<C>) -> ShardPool {
        let (job_tx, job_rx) = mpsc::channel::<Dispatch>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (rep_tx, rep_rx) = mpsc::channel::<Reply>();
        let handles = (0..workers)
            .map(|worker| {
                let job_rx = Arc::clone(&job_rx);
                let rep_tx = rep_tx.clone();
                let compute = Arc::clone(&compute);
                std::thread::spawn(move || {
                    // one arena per worker, alive for the pool's lifetime
                    let mut scratch = Scratch::new();
                    while let Ok(d) = recv_job(&job_rx) {
                        let t0 = d.clock.as_ref().map(|c| c.now_ns());
                        let (reply, kind, items) = match d.job {
                            ShardJob::Local {
                                shard,
                                pos,
                                client,
                                round,
                                global,
                            } => {
                                let outcome = compute.local_one(
                                    round,
                                    &global,
                                    client,
                                    &mut scratch,
                                );
                                (
                                    ShardReply::Local { shard, pos, outcome },
                                    JobKind::Local,
                                    1,
                                )
                            }
                            ShardJob::MaskFold {
                                group,
                                lo,
                                hi,
                                chunk,
                                batch,
                            } => {
                                let partial = fused_masked_partial(
                                    &batch,
                                    &batch.groups[group][lo..hi],
                                    &mut scratch,
                                );
                                (
                                    ShardReply::MaskFold {
                                        group,
                                        chunk,
                                        partial,
                                    },
                                    JobKind::MaskFold,
                                    (hi - lo) as u64,
                                )
                            }
                            ShardJob::ScalarFold {
                                group,
                                round_seed,
                                groups,
                            } => {
                                let items = groups[group].len() as u64;
                                let sum = SecureAggregator::new(round_seed)
                                    .aggregate_scalars(&groups[group]);
                                (
                                    ShardReply::ScalarFold { group, sum },
                                    JobKind::ScalarFold,
                                    items,
                                )
                            }
                        };
                        let timing = match (&d.clock, t0) {
                            (Some(c), Some(t0)) => Some(JobTiming {
                                kind,
                                worker,
                                start_ns: t0,
                                queue_ns: t0.saturating_sub(d.enqueued_ns),
                                exec_ns: c.now_ns().saturating_sub(t0),
                                items,
                            }),
                            _ => None,
                        };
                        if rep_tx.send(Reply { reply, timing }).is_err() {
                            break;
                        }
                    }
                })
            })
            .collect();
        ShardPool { jobs: job_tx, replies: rep_rx, handles }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // closing the channel stops the workers
        let (dead_tx, _) = mpsc::channel();
        self.jobs = dead_tx;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// parallel runner
// ---------------------------------------------------------------------------

/// [`LocalRunner`] that fans shard cohorts out over a persistent worker
/// pool. `workers <= 1` runs inline on the calling thread (identical
/// results — placement is by index, never by completion order).
pub struct ParallelRunner<C: ClientCompute> {
    compute: Arc<C>,
    pool: Option<ShardPool>,
    /// pool width — the sub-chunking budget for under-sharded folds
    workers: usize,
    /// arena for the inline (workers <= 1) path
    scratch: Scratch,
    /// telemetry clock; `None` (the default) keeps dispatch timing-free
    clock: Option<Arc<dyn Clock>>,
    /// job timings accumulated since the last `drain_timings`
    timings: Vec<JobTiming>,
}

impl<C: ClientCompute> ParallelRunner<C> {
    pub fn new(compute: C, workers: usize) -> ParallelRunner<C> {
        let compute = Arc::new(compute);
        let pool = if workers > 1 {
            Some(ShardPool::spawn(workers, Arc::clone(&compute)))
        } else {
            None
        };
        ParallelRunner {
            compute,
            pool,
            workers: workers.max(1),
            scratch: Scratch::new(),
            clock: None,
            timings: Vec::new(),
        }
    }

    /// Shared access to the underlying compute backend.
    pub fn compute(&self) -> &C {
        &self.compute
    }

    fn dispatch(&self, pool: &ShardPool, job: ShardJob) {
        let enqueued_ns = match &self.clock {
            Some(c) => c.now_ns(),
            None => 0,
        };
        pool.jobs
            .send(Dispatch { job, enqueued_ns, clock: self.clock.clone() })
            .expect("shard pool dead");
    }
}

impl<C: ClientCompute> LocalRunner for ParallelRunner<C> {
    fn dim(&self) -> usize {
        self.compute.dim()
    }

    fn num_clients(&self) -> usize {
        self.compute.num_clients()
    }

    fn init_params(&mut self, seed: u64) -> Vec<f32> {
        self.compute.init_params(seed)
    }

    fn run_shards(
        &mut self,
        round: usize,
        global: &[f32],
        shard_cohorts: &[Vec<usize>],
    ) -> Vec<Vec<LocalOutcome>> {
        if self.pool.is_none() {
            // inline path: one scratch arena, owned by the runner
            let Self { compute, scratch, clock, timings, .. } = self;
            let mut out = Vec::with_capacity(shard_cohorts.len());
            for clients in shard_cohorts {
                let mut shard_out = Vec::with_capacity(clients.len());
                for &c in clients {
                    shard_out.push(time_inline(
                        clock,
                        timings,
                        JobKind::Local,
                        1,
                        || compute.local_one(round, global, c, scratch),
                    ));
                }
                out.push(shard_out);
            }
            return out;
        }
        let pool = self.pool.as_ref().expect("pool checked above");
        let global = Arc::new(global.to_vec());
        let mut total = 0usize;
        for (shard, clients) in shard_cohorts.iter().enumerate() {
            for (pos, &client) in clients.iter().enumerate() {
                self.dispatch(
                    pool,
                    ShardJob::Local {
                        shard,
                        pos,
                        client,
                        round,
                        global: Arc::clone(&global),
                    },
                );
                total += 1;
            }
        }
        let mut out: Vec<Vec<Option<LocalOutcome>>> =
            shard_cohorts.iter().map(|c| vec![None; c.len()]).collect();
        for _ in 0..total {
            let Reply { reply, timing } =
                pool.replies.recv().expect("shard pool dead");
            if let Some(t) = timing {
                self.timings.push(t);
            }
            match reply {
                ShardReply::Local { shard, pos, outcome } => {
                    debug_assert!(out[shard][pos].is_none());
                    out[shard][pos] = Some(outcome);
                }
                _ => panic!("fold reply during local compute"),
            }
        }
        out.into_iter()
            .map(|v| v.into_iter().map(Option::unwrap).collect())
            .collect()
    }

    /// Fan the per-shard masked folds out over the worker pool,
    /// sub-chunking groups when workers outnumber non-empty groups:
    /// each group's member list splits into `⌈workers / nonempty⌉`
    /// contiguous ranges ([`chunk_ranges`]), one `MaskFold` job per
    /// range, each worker folding its range into its own ring
    /// accumulator with its own scratch arena. A well-sharded batch
    /// (groups ≥ workers) keeps the historical one-job-per-group plan.
    ///
    /// Chunk partials land by (group, chunk) index and merge in
    /// ascending chunk order; Z_2^64 addition commutes, so the merged
    /// bits equal the sequential member-order fold for any worker
    /// count, split plan or completion order (DESIGN.md §6, §12).
    fn secure_partials(&mut self, batch: MaskBatch) -> Vec<Vec<u64>> {
        if self.pool.is_none() {
            // inline path: the runner-owned arena, as in run_shards
            let Self { scratch, clock, timings, .. } = self;
            let mut out = Vec::with_capacity(batch.groups.len());
            for g in &batch.groups {
                out.push(time_inline(
                    clock,
                    timings,
                    JobKind::MaskFold,
                    g.len() as u64,
                    || fused_masked_partial(&batch, g, scratch),
                ));
            }
            return out;
        }
        let pool = self.pool.as_ref().expect("pool checked above");
        let nonempty =
            batch.groups.iter().filter(|g| !g.is_empty()).count().max(1);
        let per_group = self.workers.div_ceil(nonempty);
        let plans: Vec<Vec<(usize, usize)>> = batch
            .groups
            .iter()
            .map(|g| chunk_ranges(g.len(), per_group))
            .collect();
        let batch = Arc::new(batch);
        let mut total_jobs = 0usize;
        for (group, plan) in plans.iter().enumerate() {
            for (chunk, &(lo, hi)) in plan.iter().enumerate() {
                self.dispatch(
                    pool,
                    ShardJob::MaskFold {
                        group,
                        lo,
                        hi,
                        chunk,
                        batch: Arc::clone(&batch),
                    },
                );
                total_jobs += 1;
            }
        }
        let mut parts: Vec<Vec<Option<Vec<u64>>>> =
            plans.iter().map(|p| vec![None; p.len()]).collect();
        for _ in 0..total_jobs {
            let Reply { reply, timing } =
                pool.replies.recv().expect("shard pool dead");
            if let Some(t) = timing {
                self.timings.push(t);
            }
            match reply {
                ShardReply::MaskFold { group, chunk, partial } => {
                    debug_assert!(parts[group][chunk].is_none());
                    parts[group][chunk] = Some(partial);
                }
                _ => panic!("unexpected reply during mask fold"),
            }
        }
        parts
            .into_iter()
            .map(|chunks| {
                let mut it =
                    chunks.into_iter().map(|c| c.expect("chunk collected"));
                let mut acc = it.next().expect("every group has a chunk");
                for p in it {
                    kernels::wrapping_accumulate(&mut acc, &[&p]);
                }
                acc
            })
            .collect()
    }

    /// Fan the sharded-negotiation scalar folds out over the worker
    /// pool: one `ScalarFold` job per shard group, partials landing by
    /// group index. Fixed-point masking is exact in the ring, so the
    /// pooled result is bit-identical to the sequential default for any
    /// worker count or completion order.
    fn negotiation_partials(
        &mut self,
        round_seed: u64,
        groups: &[ScalarGroup],
    ) -> Vec<f32> {
        if self.pool.is_none() {
            let Self { clock, timings, .. } = self;
            let agg = SecureAggregator::new(round_seed);
            return groups
                .iter()
                .map(|g| {
                    time_inline(
                        clock,
                        timings,
                        JobKind::ScalarFold,
                        g.len() as u64,
                        || agg.aggregate_scalars(g),
                    )
                })
                .collect();
        }
        let pool = self.pool.as_ref().expect("pool checked above");
        let total = groups.len();
        let groups: Arc<Vec<ScalarGroup>> = Arc::new(groups.to_vec());
        for group in 0..total {
            self.dispatch(
                pool,
                ShardJob::ScalarFold {
                    group,
                    round_seed,
                    groups: Arc::clone(&groups),
                },
            );
        }
        let mut out: Vec<Option<f32>> = vec![None; total];
        for _ in 0..total {
            let Reply { reply, timing } =
                pool.replies.recv().expect("shard pool dead");
            if let Some(t) = timing {
                self.timings.push(t);
            }
            match reply {
                ShardReply::ScalarFold { group, sum } => {
                    debug_assert!(out[group].is_none());
                    out[group] = Some(sum);
                }
                _ => panic!("unexpected reply during negotiation fold"),
            }
        }
        out.into_iter().map(Option::unwrap).collect()
    }

    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome {
        self.compute.evaluate(global)
    }

    fn set_clock(&mut self, clock: Option<Arc<dyn Clock>>) {
        self.clock = clock;
    }

    fn drain_timings(&mut self, out: &mut Vec<JobTiming>) {
        out.append(&mut self.timings);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Compute whose outcome encodes (round, client) so placement errors
    /// are visible.
    struct TagCompute {
        n: usize,
        dim: usize,
    }

    impl ClientCompute for TagCompute {
        fn dim(&self) -> usize {
            self.dim
        }
        fn num_clients(&self) -> usize {
            self.n
        }
        fn init_params(&self, _seed: u64) -> Vec<f32> {
            vec![0.0; self.dim]
        }
        fn local_one(
            &self,
            round: usize,
            global: &[f32],
            client: usize,
            _scratch: &mut Scratch,
        ) -> LocalOutcome {
            LocalOutcome {
                delta: vec![
                    (round * 1000 + client) as f32 + global[0];
                    self.dim
                ],
                train_loss: client as f64,
                examples: client + 1,
            }
        }
        fn evaluate(&self, _global: &[f32]) -> EvalOutcome {
            EvalOutcome { loss: 0.0, accuracy: 1.0 }
        }
    }

    fn shard_cohorts() -> Vec<Vec<usize>> {
        vec![vec![0, 4, 8], vec![1, 5], vec![], vec![3, 7, 11, 15]]
    }

    #[test]
    fn inline_and_pooled_runners_agree() {
        let global = vec![0.5f32; 3];
        let mut inline =
            ParallelRunner::new(TagCompute { n: 16, dim: 3 }, 1);
        let mut pooled =
            ParallelRunner::new(TagCompute { n: 16, dim: 3 }, 4);
        let a = inline.run_shards(2, &global, &shard_cohorts());
        let b = pooled.run_shards(2, &global, &shard_cohorts());
        assert_eq!(a.len(), b.len());
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.len(), sb.len());
            for (oa, ob) in sa.iter().zip(sb) {
                assert_eq!(oa.delta, ob.delta);
                assert_eq!(oa.train_loss, ob.train_loss);
                assert_eq!(oa.examples, ob.examples);
            }
        }
    }

    #[test]
    fn pooled_results_land_at_their_positions() {
        let mut pooled =
            ParallelRunner::new(TagCompute { n: 16, dim: 2 }, 3);
        let cohorts = shard_cohorts();
        let out = pooled.run_shards(1, &[0.0, 0.0], &cohorts);
        for (shard, clients) in cohorts.iter().enumerate() {
            assert_eq!(out[shard].len(), clients.len());
            for (pos, &client) in clients.iter().enumerate() {
                assert_eq!(
                    out[shard][pos].delta[0],
                    (1000 + client) as f32,
                    "shard {shard} pos {pos}"
                );
                assert_eq!(out[shard][pos].examples, client + 1);
            }
        }
    }

    #[test]
    fn pooled_and_inline_secure_partials_agree_bitwise() {
        use super::super::aggregate::MaskUpload;
        use crate::util::rng::Rng;
        use crate::wire::Payload;
        let dim = 300; // spans ring blocks
        let mut rng = Rng::new(41);
        let roster: Vec<u64> = (0..7).collect();
        let mut groups = vec![Vec::new(), Vec::new(), Vec::new()];
        for (k, &client) in roster.iter().enumerate() {
            groups[k % 3].push(MaskUpload {
                client,
                factor: 0.5 + k as f32 * 0.1,
                payload: Payload::Dense(
                    (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                ),
            });
        }
        let batch = MaskBatch {
            dim,
            round_seed: 99,
            roster,
            groups,
        };
        let mut inline = ParallelRunner::new(TagCompute { n: 8, dim }, 1);
        let mut pooled = ParallelRunner::new(TagCompute { n: 8, dim }, 3);
        let a = inline.secure_partials(batch.clone());
        let b = pooled.secure_partials(batch);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn pooled_and_inline_negotiation_partials_agree_bitwise() {
        let groups: Vec<ScalarGroup> = vec![
            (0..5u64).map(|i| (i, 0.25 + i as f32 * 0.5)).collect(),
            vec![(7, -3.5)],
            Vec::new(),
            (10..14u64).map(|i| (i, (i as f32).sin())).collect(),
        ];
        let mut inline = ParallelRunner::new(TagCompute { n: 8, dim: 2 }, 1);
        let mut pooled = ParallelRunner::new(TagCompute { n: 8, dim: 2 }, 3);
        let a = inline.negotiation_partials(77, &groups);
        let b = pooled.negotiation_partials(77, &groups);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // and matches the direct secure scalar aggregation
        use crate::secure_agg::SecureAggregator;
        let agg = SecureAggregator::new(77);
        for (g, &got) in groups.iter().zip(&a) {
            assert_eq!(got.to_bits(), agg.aggregate_scalars(g).to_bits());
        }
        // masked sums track the plain sums up to fixed-point precision
        for (g, &got) in groups.iter().zip(&a) {
            let plain: f32 = g.iter().map(|&(_, x)| x).sum();
            assert!((got - plain).abs() < 1e-4, "{got} vs {plain}");
        }
    }

    #[test]
    fn pool_survives_many_rounds() {
        let mut pooled =
            ParallelRunner::new(TagCompute { n: 16, dim: 1 }, 2);
        for round in 0..50 {
            let out = pooled.run_shards(round, &[0.0], &shard_cohorts());
            assert_eq!(out.iter().map(Vec::len).sum::<usize>(), 9);
        }
    }

    #[test]
    fn timings_recorded_only_with_clock_and_results_unchanged() {
        use crate::telemetry::ManualClock;
        let global = vec![0.5f32; 3];
        let mut plain = ParallelRunner::new(TagCompute { n: 16, dim: 3 }, 4);
        let mut timed = ParallelRunner::new(TagCompute { n: 16, dim: 3 }, 4);
        timed.set_clock(Some(Arc::new(ManualClock::new(10))));
        let a = plain.run_shards(2, &global, &shard_cohorts());
        let b = timed.run_shards(2, &global, &shard_cohorts());
        for (sa, sb) in a.iter().zip(&b) {
            for (oa, ob) in sa.iter().zip(sb) {
                assert_eq!(oa.delta, ob.delta);
            }
        }
        let mut t = Vec::new();
        plain.drain_timings(&mut t);
        assert!(t.is_empty(), "no clock installed, no timings");
        timed.drain_timings(&mut t);
        assert_eq!(t.len(), 9, "one Local timing per cohort member");
        assert!(t
            .iter()
            .all(|x| matches!(x.kind, JobKind::Local) && x.items == 1));
        let mut again = Vec::new();
        timed.drain_timings(&mut again);
        assert!(again.is_empty(), "drain clears the buffer");
    }

    #[test]
    fn chunk_ranges_cover_and_balance() {
        assert_eq!(chunk_ranges(0, 4), vec![(0, 0)]);
        assert_eq!(chunk_ranges(3, 1), vec![(0, 3)]);
        assert_eq!(chunk_ranges(2, 5), vec![(0, 1), (1, 2)], "≤ len ranges");
        assert_eq!(chunk_ranges(7, 3), vec![(0, 3), (3, 5), (5, 7)]);
        for (len, parts) in [(1usize, 1usize), (8, 3), (9, 4), (100, 7)] {
            let r = chunk_ranges(len, parts);
            assert_eq!(r[0].0, 0);
            assert_eq!(r.last().unwrap().1, len);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
                assert!(w[0].1 > w[0].0, "non-empty");
            }
        }
    }

    fn one_group_batch(members: usize, dim: usize) -> MaskBatch {
        use super::super::aggregate::MaskUpload;
        use crate::util::rng::Rng;
        use crate::wire::Payload;
        let mut rng = Rng::new(4242);
        let roster: Vec<u64> = (0..members as u64).collect();
        let group: Vec<MaskUpload> = roster
            .iter()
            .map(|&client| MaskUpload {
                client,
                factor: 0.5 + client as f32 * 0.1,
                payload: Payload::Dense(
                    (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                ),
            })
            .collect();
        MaskBatch { dim, round_seed: 99, roster, groups: vec![group] }
    }

    #[test]
    fn sub_chunked_secure_partials_bitwise_match_inline() {
        // one fat group, more workers than groups: the fold must
        // sub-chunk yet stay bit-identical to the sequential fold
        let batch = one_group_batch(7, 300);
        let mut inline = ParallelRunner::new(TagCompute { n: 8, dim: 300 }, 1);
        let mut pooled = ParallelRunner::new(TagCompute { n: 8, dim: 300 }, 4);
        let a = inline.secure_partials(batch.clone());
        let b = pooled.secure_partials(batch);
        assert_eq!(a, b);
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn under_sharded_mask_fold_engages_all_workers() {
        // the PR 9 regression pin: a 1-group/N-worker secure fold must
        // produce N MaskFold jobs (one per worker, deterministic via
        // job counts) whose item counts partition the group
        use crate::telemetry::ManualClock;
        let workers = 4;
        let members = 7;
        let mut pooled =
            ParallelRunner::new(TagCompute { n: 8, dim: 64 }, workers);
        pooled.set_clock(Some(Arc::new(ManualClock::new(3))));
        let out = pooled.secure_partials(one_group_batch(members, 64));
        assert_eq!(out.len(), 1);
        let mut t = Vec::new();
        pooled.drain_timings(&mut t);
        let folds: Vec<_> = t
            .iter()
            .filter(|x| matches!(x.kind, JobKind::MaskFold))
            .collect();
        assert_eq!(
            folds.len(),
            workers,
            "one sub-chunk job per worker on an under-sharded fold"
        );
        assert_eq!(
            folds.iter().map(|x| x.items).sum::<u64>(),
            members as u64,
            "sub-chunks partition the group"
        );
        assert!(
            folds.iter().all(|x| x.items > 0),
            "no empty make-work chunks"
        );
    }

    #[test]
    fn well_sharded_mask_fold_keeps_one_job_per_group() {
        use super::super::aggregate::MaskUpload;
        use crate::telemetry::ManualClock;
        use crate::util::rng::Rng;
        use crate::wire::Payload;
        let dim = 64;
        let mut rng = Rng::new(17);
        let roster: Vec<u64> = (0..6).collect();
        let mut groups = vec![Vec::new(), Vec::new(), Vec::new()];
        for (k, &client) in roster.iter().enumerate() {
            groups[k % 3].push(MaskUpload {
                client,
                factor: 1.0,
                payload: Payload::Dense(
                    (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                ),
            });
        }
        let batch = MaskBatch { dim, round_seed: 5, roster, groups };
        let mut pooled = ParallelRunner::new(TagCompute { n: 8, dim }, 3);
        pooled.set_clock(Some(Arc::new(ManualClock::new(3))));
        let out = pooled.secure_partials(batch);
        assert_eq!(out.len(), 3);
        let mut t = Vec::new();
        pooled.drain_timings(&mut t);
        assert_eq!(
            t.iter()
                .filter(|x| matches!(x.kind, JobKind::MaskFold))
                .count(),
            3,
            "groups ≥ workers: the historical one-job-per-group plan"
        );
    }

    #[test]
    fn inline_runner_times_scalar_folds() {
        use crate::telemetry::ManualClock;
        let groups: Vec<ScalarGroup> = vec![
            (0..5u64).map(|i| (i, 0.25 + i as f32 * 0.5)).collect(),
            vec![(7, -3.5)],
        ];
        let mut inline = ParallelRunner::new(TagCompute { n: 8, dim: 2 }, 1);
        inline.set_clock(Some(Arc::new(ManualClock::new(7))));
        let sums = inline.negotiation_partials(77, &groups);
        assert_eq!(sums.len(), 2);
        let mut t = Vec::new();
        inline.drain_timings(&mut t);
        assert_eq!(t.len(), 2);
        assert!(t.iter().all(|x| matches!(x.kind, JobKind::ScalarFold)
            && x.worker == 0
            && x.queue_ns == 0));
        assert_eq!(t[0].items, 5);
        assert_eq!(t[1].items, 1);
    }
}
