//! `fedsamp` — launcher CLI for the Optimal Client Sampling reproduction.
//!
//! Subcommands:
//!   train       run one experiment (preset or JSON config, with overrides)
//!   coordinate  run the sharded round coordinator (sim engine)
//!   figures     regenerate a paper figure's data (2–7, 13)
//!   sweep       scenario grids (strategy × compressor × availability ×
//!               pool → BENCH_sweep.{json,csv}) + theory sweeps
//!   inspect     list AOT artifacts and dataset statistics

use fedsamp::bench::{f, Table};
use fedsamp::checkpoint::{
    parse_checkpoint_every, parse_resume_path, CheckpointOptions,
};
use fedsamp::compress::Compressor;
use fedsamp::config::{presets, ExperimentConfig, Strategy};
use fedsamp::coordinator::{
    Coordinator, CoordinatorOptions, DeadlinePolicy, ParallelRunner,
};
use fedsamp::exp::figures::{run_figure, Scale};
use fedsamp::exp::{default_artifacts_dir, run_experiment};
use fedsamp::faults::{parse_fault_spec, MASTERKILL_ERR_PREFIX};
use fedsamp::fl::TrainOptions;
use fedsamp::metrics::RunResult;
use fedsamp::model::quadratic::QuadraticProblem;
use fedsamp::runtime::manifest::load_manifests;
use fedsamp::sampling::Sampler;
use fedsamp::sim::build_native_engine;
use fedsamp::sim::theory::{max_stable_eta, run_dsgd_quadratic};
use fedsamp::telemetry::TelemetryConfig;
use fedsamp::tensor::dispatch;
use fedsamp::util::args::{Cli, Parsed};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..]),
        Some("coordinate") => cmd_coordinate(&args[1..]),
        Some("figures") => cmd_figures(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "fedsamp — Optimal Client Sampling for Federated Learning\n\n\
         USAGE: fedsamp <subcommand> [options]\n\n\
         SUBCOMMANDS:\n\
           train       run one experiment\n\
           coordinate  sharded round coordinator (--shards/--workers)\n\
           figures     regenerate a paper figure (2, 3, 4, 5, 6, 7, 13)\n\
           sweep       scenario grid (default; --quick for the CI smoke\n\
                       grid) or theory sweeps (--kind stepsize|budget)\n\
           bench       perf suites (kernels|secure|comm → BENCH_<suite>.json)\n\
           inspect     show artifacts + dataset statistics\n\n\
         Run `fedsamp <subcommand> --help` for options."
    );
}

fn preset_by_name(preset: &str) -> Option<ExperimentConfig> {
    match preset {
        "femnist1" => Some(presets::femnist(1, 3)),
        "femnist2" => Some(presets::femnist(2, 3)),
        "femnist3" => Some(presets::femnist(3, 3)),
        "shakespeare32" => Some(presets::shakespeare(32, 2)),
        "shakespeare128" => Some(presets::shakespeare(128, 4)),
        "cifar" => Some(presets::cifar(3)),
        other => {
            eprintln!("unknown preset '{other}'");
            None
        }
    }
}

fn print_run_summary(run: &RunResult) {
    println!(
        "\n{}: final_acc={:.4} best_acc={:.4} final_loss={:.4} \
         total_uplink={:.2} Mbit mean_alpha={:.3}",
        run.name,
        run.final_accuracy(),
        run.best_accuracy(),
        run.final_train_loss(),
        run.total_uplink_bits() as f64 / 1e6,
        run.mean_alpha()
    );
}

/// The shared telemetry CLI surface (`train` and `coordinate`):
/// `--telemetry` enables recording, `--telemetry-out`/`--trace-out` pick
/// the export paths (either implies `--telemetry`). Enabled without an
/// explicit `--telemetry-out` defaults the event stream to
/// `telemetry.jsonl` in the working directory.
fn telemetry_cli(cli: Cli) -> Cli {
    cli.flag(
        "telemetry",
        "record round-phase spans, shard timing histograms and counters",
    )
    .opt(
        "telemetry-out",
        None,
        "telemetry JSONL event stream path (implies --telemetry; \
         default telemetry.jsonl when enabled)",
    )
    .opt(
        "trace-out",
        None,
        "Chrome trace_event JSON path, loadable in Perfetto/about:tracing \
         (implies --telemetry)",
    )
}

fn telemetry_from_cli(p: &Parsed) -> TelemetryConfig {
    let jsonl = p.get("telemetry-out").map(String::from);
    let trace = p.get("trace-out").map(String::from);
    if !p.flag("telemetry") && jsonl.is_none() && trace.is_none() {
        return TelemetryConfig::off();
    }
    TelemetryConfig {
        enabled: true,
        jsonl_out: Some(jsonl.unwrap_or_else(|| "telemetry.jsonl".into())),
        trace_out: trace,
        manual_clock: false,
    }
}

/// The shared checkpoint CLI surface (`train` and `coordinate`):
/// `--checkpoint-every k` snapshots the coordinator state every `k`
/// rounds to `--checkpoint-out` (default `checkpoint.bin`), and
/// `--resume <path>` restarts a run from a snapshot written by the
/// same config (fingerprint-checked).
fn checkpoint_cli(cli: Cli) -> Cli {
    cli.opt(
        "checkpoint-every",
        Some("0"),
        "write a durable coordinator snapshot every k rounds (0 = off)",
    )
    .opt(
        "checkpoint-out",
        None,
        "snapshot path (default checkpoint.bin when --checkpoint-every > 0)",
    )
    .opt(
        "resume",
        None,
        "resume from a snapshot written by --checkpoint-out; the run \
         config must fingerprint-match the snapshot's",
    )
}

fn checkpoint_from_cli(p: &Parsed) -> Result<CheckpointOptions, String> {
    let every = parse_checkpoint_every(&p.str("checkpoint-every"))
        .map_err(|e| e.to_string())?;
    let out = p
        .get("checkpoint-out")
        .map(String::from)
        .or_else(|| (every > 0).then(|| "checkpoint.bin".into()));
    let resume = match p.get("resume") {
        Some(token) => {
            Some(parse_resume_path(token).map_err(|e| e.to_string())?)
        }
        None => None,
    };
    Ok(CheckpointOptions { every, out, resume })
}

fn print_telemetry_summary(run: &RunResult) {
    if let Some(t) = &run.telemetry {
        println!("telemetry: {}", t.one_line());
    }
}

/// The shared kernel-backend CLI surface (`train`, `coordinate`,
/// `sweep`, `bench`): `--kernel-backend` selects the process-wide
/// kernel implementation set before any hot loop runs (DESIGN.md §12).
fn kernel_backend_cli(cli: Cli) -> Cli {
    cli.opt(
        "kernel-backend",
        Some("auto"),
        "kernel implementation set: auto|scalar|simd (auto = SIMD when \
         the CPU supports AVX2; both backends are bit-identical, scalar \
         pins the blocked reference path; forcing simd without AVX2 is \
         an error)",
    )
}

/// Resolve and install `--kernel-backend`, returning the active backend
/// (for the summary lines) or the usage error (exit 2 at call sites).
fn kernel_backend_from_cli(p: &Parsed) -> Result<dispatch::Backend, String> {
    let choice = dispatch::parse_backend(&p.str("kernel-backend"))?;
    dispatch::select(choice)
}

fn parse_or_exit(cli: &Cli, args: &[String]) -> Parsed {
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", cli.usage());
        std::process::exit(0);
    }
    match cli.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

fn cmd_train(args: &[String]) -> i32 {
    let cli = Cli::new("fedsamp train", "run one federated experiment")
        .opt("config", None, "JSON config file (see config module schema)")
        .opt("preset", None, "preset: femnist<V>, shakespeare<N>, cifar")
        .opt("strategy", Some("aocs"), "full|uniform|ocs|aocs[<j>]|caocs[<j>]|clustered[<k>]|cyclic[<g>]")
        .opt("rounds", None, "override communication rounds")
        .opt("m", None, "override expected budget m")
        .opt("seed", Some("1"), "RNG seed")
        .opt("seeds", Some("1"), "number of seeds to average")
        .opt("workers", None, "override worker threads")
        .opt(
            "compress",
            None,
            "update compressor: none|randk<K>|qsgd<S> (overrides the \
             config file's compressor; none disables)",
        )
        .opt(
            "faults",
            None,
            "chaos fault plan: '+'- or ','-joined kinds, e.g. \
             crash0.2+corrupt0.05 (crash|crashpre|crashpost|corrupt|\
             stall<p>, retries<k>, seed<k>, masterkill<r>; overrides the \
             config file's fault_plan)",
        )
        .opt("sim", Some("false"), "true = force native sim engine")
        .opt("out", None, "directory for JSON/CSV results")
        .opt("artifacts", None, "artifacts directory")
        .flag("verbose", "print per-round progress");
    let cli = kernel_backend_cli(checkpoint_cli(telemetry_cli(cli)));
    let p = parse_or_exit(&cli, args);
    if let Err(e) = kernel_backend_from_cli(&p) {
        eprintln!("{e}");
        return 2;
    }

    let mut cfg: ExperimentConfig = if let Some(path) = p.get("config") {
        match ExperimentConfig::load(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    } else {
        match preset_by_name(p.get("preset").unwrap_or("femnist1")) {
            Some(c) => c,
            None => return 2,
        }
    };

    let strategy = match Strategy::parse(&p.str("strategy")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    cfg = cfg.with_strategy(strategy);
    if let Some(r) = p.get("rounds") {
        cfg.rounds = r.parse().expect("--rounds");
    }
    if let Some(m) = p.get("m") {
        cfg.budget = m.parse().expect("--m");
    }
    if let Some(w) = p.get("workers") {
        cfg.workers = w.parse().expect("--workers");
    }
    cfg.seed = p.u64("seed");
    if p.str("sim") == "true" {
        cfg.model = "native:logistic".into();
    }
    // an explicitly passed --compress always wins over the config file
    // ("none" clears a config-level compressor); absent = config as-is
    if let Some(spec) = p.get("compress") {
        match Compressor::parse(spec) {
            Ok(Compressor::None) => cfg.compressor = None,
            Ok(c) => cfg.compressor = Some(c),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    if let Some(spec) = p.get("faults") {
        match parse_fault_spec(spec) {
            Ok(plan) => cfg.fault_plan = Some(plan),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let artifacts = p
        .get("artifacts")
        .map(String::from)
        .unwrap_or_else(default_artifacts_dir);
    let telemetry = telemetry_from_cli(&p);
    let checkpoint = match checkpoint_from_cli(&p) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let seeds = p.u64("seeds");
    if seeds > 1 && (checkpoint.every > 0 || checkpoint.resume.is_some()) {
        eprintln!(
            "--checkpoint-every/--resume describe one trajectory; they \
             cannot be combined with --seeds > 1"
        );
        return 2;
    }
    let opts = TrainOptions {
        verbose_every: if p.flag("verbose") { 1 } else { 10 },
        checkpoint,
        ..TrainOptions::default()
    };

    let mut runs = Vec::new();
    for s in 0..seeds {
        let mut c = cfg.clone();
        c.seed = cfg.seed + s;
        let mut o = opts.clone();
        // multi-seed runs get per-seed export paths so seed k's stream
        // does not clobber seed k-1's
        o.telemetry = if seeds > 1 {
            telemetry.with_seed_suffix(c.seed)
        } else {
            telemetry.clone()
        };
        match run_experiment(&c, &artifacts, &o) {
            Ok(r) => runs.push(r),
            Err(e) => {
                eprintln!("run failed: {e}");
                // a masterkill fault is a *planned* abort (chaos smoke):
                // give it a distinct exit code so CI can tell it from a
                // real failure
                return if e.starts_with(MASTERKILL_ERR_PREFIX) {
                    3
                } else {
                    1
                };
            }
        }
    }
    let avg = fedsamp::metrics::average_runs(&runs);
    print_run_summary(&avg);
    print_telemetry_summary(&avg);
    if let Some(out) = p.get("out") {
        match avg.save(out) {
            Ok(path) => println!("saved {path}"),
            Err(e) => eprintln!("save failed: {e}"),
        }
    }
    0
}

fn cmd_coordinate(args: &[String]) -> i32 {
    let cli = Cli::new(
        "fedsamp coordinate",
        "run the sharded round coordinator over the sim engine",
    )
    .opt("preset", Some("femnist1"), "preset: femnist<V>, shakespeare<N>, cifar")
    .opt("strategy", Some("aocs"), "full|uniform|ocs|aocs[<j>]|caocs[<j>]|clustered[<k>]|cyclic[<g>]")
    .opt("rounds", None, "override communication rounds")
    .opt("m", None, "override expected budget m")
    .opt("seed", Some("1"), "RNG seed")
    .opt("shards", Some("4"), "client-registry shards")
    .opt("workers", Some("0"), "shard-pool worker threads (0 = config value)")
    .opt(
        "deadline-miss",
        Some("0"),
        "per-round probability that a shard misses the deadline",
    )
    .opt(
        "faults",
        None,
        "chaos fault plan: '+'- or ','-joined kinds, e.g. \
         crash0.2,corrupt0.05 (crash|crashpre|crashpost|corrupt|\
         stall<p>, retries<k>, seed<k>, masterkill<r>)",
    )
    .opt("out", None, "directory for JSON/CSV results")
    .flag(
        "sharded-negotiation",
        "run the AOCS negotiation per shard (secure partial sums over \
         the worker pool) instead of centrally",
    )
    .flag("verbose", "print per-round progress");
    let cli = kernel_backend_cli(checkpoint_cli(telemetry_cli(cli)));
    let p = parse_or_exit(&cli, args);
    let backend = match kernel_backend_from_cli(&p) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    let mut cfg = match preset_by_name(&p.str("preset")) {
        Some(c) => c,
        None => return 2,
    };
    let strategy = match Strategy::parse(&p.str("strategy")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    cfg = cfg.with_strategy(strategy);
    cfg.name = format!("coord_{}", cfg.name);
    cfg.model = "native:logistic".into(); // coordinator CLI drives the sim path
    if let Some(r) = p.get("rounds") {
        cfg.rounds = r.parse().expect("--rounds");
    }
    if let Some(m) = p.get("m") {
        cfg.budget = m.parse().expect("--m");
    }
    cfg.seed = p.u64("seed");
    // --workers overrides the config's worker-thread field; both feed the
    // coordinator's shard pool
    let workers = match p.usize("workers") {
        0 => cfg.workers,
        w => {
            cfg.workers = w;
            w
        }
    };
    let shards = p.usize("shards");
    let miss = p.f64("deadline-miss");
    if !(0.0..=1.0).contains(&miss) {
        eprintln!("--deadline-miss must be in [0, 1]");
        return 2;
    }
    if let Some(spec) = p.get("faults") {
        match parse_fault_spec(spec) {
            Ok(plan) => cfg.fault_plan = Some(plan),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }

    let engine = build_native_engine(&cfg);
    let mut runner = ParallelRunner::new(engine, workers);
    let deadline = if miss > 0.0 {
        Some(DeadlinePolicy { miss_prob: miss })
    } else {
        None
    };
    let mut coordinator = Coordinator::new(CoordinatorOptions {
        shards,
        deadline,
        sharded_negotiation: p.flag("sharded-negotiation"),
    });
    let checkpoint = match checkpoint_from_cli(&p) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let opts = TrainOptions {
        verbose_every: if p.flag("verbose") { 1 } else { 10 },
        telemetry: telemetry_from_cli(&p),
        checkpoint,
        ..TrainOptions::default()
    };
    println!(
        "coordinator: {} shards, {} workers, {} kernels, \
         deadline-miss {miss}{}",
        shards,
        workers,
        backend.name(),
        if p.flag("sharded-negotiation") {
            ", sharded negotiation"
        } else {
            ""
        }
    );
    match coordinator.run(&cfg, &mut runner, &opts) {
        Ok(run) => {
            print_run_summary(&run);
            print_telemetry_summary(&run);
            println!(
                "coordinator stats: {} shard-rounds dropped, {} outaged, \
                 {} no-op rounds",
                coordinator.stats.shards_dropped,
                coordinator.stats.shards_outaged,
                coordinator.stats.noop_rounds
            );
            if cfg.fault_plan.is_some() {
                let f = &coordinator.stats.faults;
                println!(
                    "chaos stats: {} injected ({} crash-pre, {} crash-post, \
                     {} corrupt, {} stalls), {} repaired ({} mask repairs, \
                     {} quarantined, {} shards degraded), {} retries",
                    f.injected(),
                    f.crash_pre,
                    f.crash_post,
                    f.corrupt,
                    f.stalls,
                    f.repaired(),
                    f.mask_repairs,
                    f.quarantined,
                    f.shards_degraded,
                    f.retries
                );
            }
            if let Some(out) = p.get("out") {
                match run.save(out) {
                    Ok(path) => println!("saved {path}"),
                    Err(e) => eprintln!("save failed: {e}"),
                }
            }
            0
        }
        Err(e) => {
            eprintln!("coordinate failed: {e}");
            // planned masterkill abort (kill-and-resume smoke) → exit 3
            if e.starts_with(MASTERKILL_ERR_PREFIX) {
                3
            } else {
                1
            }
        }
    }
}

fn cmd_figures(args: &[String]) -> i32 {
    let cli = Cli::new("fedsamp figures", "regenerate a paper figure")
        .opt("fig", Some("3"), "figure id: 2, 3, 4, 5, 6, 7, 13")
        .opt("scale", Some("quick"), "quick|full (full = paper scale)")
        .opt("seeds", Some("1"), "seeds to average (paper: 5)")
        .opt("sim", Some("true"), "true = sim engine, false = XLA engine")
        .opt("out", None, "directory for JSON/CSV series")
        .opt("artifacts", None, "artifacts directory");
    let p = parse_or_exit(&cli, args);
    let scale = match Scale::parse(&p.str("scale")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let artifacts = p
        .get("artifacts")
        .map(String::from)
        .unwrap_or_else(default_artifacts_dir);
    let use_sim = p.str("sim") == "true";
    match run_figure(
        &p.str("fig"),
        scale,
        p.u64("seeds"),
        &artifacts,
        use_sim,
        p.get("out"),
        &TrainOptions::default(),
    ) {
        Ok(_) => 0,
        Err(e) => {
            eprintln!("figure failed: {e}");
            1
        }
    }
}

fn cmd_sweep(args: &[String]) -> i32 {
    let cli = Cli::new(
        "fedsamp sweep",
        "scenario grid sweeps (default kind=grid: strategy × compressor × \
         availability × pool with multi-seed averaging, emitting \
         BENCH_sweep.json + BENCH_sweep.csv) and the quadratic-testbed \
         theory sweeps (kind=stepsize|budget)",
    )
    .opt("kind", Some("grid"), "grid|stepsize|budget")
    .opt(
        "strategies",
        Some("full,uniform,ocs,aocs"),
        "grid: comma list of full|uniform|ocs|aocs[<j>]|caocs[<j>]|clustered[<k>]|cyclic[<g>]",
    )
    .opt(
        "compressors",
        Some("none,randk64"),
        "grid: comma list of none|randk<K>|qsgd<S>",
    )
    .opt(
        "availabilities",
        Some("alwayson,bern0.7,diurnal0.8"),
        "grid: comma list of alwayson|bern<q>|diurnal<q>|churn<q>|outage<p>",
    )
    .opt(
        "faults",
        Some("none"),
        "grid: comma list of chaos fault arms — none, or '+'-joined \
         kinds (crash|crashpre|crashpost|corrupt|stall<p>, retries<k>, \
         seed<k>), e.g. none,crash0.2+corrupt0.05",
    )
    .opt("pools", Some("60,240"), "grid: comma list of pool sizes")
    .opt("seeds", Some("3"), "grid: seeds averaged per arm")
    .opt("grid-rounds", Some("30"), "grid: rounds per run")
    .opt("out", Some("."), "grid: directory for BENCH_sweep.{json,csv}")
    .opt(
        "ledger",
        None,
        "grid: per-(arm,seed) completion ledger path; an interrupted \
         sweep rerun with the same spec + ledger resumes at the first \
         unfinished unit and emits byte-identical BENCH files",
    )
    .opt(
        "abort-after",
        None,
        "grid: abort after n newly completed units (sweep-resume CI \
         smoke; requires --ledger)",
    )
    .flag("quick", "grid: tiny CI smoke grid (overrides the axis flags)")
    .flag(
        "telemetry",
        "grid: attach a per-arm telemetry summary (phase latencies, \
         counters) to every BENCH_sweep.json arm record",
    )
    .flag("verbose", "grid: print one line per arm")
    .opt("n", Some("32"), "theory: number of clients")
    .opt("dim", Some("32"), "theory: problem dimension")
    .opt("ms", Some("2,4,8,16"), "theory: budgets to sweep (kind=budget)")
    .opt("m", Some("4"), "theory: budget (kind=stepsize)")
    .opt("rounds", Some("200"), "theory: rounds per run")
    .opt("seed", Some("1"), "seed");
    let cli = kernel_backend_cli(cli);
    let p = parse_or_exit(&cli, args);
    if let Err(e) = kernel_backend_from_cli(&p) {
        eprintln!("{e}");
        return 2;
    }

    if p.str("kind") == "grid" {
        use fedsamp::exp::sweep::{
            parse_availability_arm, parse_fault_arms, run_sweep_resumable,
            SweepSpec, SWEEP_ABORT_ERR_PREFIX,
        };
        let mut spec = if p.flag("quick") {
            SweepSpec::quick()
        } else {
            let mut strategies = Vec::new();
            for s in p.str("strategies").split(',').filter(|s| !s.is_empty())
            {
                match Strategy::parse(s.trim()) {
                    Ok(s) => strategies.push(s),
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            }
            let mut compressors = Vec::new();
            for c in p.str("compressors").split(',').filter(|s| !s.is_empty())
            {
                match Compressor::parse(c.trim()) {
                    Ok(c) => compressors.push(c),
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            }
            let mut availabilities = Vec::new();
            for a in
                p.str("availabilities").split(',').filter(|s| !s.is_empty())
            {
                match parse_availability_arm(a.trim()) {
                    Ok(a) => availabilities.push(a),
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            }
            let faults = match parse_fault_arms(&p.str("faults")) {
                Ok(f) => f,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let mut spec = SweepSpec::default_grid();
            spec.strategies = strategies;
            spec.compressors = compressors;
            spec.availabilities = availabilities;
            spec.faults = faults;
            spec.pools = p.usize_list("pools");
            spec.seeds = p.u64("seeds");
            spec.base_seed = p.u64("seed");
            spec.rounds = p.usize("grid-rounds");
            spec
        };
        spec.telemetry = p.flag("telemetry");
        if spec.arm_count() == 0 {
            eprintln!("empty sweep grid");
            return 2;
        }
        println!(
            "sweep grid: {} arms × {} seed(s), {} rounds each",
            spec.arm_count(),
            spec.seeds.max(1),
            spec.rounds
        );
        let ledger = p.get("ledger").map(String::from);
        let abort_after = match p.get("abort-after") {
            Some(n) => match n.parse::<usize>() {
                Ok(n) if n > 0 => Some(n),
                _ => {
                    eprintln!(
                        "--abort-after: expected a positive integer, \
                         got '{n}'"
                    );
                    return 2;
                }
            },
            None => None,
        };
        if abort_after.is_some() && ledger.is_none() {
            eprintln!("--abort-after requires --ledger");
            return 2;
        }
        let report = match run_sweep_resumable(
            &spec,
            ledger.as_deref(),
            abort_after,
            p.flag("verbose") || p.flag("quick"),
        ) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("sweep failed: {e}");
                // planned --abort-after kill (sweep-resume smoke) → exit 3
                return if e.starts_with(SWEEP_ABORT_ERR_PREFIX) {
                    3
                } else {
                    1
                };
            }
        };
        return match report.save(&p.str("out")) {
            Ok((json_path, csv_path)) => {
                println!("saved {json_path}\nsaved {csv_path}");
                0
            }
            Err(e) => {
                eprintln!("save failed: {e}");
                1
            }
        };
    }

    let n = p.usize("n");
    let problem = QuadraticProblem::generate(
        n,
        p.usize("dim"),
        3.0,
        8.0,
        None,
        p.u64("seed"),
    );
    println!(
        "quadratic testbed: n={n} dim={} L={:.3} mu={:.3}",
        p.usize("dim"),
        problem.smoothness(),
        problem.strong_convexity()
    );
    match p.str("kind").as_str() {
        "stepsize" => {
            let m = p.usize("m");
            let mut t = Table::new(&["strategy", "max_stable_eta", "eta*L"]);
            for s in [Sampler::Full, Sampler::Ocs, Sampler::Uniform] {
                let eta = max_stable_eta(&problem, &s, m, p.usize("rounds"), 5);
                t.row(vec![
                    s.name().into(),
                    f(eta, 4),
                    f(eta * problem.smoothness(), 3),
                ]);
            }
            t.print();
        }
        "budget" => {
            let rounds = p.usize("rounds");
            let mut t =
                Table::new(&["m", "strategy", "final_dist_sq", "mean_gamma"]);
            for m in p.usize_list("ms") {
                for s in [Sampler::Ocs, Sampler::Uniform] {
                    let eta = 0.25 / problem.smoothness();
                    let run = run_dsgd_quadratic(
                        &problem,
                        &s,
                        m,
                        eta,
                        rounds,
                        0.0,
                        p.u64("seed"),
                    );
                    t.row(vec![
                        m.to_string(),
                        s.name().into(),
                        format!("{:.3e}", run.final_dist()),
                        f(run.mean_gamma(), 3),
                    ]);
                }
            }
            t.print();
        }
        other => {
            eprintln!("unknown sweep kind '{other}'");
            return 2;
        }
    }
    0
}

fn cmd_bench(args: &[String]) -> i32 {
    let cli = Cli::new(
        "fedsamp bench",
        "perf suites; `bench kernels` measures scalar vs kernelized hot \
         loops, `bench secure` the secure-aggregation masking pipeline, \
         `bench comm` the wire layer (payload folds, codec, measured \
         bytes/round); each emits BENCH_<suite>.json",
    )
    .opt("suite", None, "suite name (or positional): kernels, secure, comm")
    .opt("out", Some("."), "directory for BENCH_<suite>.json")
    .flag("quick", "1-ish iteration per bench (CI smoke mode)");
    let cli = kernel_backend_cli(cli);
    let p = parse_or_exit(&cli, args);
    if let Err(e) = kernel_backend_from_cli(&p) {
        eprintln!("{e}");
        return 2;
    }
    let suite = p
        .get("suite")
        .map(String::from)
        .or_else(|| p.positionals.first().cloned())
        .unwrap_or_else(|| "kernels".into());
    let doc = match suite.as_str() {
        "kernels" => {
            fedsamp::exp::kernelbench::run_kernel_suite(p.flag("quick"))
        }
        "secure" => {
            fedsamp::exp::securebench::run_secure_suite(p.flag("quick"))
        }
        "comm" => fedsamp::exp::commbench::run_comm_suite(p.flag("quick")),
        other => {
            eprintln!(
                "unknown bench suite '{other}' (available: kernels, \
                 secure, comm)"
            );
            return 2;
        }
    };
    let dir = p.str("out");
    if let Err(e) = std::fs::create_dir_all(&dir) {
        eprintln!("cannot create {dir}: {e}");
        return 1;
    }
    let path = format!("{dir}/BENCH_{suite}.json");
    match std::fs::write(&path, doc.to_pretty()) {
        Ok(()) => {
            println!("saved {path}");
            0
        }
        Err(e) => {
            eprintln!("save failed: {e}");
            1
        }
    }
}

fn cmd_inspect(args: &[String]) -> i32 {
    let cli = Cli::new("fedsamp inspect", "show artifacts + dataset stats")
        .opt("artifacts", None, "artifacts directory")
        .opt("data", None, "dataset: femnist1..3|shakespeare|cifar");
    let p = parse_or_exit(&cli, args);
    let artifacts = p
        .get("artifacts")
        .map(String::from)
        .unwrap_or_else(default_artifacts_dir);
    match load_manifests(&artifacts) {
        Ok(models) => {
            let mut t = Table::new(&[
                "model", "kind", "params", "batch", "classes", "pallas",
            ]);
            for m in models {
                t.row(vec![
                    m.name.clone(),
                    m.kind.clone(),
                    m.num_params.to_string(),
                    m.batch_size.to_string(),
                    m.num_classes.to_string(),
                    m.use_pallas.to_string(),
                ]);
            }
            t.print();
        }
        Err(e) => println!("no artifacts: {e}"),
    }
    if let Some(ds) = p.get("data") {
        let spec = match ds {
            "femnist1" => {
                fedsamp::config::DataSpec::FemnistLike { pool: 350, variant: 1 }
            }
            "femnist2" => {
                fedsamp::config::DataSpec::FemnistLike { pool: 350, variant: 2 }
            }
            "femnist3" => {
                fedsamp::config::DataSpec::FemnistLike { pool: 350, variant: 3 }
            }
            "shakespeare" => {
                fedsamp::config::DataSpec::ShakespeareLike { pool: 715 }
            }
            "cifar" => fedsamp::config::DataSpec::CifarLike {
                pool: 500,
                per_client: 100,
            },
            other => {
                eprintln!("unknown dataset '{other}'");
                return 2;
            }
        };
        let fd = fedsamp::data::build(&spec, 64, 1);
        let sizes: Vec<f64> =
            fd.client_sizes().iter().map(|&s| s as f64).collect();
        let s = fedsamp::util::stats::summarize(&sizes);
        println!(
            "\n{ds}: {} clients, {} examples; per-client n: mean {:.1} \
             std {:.1} min {} max {} median {:.0}",
            fd.num_clients(),
            fd.total_examples(),
            s.mean,
            s.std,
            s.min,
            s.max,
            s.median
        );
    }
    0
}
