//! Telemetry exporters: newline-delimited JSON event log and Chrome
//! `trace_event` JSON (loadable in `chrome://tracing` / Perfetto).
//!
//! Both writers format into a reusable `String` line buffer and append
//! to a `BufWriter`, so steady-state export does no per-event heap
//! allocation. Write errors after a successful create are recorded once
//! and silence the writer — telemetry must never abort a training run.
//!
//! Final flush is **crash-safe**: writers stream into `<path>.tmp` and
//! atomically rename onto the real path at `finish()` (after fsync), so
//! a kill mid-run never leaves a truncated log where a complete one is
//! expected — the same write sequence as `crate::checkpoint`
//! (DESIGN.md §11). A run that dies before `finish()` leaves only the
//! `.tmp` file.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use super::JobTiming;

fn create_file(path: &str) -> Result<BufWriter<File>, String> {
    if let Some(dir) = Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("telemetry: mkdir {}: {e}", dir.display()))?;
        }
    }
    let f = File::create(path).map_err(|e| format!("telemetry: create {path}: {e}"))?;
    Ok(BufWriter::new(f))
}

/// Flush + fsync the buffered tmp file and rename it onto `path`.
/// Errors silence-warn, matching the writers' never-abort contract.
fn finalize_atomic(mut w: BufWriter<File>, path: &str) {
    let tmp = format!("{path}.tmp");
    if w.flush().is_err() || w.get_ref().sync_all().is_err() {
        eprintln!("telemetry: final flush of {tmp} failed");
        return;
    }
    drop(w);
    if std::fs::rename(&tmp, path).is_err() {
        eprintln!("telemetry: rename {tmp} -> {path} failed");
    }
}

/// One JSON object per line; schema documented in DESIGN.md §9.
pub struct JsonlWriter {
    w: BufWriter<File>,
    path: String,
    line: String,
    ok: bool,
}

impl JsonlWriter {
    pub fn create(path: &str) -> Result<JsonlWriter, String> {
        Ok(JsonlWriter {
            w: create_file(&format!("{path}.tmp"))?,
            path: path.to_string(),
            line: String::new(),
            ok: true,
        })
    }

    pub fn span(&mut self, name: &str, end: bool, round: usize, t_ns: u64, dur_ns: u64) {
        self.line.clear();
        let ev = if end { "span_end" } else { "span_begin" };
        let _ = write!(
            self.line,
            "{{\"ev\":\"{ev}\",\"name\":\"{name}\",\"round\":{round},\"t_ns\":{t_ns}"
        );
        if end {
            let _ = write!(self.line, ",\"dur_ns\":{dur_ns}");
        }
        self.line.push('}');
        self.emit();
    }

    pub fn counter(&mut self, name: &str, round: usize, value: u64) {
        self.line.clear();
        let _ = write!(
            self.line,
            "{{\"ev\":\"counter\",\"name\":\"{name}\",\"round\":{round},\"value\":{value}}}"
        );
        self.emit();
    }

    pub fn job(&mut self, round: usize, t: &JobTiming) {
        self.line.clear();
        let _ = write!(
            self.line,
            "{{\"ev\":\"job\",\"kind\":\"{}\",\"round\":{round},\"worker\":{},\
             \"start_ns\":{},\"queue_ns\":{},\"exec_ns\":{},\"items\":{}}}",
            t.kind.name(),
            t.worker,
            t.start_ns,
            t.queue_ns,
            t.exec_ns,
            t.items
        );
        self.emit();
    }

    pub fn finish(mut self, rounds: usize) {
        self.line.clear();
        let _ = write!(self.line, "{{\"ev\":\"run_end\",\"rounds\":{rounds}}}");
        self.emit();
        if self.ok {
            let path = std::mem::take(&mut self.path);
            finalize_atomic(self.w, &path);
        }
    }

    fn emit(&mut self) {
        if !self.ok {
            return;
        }
        self.line.push('\n');
        if self.w.write_all(self.line.as_bytes()).is_err() {
            self.ok = false;
            eprintln!("telemetry: jsonl write failed; disabling event log");
        }
    }
}

/// Chrome `trace_event` JSON: `{"traceEvents":[...]}` with B/E duration
/// events for round phases (tid 0 = coordinator master) and X complete
/// events for pool jobs (tid = worker + 1). Timestamps are microseconds
/// with sub-µs precision as Chrome expects.
pub struct TraceWriter {
    w: BufWriter<File>,
    path: String,
    line: String,
    first: bool,
    ok: bool,
}

impl TraceWriter {
    pub fn create(path: &str) -> Result<TraceWriter, String> {
        let mut w = create_file(&format!("{path}.tmp"))?;
        let ok = w.write_all(b"{\"traceEvents\":[").is_ok();
        Ok(TraceWriter { w, path: path.to_string(), line: String::new(), first: true, ok })
    }

    pub fn phase(&mut self, name: &str, end: bool, round: usize, t_ns: u64) {
        self.line.clear();
        let ph = if end { "E" } else { "B" };
        let _ = write!(
            self.line,
            "{{\"name\":\"{name}\",\"cat\":\"round\",\"ph\":\"{ph}\",\"pid\":1,\"tid\":0,\
             \"ts\":{:.3},\"args\":{{\"round\":{round}}}}}",
            t_ns as f64 / 1_000.0
        );
        self.emit();
    }

    pub fn job(&mut self, round: usize, t: &JobTiming) {
        self.line.clear();
        let _ = write!(
            self.line,
            "{{\"name\":\"{}\",\"cat\":\"job\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
             \"ts\":{:.3},\"dur\":{:.3},\
             \"args\":{{\"round\":{round},\"queue_ns\":{},\"items\":{}}}}}",
            t.kind.name(),
            t.worker + 1,
            t.start_ns as f64 / 1_000.0,
            t.exec_ns as f64 / 1_000.0,
            t.queue_ns,
            t.items
        );
        self.emit();
    }

    pub fn finish(mut self) {
        if self.ok && self.w.write_all(b"]}").is_ok() {
            let path = std::mem::take(&mut self.path);
            finalize_atomic(self.w, &path);
        }
    }

    fn emit(&mut self) {
        if !self.ok {
            return;
        }
        if self.first {
            self.first = false;
        } else {
            self.line.insert(0, ',');
        }
        if self.w.write_all(self.line.as_bytes()).is_err() {
            self.ok = false;
            eprintln!("telemetry: trace write failed; disabling trace export");
        }
    }
}
