//! Opt-in runtime telemetry: round-phase spans, shard/worker job timing
//! histograms, per-round counters, and machine-readable trace export.
//!
//! Design contract (DESIGN.md §9):
//!
//! * **Off by default, bitwise-free when off.** [`TelemetryConfig`]
//!   defaults to disabled; a disabled [`Telemetry`] never reads the
//!   clock, never allocates, and never touches an RNG stream, so
//!   trajectories are bit-identical with or without the subsystem
//!   compiled in the call path.
//! * **Allocation-light when on.** Events are fixed-size `Copy` values
//!   pushed into a preallocated ring that is drained to the exporters at
//!   each Commit (or when full); histograms are fixed 65-bucket
//!   [`LogHistogram`]s; counter names are `&'static str`.
//! * **Never aborts a run.** Export I/O errors disable the writer and
//!   warn once; recording continues into the in-memory summary.
//!
//! The recorder is fed by [`crate::coordinator::RoundMachine`] (phase
//! spans + counters) and by [`crate::coordinator::LocalRunner`]
//! implementations (per-job [`JobTiming`]s measured inside the
//! `ShardPool` workers), and folds everything into a
//! [`TelemetrySummary`] merged into run JSON and sweep arm records.

pub mod clock;
pub mod export;

pub use clock::{Clock, ManualClock, MonoClock};

use std::sync::Arc;

use crate::util::json::Json;
use crate::util::stats::{LogHistogram, LogSummary};
use crate::wire::Payload;
use export::{JsonlWriter, TraceWriter};

/// The seven phases of one federated round, in protocol order, plus the
/// out-of-round `Checkpoint` span (a cadenced snapshot write after
/// Commit — see `crate::checkpoint`). The Repair span doubles as the
/// repair-latency histogram: it is recorded every committed round, so a
/// fault-free round contributes its (near zero) baseline and chaos runs
/// surface the recovery cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseSpan {
    Announce = 0,
    LocalCompute = 1,
    NormReport = 2,
    Negotiate = 3,
    SecureAggregate = 4,
    Repair = 5,
    Commit = 6,
    /// Durable snapshot write (only on `--checkpoint-every` rounds).
    Checkpoint = 7,
}

/// Number of *per-round* phases — every committed round emits exactly
/// one span per phase in `PHASE_NAMES[..NUM_ROUND_PHASES]`; the
/// trailing `checkpoint` span fires only on snapshot cadence rounds.
pub const NUM_ROUND_PHASES: usize = 7;

pub const PHASE_NAMES: [&str; 8] = [
    "announce",
    "local_compute",
    "norm_report",
    "negotiate",
    "secure_aggregate",
    "repair",
    "commit",
    "checkpoint",
];

impl PhaseSpan {
    pub fn name(self) -> &'static str {
        PHASE_NAMES[self as usize]
    }
}

/// Worker-pool job kinds timed inside `ShardPool` (and on the inline
/// single-worker paths).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// One client's local epochs (LocalCompute phase).
    Local = 0,
    /// Fused encode+scale+mask partial for one pairwise-mask group.
    MaskFold = 1,
    /// Masked scalar partial for one group (AOCS negotiation).
    ScalarFold = 2,
}

pub const JOB_KIND_NAMES: [&str; 3] = ["local", "mask_fold", "scalar_fold"];

impl JobKind {
    pub fn name(self) -> &'static str {
        JOB_KIND_NAMES[self as usize]
    }
}

/// One measured job: when it started, how long it waited in the queue,
/// how long it executed, which worker ran it, and its work size (clients
/// for `Local`, group members for folds).
#[derive(Clone, Copy, Debug)]
pub struct JobTiming {
    pub kind: JobKind,
    pub worker: usize,
    pub start_ns: u64,
    pub queue_ns: u64,
    pub exec_ns: u64,
    pub items: u64,
}

/// Per-round counters the round machine decides but (pre-telemetry)
/// never reported. Values accumulate within a round and are emitted +
/// rolled into run totals at Commit.
#[derive(Clone, Copy, Debug)]
pub enum Counter {
    /// Cohort size drawn from availability, before deadline drops.
    ClientsAnnounced = 0,
    /// Cohort members dropped by the per-shard deadline model.
    ClientsDeadlineDropped = 1,
    /// Clients with `selected[i] = 1` after the sampling draw.
    ClientsSelected = 2,
    /// Clients that actually uploaded a payload.
    ClientsTransmitted = 3,
    /// Shards offline for the whole round (pre-selection outage).
    ShardsOutaged = 4,
    /// Shards that missed the reporting deadline (post-selection drop).
    ShardsDeadlineDropped = 5,
    /// Negotiation round trips this round (0 = fixed-probability).
    NegotiationRounds = 6,
    /// Extra uplink floats across the cohort spent on negotiation.
    NegotiationUplinkFloats = 7,
    PayloadsDense = 8,
    PayloadsSparse = 9,
    PayloadsQuantized = 10,
    PayloadBytesDense = 11,
    PayloadBytesSparse = 12,
    PayloadBytesQuantized = 13,
    /// Injected crash-before-upload faults (chaos layer).
    FaultsCrashPre = 14,
    /// Injected crash-after-mask-commitment faults.
    FaultsCrashPost = 15,
    /// Injected payload corruption/truncation faults.
    FaultsCorrupt = 16,
    /// Stalled negotiation-partial delivery attempts.
    FaultsStalled = 17,
    /// Retry attempts issued for stalled negotiation partials.
    NegotiationRetries = 18,
    /// Shards degraded to last-good probabilities after retries ran out.
    ShardsDegraded = 19,
    /// Clients quarantined because their payload failed integrity checks.
    ClientsQuarantined = 20,
    /// Post-commit dropouts whose mask residue was repaired out.
    MaskRepairs = 21,
    /// Durable coordinator snapshots written (`--checkpoint-every`).
    CheckpointsWritten = 22,
    /// Total encoded snapshot bytes written.
    CheckpointBytes = 23,
    /// Runs restored from a snapshot (`--resume`); 0 or 1 per process.
    Resumes = 24,
}

pub const COUNTER_NAMES: [&str; NUM_COUNTERS] = [
    "clients_announced",
    "clients_deadline_dropped",
    "clients_selected",
    "clients_transmitted",
    "shards_outaged",
    "shards_deadline_dropped",
    "negotiation_rounds",
    "negotiation_uplink_floats",
    "payloads_dense",
    "payloads_sparse",
    "payloads_quantized",
    "payload_bytes_dense",
    "payload_bytes_sparse",
    "payload_bytes_quantized",
    "faults_crash_pre",
    "faults_crash_post",
    "faults_corrupt",
    "faults_stalled",
    "negotiation_retries",
    "shards_degraded",
    "clients_quarantined",
    "mask_repairs",
    "checkpoints_written",
    "checkpoint_bytes",
    "resumes",
];

const NUM_COUNTERS: usize = 25;

/// Event ring capacity; full ring forces an early drain to the writers.
const RING_CAPACITY: usize = 8192;

/// Configuration for one run's telemetry. Default = fully disabled.
#[derive(Clone, Debug, Default)]
pub struct TelemetryConfig {
    pub enabled: bool,
    /// Per-run JSONL event log path (`None` = summary only).
    pub jsonl_out: Option<String>,
    /// Chrome `trace_event` JSON path (`None` = no trace export).
    pub trace_out: Option<String>,
    /// Use the deterministic auto-ticking [`ManualClock`] (1 µs/read)
    /// instead of the wall monotonic clock; for reproducible traces in
    /// tests.
    pub manual_clock: bool,
}

impl TelemetryConfig {
    pub fn off() -> TelemetryConfig {
        TelemetryConfig::default()
    }

    /// Enabled, in-memory summary only — no file exports.
    pub fn summary_only() -> TelemetryConfig {
        TelemetryConfig { enabled: true, ..TelemetryConfig::default() }
    }

    /// Rewrite output paths with a `.seed<k>` suffix so multi-seed runs
    /// don't clobber each other's logs.
    pub fn with_seed_suffix(&self, seed: u64) -> TelemetryConfig {
        let tag = |p: &Option<String>| p.as_ref().map(|p| format!("{p}.seed{seed}"));
        TelemetryConfig {
            enabled: self.enabled,
            jsonl_out: tag(&self.jsonl_out),
            trace_out: tag(&self.trace_out),
            manual_clock: self.manual_clock,
        }
    }
}

#[derive(Clone, Copy, Debug)]
enum Event {
    Begin { phase: usize, round: usize, t_ns: u64 },
    End { phase: usize, round: usize, t_ns: u64, dur_ns: u64 },
    Count { id: usize, round: usize, value: u64 },
    Job { round: usize, timing: JobTiming },
}

/// The per-run recorder. Construct with [`Telemetry::from_config`];
/// every recording method is a no-op when disabled.
pub struct Telemetry {
    enabled: bool,
    clock: Arc<dyn Clock>,
    events: Vec<Event>,
    jsonl: Option<JsonlWriter>,
    trace: Option<TraceWriter>,
    span_t0: [u64; 8],
    phase_hist: Vec<LogHistogram>,
    exec_hist: Vec<LogHistogram>,
    queue_hist: Vec<LogHistogram>,
    items_hist: Vec<LogHistogram>,
    payload_hist: LogHistogram,
    round_counters: [u64; NUM_COUNTERS],
    total_counters: [u64; NUM_COUNTERS],
    rounds_flushed: usize,
    timing_scratch: Vec<JobTiming>,
}

impl Telemetry {
    /// A recorder that records nothing; for tests and telemetry-off
    /// call paths. Performs no allocation.
    pub fn disabled() -> Telemetry {
        Telemetry {
            enabled: false,
            clock: Arc::new(ManualClock::new(0)),
            events: Vec::new(),
            jsonl: None,
            trace: None,
            span_t0: [0; 8],
            phase_hist: Vec::new(),
            exec_hist: Vec::new(),
            queue_hist: Vec::new(),
            items_hist: Vec::new(),
            payload_hist: LogHistogram::new(),
            round_counters: [0; NUM_COUNTERS],
            total_counters: [0; NUM_COUNTERS],
            rounds_flushed: 0,
            timing_scratch: Vec::new(),
        }
    }

    /// Build a recorder from config; opens export files eagerly so path
    /// errors surface before the run starts.
    pub fn from_config(cfg: &TelemetryConfig) -> Result<Telemetry, String> {
        if !cfg.enabled {
            return Ok(Telemetry::disabled());
        }
        let clock: Arc<dyn Clock> = if cfg.manual_clock {
            Arc::new(ManualClock::new(1_000))
        } else {
            Arc::new(MonoClock::new())
        };
        let jsonl = match &cfg.jsonl_out {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        let trace = match &cfg.trace_out {
            Some(p) => Some(TraceWriter::create(p)?),
            None => None,
        };
        Ok(Telemetry {
            enabled: true,
            clock,
            events: Vec::with_capacity(RING_CAPACITY),
            jsonl,
            trace,
            span_t0: [0; 8],
            phase_hist: (0..8).map(|_| LogHistogram::new()).collect(),
            exec_hist: (0..3).map(|_| LogHistogram::new()).collect(),
            queue_hist: (0..3).map(|_| LogHistogram::new()).collect(),
            items_hist: (0..3).map(|_| LogHistogram::new()).collect(),
            payload_hist: LogHistogram::new(),
            round_counters: [0; NUM_COUNTERS],
            total_counters: [0; NUM_COUNTERS],
            rounds_flushed: 0,
            timing_scratch: Vec::with_capacity(256),
        })
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The clock to install into runners via `LocalRunner::set_clock`.
    pub fn clock(&self) -> Arc<dyn Clock> {
        Arc::clone(&self.clock)
    }

    pub fn span_begin(&mut self, round: usize, phase: PhaseSpan) {
        if !self.enabled {
            return;
        }
        let t = self.clock.now_ns();
        self.span_t0[phase as usize] = t;
        self.push(Event::Begin { phase: phase as usize, round, t_ns: t });
    }

    pub fn span_end(&mut self, round: usize, phase: PhaseSpan) {
        if !self.enabled {
            return;
        }
        let t = self.clock.now_ns();
        let dur = t.saturating_sub(self.span_t0[phase as usize]);
        self.phase_hist[phase as usize].record(dur);
        self.push(Event::End { phase: phase as usize, round, t_ns: t, dur_ns: dur });
    }

    /// Accumulate `v` into a per-round counter.
    pub fn add(&mut self, c: Counter, v: u64) {
        if self.enabled {
            self.round_counters[c as usize] += v;
        }
    }

    /// Record one uploaded payload: size histogram + per-variant
    /// count/byte counters.
    pub fn payload(&mut self, p: &Payload) {
        if !self.enabled {
            return;
        }
        let bytes = p.wire_bytes() as u64;
        self.payload_hist.record(bytes);
        let (count, total) = match p {
            Payload::Dense(_) => (Counter::PayloadsDense, Counter::PayloadBytesDense),
            Payload::SparseK { .. } => (Counter::PayloadsSparse, Counter::PayloadBytesSparse),
            Payload::Quantized { .. } => {
                (Counter::PayloadsQuantized, Counter::PayloadBytesQuantized)
            }
        };
        self.add(count, 1);
        self.add(total, bytes);
    }

    /// Drain job timings out of a runner (via `drain`, which appends
    /// into the provided buffer) and fold them into histograms and the
    /// event ring. The buffer is reused across calls.
    pub fn collect_jobs(&mut self, round: usize, drain: &mut dyn FnMut(&mut Vec<JobTiming>)) {
        if !self.enabled {
            return;
        }
        let mut buf = std::mem::take(&mut self.timing_scratch);
        buf.clear();
        drain(&mut buf);
        for t in &buf {
            self.exec_hist[t.kind as usize].record(t.exec_ns);
            self.queue_hist[t.kind as usize].record(t.queue_ns);
            self.items_hist[t.kind as usize].record(t.items);
            self.push(Event::Job { round, timing: *t });
        }
        self.timing_scratch = buf;
    }

    /// Record one durable snapshot write of `bytes` encoded bytes.
    /// Checkpoints happen *after* Commit has already flushed the round's
    /// counters, so these bump the run totals directly (a cadence write
    /// after the final round would otherwise be lost) and emit their
    /// count events immediately.
    pub fn checkpoint_written(&mut self, round: usize, bytes: u64) {
        if !self.enabled {
            return;
        }
        self.total_counters[Counter::CheckpointsWritten as usize] += 1;
        self.total_counters[Counter::CheckpointBytes as usize] += bytes;
        self.push(Event::Count { id: Counter::CheckpointsWritten as usize, round, value: 1 });
        self.push(Event::Count { id: Counter::CheckpointBytes as usize, round, value: bytes });
    }

    /// Record a restore-from-snapshot (fires once, before the resumed
    /// round loop starts).
    pub fn resumed(&mut self, round: usize) {
        if !self.enabled {
            return;
        }
        self.total_counters[Counter::Resumes as usize] += 1;
        self.push(Event::Count { id: Counter::Resumes as usize, round, value: 1 });
    }

    /// The run-total counters + rounds flushed, for inclusion in a
    /// snapshot. Empty when telemetry is off (a resumed run may enable
    /// or disable telemetry independently of the killed one).
    pub fn checkpoint_state(&self) -> (Vec<u64>, usize) {
        if !self.enabled {
            return (Vec::new(), 0);
        }
        (self.total_counters.to_vec(), self.rounds_flushed)
    }

    /// Restore run-total counters + rounds flushed from a snapshot. A
    /// length mismatch (snapshot from a build with different counters,
    /// or telemetry off when it was taken) restores nothing — counters
    /// then cover only the post-resume segment.
    pub fn restore_counters(&mut self, totals: &[u64], rounds: usize) {
        if !self.enabled || totals.len() != NUM_COUNTERS {
            return;
        }
        self.total_counters.copy_from_slice(totals);
        self.rounds_flushed = rounds;
    }

    /// End-of-round flush: emit counter events, roll round counters into
    /// run totals, and drain the event ring to the exporters.
    pub fn flush_round(&mut self, round: usize) {
        if !self.enabled {
            return;
        }
        for id in 0..NUM_COUNTERS {
            let value = self.round_counters[id];
            if value > 0 {
                self.push(Event::Count { id, round, value });
            }
            self.total_counters[id] += value;
            self.round_counters[id] = 0;
        }
        self.rounds_flushed += 1;
        self.drain_events();
    }

    /// Finalize: drain remaining events, close export files, and return
    /// the in-memory summary. `None` when disabled.
    pub fn finish(mut self) -> Option<TelemetrySummary> {
        if !self.enabled {
            return None;
        }
        self.drain_events();
        if let Some(w) = self.jsonl.take() {
            w.finish(self.rounds_flushed);
        }
        if let Some(w) = self.trace.take() {
            w.finish();
        }
        let zip = |hists: &[LogHistogram], names: &[&'static str]| {
            hists
                .iter()
                .zip(names.iter())
                .map(|(h, &n)| (n, h.summary()))
                .collect::<Vec<_>>()
        };
        Some(TelemetrySummary {
            rounds: self.rounds_flushed,
            phases: zip(&self.phase_hist, &PHASE_NAMES),
            job_exec: zip(&self.exec_hist, &JOB_KIND_NAMES),
            job_queue: zip(&self.queue_hist, &JOB_KIND_NAMES),
            job_items: zip(&self.items_hist, &JOB_KIND_NAMES),
            payload_bytes: self.payload_hist.summary(),
            counters: COUNTER_NAMES
                .iter()
                .zip(self.total_counters.iter())
                .map(|(&n, &v)| (n, v))
                .collect(),
        })
    }

    fn push(&mut self, e: Event) {
        if self.events.len() == RING_CAPACITY {
            self.drain_events();
        }
        self.events.push(e);
    }

    fn drain_events(&mut self) {
        if self.jsonl.is_none() && self.trace.is_none() {
            self.events.clear();
            return;
        }
        for i in 0..self.events.len() {
            let e = self.events[i];
            match e {
                Event::Begin { phase, round, t_ns } => {
                    let name = PHASE_NAMES[phase];
                    if let Some(w) = &mut self.jsonl {
                        w.span(name, false, round, t_ns, 0);
                    }
                    if let Some(w) = &mut self.trace {
                        w.phase(name, false, round, t_ns);
                    }
                }
                Event::End { phase, round, t_ns, dur_ns } => {
                    let name = PHASE_NAMES[phase];
                    if let Some(w) = &mut self.jsonl {
                        w.span(name, true, round, t_ns, dur_ns);
                    }
                    if let Some(w) = &mut self.trace {
                        w.phase(name, true, round, t_ns);
                    }
                }
                Event::Count { id, round, value } => {
                    if let Some(w) = &mut self.jsonl {
                        w.counter(COUNTER_NAMES[id], round, value);
                    }
                }
                Event::Job { round, timing } => {
                    if let Some(w) = &mut self.jsonl {
                        w.job(round, &timing);
                    }
                    if let Some(w) = &mut self.trace {
                        w.job(round, &timing);
                    }
                }
            }
        }
        self.events.clear();
    }
}

/// End-of-run rollup merged into run JSON (`"telemetry"` key) and sweep
/// arm records: per-phase latency summaries, per-job-kind exec/queue
/// latency and size summaries, payload size summary, and run-total
/// counters.
#[derive(Clone, Debug)]
pub struct TelemetrySummary {
    pub rounds: usize,
    pub phases: Vec<(&'static str, LogSummary)>,
    pub job_exec: Vec<(&'static str, LogSummary)>,
    pub job_queue: Vec<(&'static str, LogSummary)>,
    pub job_items: Vec<(&'static str, LogSummary)>,
    pub payload_bytes: LogSummary,
    pub counters: Vec<(&'static str, u64)>,
}

fn log_summary_json(s: &LogSummary) -> Json {
    Json::obj(vec![
        ("n", Json::num(s.n as f64)),
        ("mean", Json::num(s.mean)),
        ("p50", Json::num(s.p50)),
        ("p90", Json::num(s.p90)),
        ("p99", Json::num(s.p99)),
        ("max", Json::num(s.max as f64)),
    ])
}

impl TelemetrySummary {
    pub fn to_json(&self) -> Json {
        let section = |xs: &[(&'static str, LogSummary)]| {
            Json::obj(xs.iter().map(|(n, s)| (*n, log_summary_json(s))).collect())
        };
        Json::obj(vec![
            ("rounds", Json::num(self.rounds as f64)),
            ("phases_ns", section(&self.phases)),
            (
                "jobs",
                Json::obj(vec![
                    ("exec_ns", section(&self.job_exec)),
                    ("queue_ns", section(&self.job_queue)),
                    ("items", section(&self.job_items)),
                ]),
            ),
            ("payload_bytes", log_summary_json(&self.payload_bytes)),
            (
                "counters",
                Json::obj(
                    self.counters.iter().map(|(n, v)| (*n, Json::num(*v as f64))).collect(),
                ),
            ),
        ])
    }

    /// Run-total counter by name; 0 if absent.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    /// Phase latency summary by name.
    pub fn phase(&self, name: &str) -> Option<&LogSummary> {
        self.phases.iter().find(|(n, _)| *n == name).map(|(_, s)| s)
    }

    /// Job exec-latency summary by kind name.
    pub fn job_exec(&self, name: &str) -> Option<&LogSummary> {
        self.job_exec.iter().find(|(n, _)| *n == name).map(|(_, s)| s)
    }

    /// Compact single-line rendering for CLI output.
    pub fn one_line(&self) -> String {
        let us = |x: f64| x / 1_000.0;
        let lc = self.phase("local_compute").cloned().unwrap_or_else(LogSummary::empty);
        let sa = self.phase("secure_aggregate").cloned().unwrap_or_else(LogSummary::empty);
        format!(
            "rounds={} local_compute p50={:.1}us p99={:.1}us | secure_aggregate p50={:.1}us \
             p99={:.1}us | payload_bytes p50={:.0} | transmitted={}",
            self.rounds,
            us(lc.p50),
            us(lc.p99),
            us(sa.p50),
            us(sa.p99),
            self.payload_bytes.p50,
            self.counter("clients_transmitted"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let mut tel = Telemetry::disabled();
        assert!(!tel.enabled());
        tel.span_begin(0, PhaseSpan::Announce);
        tel.add(Counter::ClientsAnnounced, 5);
        tel.span_end(0, PhaseSpan::Announce);
        tel.flush_round(0);
        assert!(tel.finish().is_none());
    }

    #[test]
    fn summary_only_records_spans_and_counters() {
        let cfg = TelemetryConfig { manual_clock: true, ..TelemetryConfig::summary_only() };
        let mut tel = Telemetry::from_config(&cfg).unwrap();
        for round in 0..3 {
            tel.span_begin(round, PhaseSpan::LocalCompute);
            tel.span_end(round, PhaseSpan::LocalCompute);
            tel.add(Counter::ClientsAnnounced, 10);
            tel.add(Counter::ClientsTransmitted, 4);
            tel.flush_round(round);
        }
        let s = tel.finish().unwrap();
        assert_eq!(s.rounds, 3);
        let lc = s.phase("local_compute").unwrap();
        assert_eq!(lc.n, 3);
        // ManualClock ticks 1 µs per read: every span lasts exactly 1 µs.
        assert_eq!(lc.max, 1_000);
        assert_eq!(s.counter("clients_announced"), 30);
        assert_eq!(s.counter("clients_transmitted"), 12);
        assert_eq!(s.counter("shards_outaged"), 0);
    }

    #[test]
    fn collect_jobs_feeds_histograms() {
        let cfg = TelemetryConfig { manual_clock: true, ..TelemetryConfig::summary_only() };
        let mut tel = Telemetry::from_config(&cfg).unwrap();
        tel.collect_jobs(0, &mut |buf| {
            for w in 0..4u64 {
                buf.push(JobTiming {
                    kind: JobKind::Local,
                    worker: w as usize,
                    start_ns: w * 100,
                    queue_ns: w * 10,
                    exec_ns: 1_000 + w,
                    items: 1,
                });
            }
        });
        tel.flush_round(0);
        let s = tel.finish().unwrap();
        let exec = s.job_exec("local").unwrap();
        assert_eq!(exec.n, 4);
        assert!(exec.p50 <= exec.p99 && exec.p99 <= exec.max as f64);
        assert_eq!(exec.max, 1_003);
    }

    #[test]
    fn payload_variants_split_counters() {
        let cfg = TelemetryConfig { manual_clock: true, ..TelemetryConfig::summary_only() };
        let mut tel = Telemetry::from_config(&cfg).unwrap();
        let dense = Payload::Dense(vec![1.0; 8]);
        let sparse = Payload::SparseK { indices: vec![0, 3], values: vec![1.0, 2.0] };
        tel.payload(&dense);
        tel.payload(&dense);
        tel.payload(&sparse);
        tel.flush_round(0);
        let s = tel.finish().unwrap();
        assert_eq!(s.counter("payloads_dense"), 2);
        assert_eq!(s.counter("payloads_sparse"), 1);
        assert_eq!(s.counter("payloads_quantized"), 0);
        assert_eq!(s.counter("payload_bytes_dense"), 2 * dense.wire_bytes() as u64);
        assert_eq!(s.counter("payload_bytes_sparse"), sparse.wire_bytes() as u64);
        assert_eq!(s.payload_bytes.n, 3);
    }

    #[test]
    fn checkpoint_counters_survive_the_final_flush() {
        let cfg = TelemetryConfig { manual_clock: true, ..TelemetryConfig::summary_only() };
        let mut tel = Telemetry::from_config(&cfg).unwrap();
        tel.flush_round(0);
        // checkpoint lands after the round's flush — totals must still
        // see it at finish()
        tel.span_begin(0, PhaseSpan::Checkpoint);
        tel.span_end(0, PhaseSpan::Checkpoint);
        tel.checkpoint_written(0, 512);
        let s = tel.finish().unwrap();
        assert_eq!(s.counter("checkpoints_written"), 1);
        assert_eq!(s.counter("checkpoint_bytes"), 512);
        assert_eq!(s.counter("resumes"), 0);
        assert_eq!(s.phase("checkpoint").unwrap().n, 1);
    }

    #[test]
    fn restore_counters_round_trips_checkpoint_state() {
        let cfg = TelemetryConfig { manual_clock: true, ..TelemetryConfig::summary_only() };
        let mut a = Telemetry::from_config(&cfg).unwrap();
        a.add(Counter::ClientsTransmitted, 7);
        a.flush_round(0);
        let (totals, rounds) = a.checkpoint_state();
        assert_eq!(rounds, 1);

        let mut b = Telemetry::from_config(&cfg).unwrap();
        b.restore_counters(&totals, rounds);
        b.resumed(1);
        b.add(Counter::ClientsTransmitted, 3);
        b.flush_round(1);
        let s = b.finish().unwrap();
        assert_eq!(s.rounds, 2);
        assert_eq!(s.counter("clients_transmitted"), 10);
        assert_eq!(s.counter("resumes"), 1);

        // length-mismatched restores are ignored, not mis-mapped
        let mut c = Telemetry::from_config(&cfg).unwrap();
        c.restore_counters(&[1, 2, 3], 9);
        c.flush_round(0);
        assert_eq!(c.finish().unwrap().rounds, 1);

        // disabled recorders expose no state
        assert_eq!(Telemetry::disabled().checkpoint_state(), (Vec::new(), 0));
    }

    #[test]
    fn seed_suffix_rewrites_paths() {
        let cfg = TelemetryConfig {
            enabled: true,
            jsonl_out: Some("tel.jsonl".into()),
            trace_out: Some("trace.json".into()),
            manual_clock: false,
        };
        let s = cfg.with_seed_suffix(3);
        assert_eq!(s.jsonl_out.as_deref(), Some("tel.jsonl.seed3"));
        assert_eq!(s.trace_out.as_deref(), Some("trace.json.seed3"));
    }
}
