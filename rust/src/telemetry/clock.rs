//! Time sources for telemetry spans and job timings.
//!
//! All instrumentation reads time through the [`Clock`] trait so the
//! production monotonic clock ([`MonoClock`]) can be swapped for a
//! deterministic [`ManualClock`] in tests — trace assertions never
//! depend on real scheduler jitter.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotonic nanosecond source. `Send + Sync` because `ShardPool`
/// workers stamp job timings concurrently with the master thread.
pub trait Clock: Send + Sync {
    /// Nanoseconds since an arbitrary per-clock origin; never decreases
    /// on a single thread.
    fn now_ns(&self) -> u64;
}

/// Wall monotonic clock anchored at construction.
pub struct MonoClock {
    origin: Instant,
}

impl MonoClock {
    pub fn new() -> MonoClock {
        MonoClock { origin: Instant::now() }
    }
}

impl Default for MonoClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonoClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// Deterministic clock: each reading returns the current value and then
/// advances it by a fixed step, so a single-threaded sequence of reads
/// yields 0, step, 2·step, … regardless of host load. Tests can also
/// drive it explicitly with [`ManualClock::advance`].
pub struct ManualClock {
    t: AtomicU64,
    step: u64,
}

impl ManualClock {
    pub fn new(step: u64) -> ManualClock {
        ManualClock { t: AtomicU64::new(0), step }
    }

    /// Move time forward without consuming a tick.
    pub fn advance(&self, ns: u64) {
        self.t.fetch_add(ns, Ordering::Relaxed);
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.t.fetch_add(self.step, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mono_clock_is_monotone() {
        let c = MonoClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_ticks_deterministically() {
        let c = ManualClock::new(1_000);
        assert_eq!(c.now_ns(), 0);
        assert_eq!(c.now_ns(), 1_000);
        c.advance(500);
        assert_eq!(c.now_ns(), 2_500);
    }
}
