//! The `bench kernels` suite: scalar-reference vs kernelized ns/op for
//! every hot-loop kernel, plus end-to-end sim rounds/sec — the perf
//! trajectory every future PR regresses against (EXPERIMENTS.md §Perf).
//!
//! Shared by the `fedsamp bench kernels` CLI mode (which also emits
//! `BENCH_kernels.json`) and `benches/micro_kernels.rs`. Both arms of
//! every comparison are measured in the same process in the same run,
//! so machine variance cancels out of the speedup ratios.

use std::hint::black_box;
use std::time::Duration;

use crate::bench::{f, Bench, Table};
use crate::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use crate::data::ClientData;
use crate::fl::{train, TrainOptions};
use crate::model::logistic::Logistic;
use crate::model::NativeModel;
use crate::sim::build_native_engine;
use crate::tensor::dispatch;
use crate::tensor::kernels::{self, reference};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Vector lengths the micro-kernels are swept over. The 1M arm stresses
/// memory bandwidth rather than cache (ROADMAP item 3) — it is where
/// the SIMD-vs-scalar gap on the reductions is widest.
pub const DIMS: [usize; 4] = [64, 1_000, 100_000, 1_000_000];

/// Vector lengths for the logistic `loss_grad` meso-bench. Capped at
/// 100k: the bench materializes `BATCH × 4` dense rows per dim, so a 1M
/// arm would allocate ~512 MB of synthetic data for a GEMM the vector
/// sweep above already covers at 1M.
const LOSS_GRAD_DIMS: [usize; 3] = [64, 1_000, 100_000];

/// Members folded per accumulate measurement (a plausible shard size).
const MEMBERS: usize = 8;

/// Batch size / class count for the logistic `loss_grad` meso-bench.
const BATCH: usize = 32;
const CLASSES: usize = 16;

/// One scalar-vs-kernel comparison.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub op: String,
    pub dim: usize,
    pub scalar_ns: f64,
    pub kernel_ns: f64,
}

impl Measurement {
    pub fn speedup(&self) -> f64 {
        self.scalar_ns / self.kernel_ns
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("op", Json::str(self.op.clone())),
            ("dim", Json::num(self.dim as f64)),
            ("scalar_ns_per_op", Json::num(self.scalar_ns)),
            ("kernel_ns_per_op", Json::num(self.kernel_ns)),
            ("ops_per_sec_kernel", Json::num(1e9 / self.kernel_ns)),
            ("speedup", Json::num(self.speedup())),
        ])
    }
}

fn bench(group: &str, quick: bool) -> Bench {
    let min_time = if quick {
        Duration::from_millis(1)
    } else {
        Duration::from_millis(200)
    };
    Bench::new(group).with_min_time(min_time)
}

fn random_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn dense_data(n: usize, dim: usize, classes: usize, seed: u64) -> ClientData {
    let mut rng = Rng::new(seed);
    ClientData {
        x_dense: (0..n * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        x_tokens: vec![],
        labels: (0..n).map(|_| rng.range(0, classes) as u32).collect(),
        dim,
    }
}

/// Reduction + elementwise micro-kernels across [`DIMS`].
fn vector_measurements(quick: bool) -> Vec<Measurement> {
    let mut rng = Rng::new(0xBE_AC);
    let mut out = Vec::new();
    for &dim in &DIMS {
        let b = bench(&format!("kernels/dim={dim}"), quick);
        let x = random_vec(&mut rng, dim);
        let y = random_vec(&mut rng, dim);

        let scalar_ns = b.run("norm_sq/scalar", || {
            black_box(reference::norm_sq(black_box(&x)));
        });
        let kernel_ns = b.run("norm_sq/kernel", || {
            black_box(kernels::norm_sq(black_box(&x)));
        });
        out.push(Measurement {
            op: "norm_sq".into(),
            dim,
            scalar_ns,
            kernel_ns,
        });

        let scalar_ns = b.run("dot/scalar", || {
            black_box(reference::dot(black_box(&x), black_box(&y)));
        });
        let kernel_ns = b.run("dot/kernel", || {
            black_box(kernels::dot(black_box(&x), black_box(&y)));
        });
        out.push(Measurement { op: "dot".into(), dim, scalar_ns, kernel_ns });

        let mut acc = vec![0.0f32; dim];
        let scalar_ns = b.run("axpy/scalar", || {
            reference::axpy(black_box(&mut acc), 0.5, black_box(&x));
        });
        let kernel_ns = b.run("axpy/kernel", || {
            kernels::axpy(black_box(&mut acc), 0.5, black_box(&x));
        });
        out.push(Measurement { op: "axpy".into(), dim, scalar_ns, kernel_ns });

        let vecs: Vec<Vec<f32>> =
            (0..MEMBERS).map(|_| random_vec(&mut rng, dim)).collect();
        let refs: Vec<&[f32]> = vecs.iter().map(|v| v.as_slice()).collect();
        let weights = vec![0.25f32; MEMBERS];
        let mut acc = vec![0.0f32; dim];
        let scalar_ns = b.run("weighted_accumulate/scalar", || {
            // the seed aggregation: one full axpy pass per member
            for (v, &w) in refs.iter().zip(&weights) {
                reference::axpy(black_box(&mut acc), w, v);
            }
        });
        let kernel_ns = b.run("weighted_accumulate/kernel", || {
            kernels::weighted_accumulate(
                black_box(&mut acc),
                &refs,
                &weights,
            );
        });
        out.push(Measurement {
            op: "weighted_accumulate".into(),
            dim,
            scalar_ns,
            kernel_ns,
        });
    }
    out
}

/// The acceptance meso-bench: logistic `loss_grad` over a BATCH-row
/// mini-batch, scalar per-sample row walks vs the batch GEMM + rank-1
/// kernel path, across [`DIMS`] input dimensions.
fn loss_grad_measurements(quick: bool) -> Vec<Measurement> {
    let mut out = Vec::new();
    for &dim in &LOSS_GRAD_DIMS {
        let b = bench(&format!("loss_grad/dim={dim}"), quick);
        let model = Logistic::new(dim, CLASSES, 1e-4);
        let data = dense_data(BATCH * 4, dim, CLASSES, dim as u64);
        let params = model.init_params(7);
        let batch: Vec<usize> = (0..BATCH).collect();
        let mut grad = vec![0.0f32; model.dim()];
        let mut work: Vec<f32> = Vec::new();
        let scalar_ns = b.run("scalar", || {
            black_box(model.loss_grad_scalar(
                black_box(&params),
                &data,
                &batch,
                black_box(&mut grad),
            ));
        });
        let kernel_ns = b.run("kernel", || {
            black_box(model.loss_grad_scratch(
                black_box(&params),
                &data,
                &batch,
                black_box(&mut grad),
                &mut work,
            ));
        });
        out.push(Measurement {
            op: "logistic_loss_grad".into(),
            dim,
            scalar_ns,
            kernel_ns,
        });
    }
    out
}

/// End-to-end sim rounds/sec (kernelized path): the number every future
/// perf PR regresses against.
fn rounds_per_sec(quick: bool) -> (f64, usize) {
    let rounds = if quick { 2 } else { 10 };
    let cfg = ExperimentConfig {
        name: "bench_kernels_sim".into(),
        seed: 9,
        rounds,
        cohort: 16,
        budget: 4,
        strategy: Strategy::Aocs { j_max: 4 },
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 40, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: rounds,
        eval_examples: 128,
        workers: 1,
        secure_updates: true,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    };
    let mut engine = build_native_engine(&cfg);
    let b = bench("sim", quick);
    let ns = b.run(&format!("fedavg_{rounds}_rounds"), || {
        let run =
            train(&cfg, &mut engine, &TrainOptions::default()).unwrap();
        black_box(run);
    });
    (rounds as f64 / (ns * 1e-9), rounds)
}

/// Run the full suite; returns the `BENCH_kernels.json` document. The
/// active kernel backend (scalar or simd — `--kernel-backend` /
/// `FEDSAMP_KERNEL_BACKEND`) applies to the kernel arm of every
/// comparison and is recorded in the document.
pub fn run_kernel_suite(quick: bool) -> Json {
    let backend = dispatch::active();
    let mut measurements = vector_measurements(quick);
    measurements.extend(loss_grad_measurements(quick));
    let (rps, rounds) = rounds_per_sec(quick);
    println!("\nsim throughput: {rps:.2} rounds/sec ({rounds}-round FedAvg, secure, pool=40)");
    println!("kernel backend: {}", backend.name());
    let mut table = Table::new(&[
        "op",
        "dim",
        "scalar ns/op",
        "kernel ns/op",
        "speedup",
    ]);
    for m in &measurements {
        table.row(vec![
            m.op.clone(),
            m.dim.to_string(),
            f(m.scalar_ns, 1),
            f(m.kernel_ns, 1),
            format!("{:.2}x", m.speedup()),
        ]);
    }
    table.print();
    Json::obj(vec![
        ("bench", Json::str("kernels")),
        ("quick", Json::Bool(quick)),
        ("kernel_backend", Json::str(backend.name())),
        (
            "ops",
            Json::Arr(measurements.iter().map(Measurement::to_json).collect()),
        ),
        (
            "sim_rounds_per_sec",
            Json::obj(vec![
                ("config", Json::str("fedavg_secure_femnist40")),
                ("rounds_per_run", Json::num(rounds as f64)),
                ("value", Json::num(rps)),
            ]),
        ),
    ])
}
