//! The scenario sweep driver: `fedsamp sweep` runs a
//! {strategy × compressor × availability × pool-size} experiment grid
//! with multi-seed averaging and emits `BENCH_sweep.json` plus a flat
//! `BENCH_sweep.csv` — the harness behind EXPERIMENTS.md §Scenarios.
//!
//! Every arm is one sim-path experiment through the full coordinator
//! stack — run over a **sharded** registry ([`SweepSpec::shards`]), so
//! availability traces (including correlated whole-shard outages),
//! streaming cohort selection, compression and the measured-bytes
//! metrics all compose exactly as they do in a real deployment. Arms share the FedAvg/femnist
//! configuration of the perf suites; `secure_updates` is off (the
//! sweep measures sampling/availability behavior, and `bench secure`
//! owns the masking-cost story).
//!
//! Availability arms are named specs (the CLI grammar):
//! `alwayson`, `bern<q>` (Bernoulli trace at base q), `diurnal<q>`
//! (base q with a 24-round day cycle over 4 timezone groups),
//! `churn<q>` (8-round sessions, 30% dropped), `outage<p>` (per-round
//! whole-shard outage probability p) — see [`parse_availability_arm`].
//!
//! Fault arms compose the chaos layer into the grid: `none` is the
//! fault-free arm, any other spec is a [`crate::faults::FaultPlan`] in
//! the `--faults` CLI grammar with `'+'` joining kinds *within* an arm
//! (`,` separates arms), e.g. `none,crash0.2+corrupt0.05` — see
//! [`parse_fault_arms`]. Fault/repair tallies land in the
//! `faults_injected`/`faults_repaired` CSV columns.
//!
//! With `--ledger <path>` the grid is **resumable**: every completed
//! `(arm, seed)` unit is appended to a crash-safe
//! [`crate::checkpoint::SweepLedger`], so an interrupted sweep picks up
//! at the first unfinished unit and emits byte-identical
//! `BENCH_sweep.json`/`.csv` (see [`run_sweep_resumable`]).

use crate::checkpoint::{fnv1a64, CheckpointError, LedgerEntry, SweepLedger};
use crate::compress::Compressor;
use crate::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use crate::coordinator::{
    CoordStats, Coordinator, CoordinatorOptions, ParallelRunner,
};
use crate::faults::{parse_fault_spec, FaultPlan};
use crate::fl::availability::{Churn, Diurnal, Outage, Trace};
use crate::fl::TrainOptions;
use crate::metrics::{average_runs, RunResult};
use crate::sim::build_native_engine;
use crate::telemetry::{TelemetryConfig, TelemetrySummary};
use crate::util::json::Json;

/// Seed for the trace draw streams of CLI/preset availability arms —
/// fixed so that scenario arms are comparable across sweeps.
const ARM_TRACE_SEED: u64 = 0x5CE2_A210;

/// One availability arm of the grid: a display name plus the trace it
/// runs under (`None` = the main-paper always-on setting).
#[derive(Clone, Debug, PartialEq)]
pub struct AvailabilityArm {
    pub name: String,
    pub trace: Option<Trace>,
}

impl AvailabilityArm {
    pub fn always_on() -> AvailabilityArm {
        AvailabilityArm { name: "alwayson".into(), trace: None }
    }
}

/// Parse an availability-arm spec (the `--availabilities` CLI grammar).
pub fn parse_availability_arm(spec: &str) -> Result<AvailabilityArm, String> {
    let arm = |trace: Trace| AvailabilityArm {
        name: spec.to_string(),
        trace: Some(trace),
    };
    if spec == "alwayson" || spec == "always" {
        return Ok(AvailabilityArm::always_on());
    }
    let q_of = |rest: &str, what: &str| -> Result<f64, String> {
        rest.parse::<f64>()
            .map_err(|_| format!("bad {what} probability in '{spec}'"))
    };
    if let Some(rest) = spec.strip_prefix("bern") {
        return Ok(arm(Trace::bernoulli(ARM_TRACE_SEED, q_of(rest, "bern")?)));
    }
    if let Some(rest) = spec.strip_prefix("diurnal") {
        return Ok(arm(Trace {
            seed: ARM_TRACE_SEED,
            base_q: q_of(rest, "diurnal")?,
            diurnal: Some(Diurnal { amplitude: 0.6, period: 24, zones: 4 }),
            churn: None,
            outage: None,
        }));
    }
    if let Some(rest) = spec.strip_prefix("churn") {
        return Ok(arm(Trace {
            seed: ARM_TRACE_SEED,
            base_q: q_of(rest, "churn")?,
            diurnal: None,
            churn: Some(Churn { session_len: 8, drop_prob: 0.3 }),
            outage: None,
        }));
    }
    if let Some(rest) = spec.strip_prefix("outage") {
        return Ok(arm(Trace {
            seed: ARM_TRACE_SEED,
            base_q: 1.0,
            diurnal: None,
            churn: None,
            outage: Some(Outage { prob: q_of(rest, "outage")? }),
        }));
    }
    Err(format!(
        "unknown availability arm '{spec}' (expected alwayson|bern<q>|\
         diurnal<q>|churn<q>|outage<p>)"
    ))
}

/// One fault arm of the grid: a display name plus the chaos plan it
/// runs under (`None` = the fault-free arm).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultArm {
    pub name: String,
    pub plan: Option<FaultPlan>,
}

impl FaultArm {
    pub fn none() -> FaultArm {
        FaultArm { name: "none".into(), plan: None }
    }
}

/// Parse a comma-separated fault-arm list (the `--faults` sweep
/// grammar): each arm is `none` or a `'+'`-joined
/// [`crate::faults::parse_fault_spec`] plan, e.g.
/// `none,crash0.2+corrupt0.05,stall0.3+retries2`.
pub fn parse_fault_arms(spec: &str) -> Result<Vec<FaultArm>, String> {
    let mut arms = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        if part == "none" {
            arms.push(FaultArm::none());
        } else {
            arms.push(FaultArm {
                name: part.to_string(),
                plan: Some(parse_fault_spec(part)?),
            });
        }
    }
    if arms.is_empty() {
        return Err("empty fault-arm list".into());
    }
    Ok(arms)
}

/// The grid axes plus the per-arm run shape.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub strategies: Vec<Strategy>,
    /// `Compressor::None` is the uncompressed arm.
    pub compressors: Vec<Compressor>,
    pub availabilities: Vec<AvailabilityArm>,
    /// Chaos-layer arms ([`FaultArm::none`] is the fault-free arm).
    pub faults: Vec<FaultArm>,
    pub pools: Vec<usize>,
    /// Seeds averaged per arm (`base_seed..base_seed + seeds`).
    pub seeds: u64,
    pub base_seed: u64,
    pub rounds: usize,
    pub cohort: usize,
    pub budget: usize,
    /// Registry shards each arm's coordinator runs over (> 1 so
    /// shard-scoped trace outages down a segment, not the whole pool).
    pub shards: usize,
    /// Echoed into the JSON so quick smoke outputs are identifiable.
    pub quick: bool,
    /// Record a [`TelemetrySummary`] per arm (summary-only: no trace
    /// files, latency rollups attached to each arm's JSON record).
    pub telemetry: bool,
}

impl SweepSpec {
    /// The CI smoke grid: {full, uniform, aocs, caocs, clustered,
    /// cyclic} × {none} × {alwayson, bern0.7} ×
    /// {none, crash0.2+corrupt0.05} × {40}, one seed, 6 rounds —
    /// seconds of work, every layer (the chaos layer and the whole
    /// strategy zoo included) exercised.
    pub fn quick() -> SweepSpec {
        SweepSpec {
            strategies: vec![
                Strategy::Full,
                Strategy::Uniform,
                Strategy::Aocs { j_max: 4 },
                Strategy::Caocs { j_max: 4 },
                Strategy::Clustered { k: 2 },
                Strategy::Cyclic { g: 2 },
            ],
            compressors: vec![Compressor::None],
            availabilities: vec![
                AvailabilityArm::always_on(),
                parse_availability_arm("bern0.7").unwrap(),
            ],
            faults: parse_fault_arms("none,crash0.2+corrupt0.05").unwrap(),
            pools: vec![40],
            seeds: 1,
            base_seed: 1,
            rounds: 6,
            cohort: 16,
            budget: 4,
            shards: 4,
            quick: true,
            telemetry: false,
        }
    }

    /// The default full grid: 7 strategies × {none, randk64} ×
    /// {alwayson, bern0.7, diurnal0.8} × {60, 240}, 3 seeds, 30 rounds.
    pub fn default_grid() -> SweepSpec {
        SweepSpec {
            strategies: vec![
                Strategy::Full,
                Strategy::Uniform,
                Strategy::Ocs,
                Strategy::Aocs { j_max: 4 },
                Strategy::Caocs { j_max: 4 },
                Strategy::Clustered { k: 4 },
                Strategy::Cyclic { g: 4 },
            ],
            compressors: vec![
                Compressor::None,
                Compressor::RandK { k: 64 },
            ],
            availabilities: vec![
                AvailabilityArm::always_on(),
                parse_availability_arm("bern0.7").unwrap(),
                parse_availability_arm("diurnal0.8").unwrap(),
            ],
            faults: vec![FaultArm::none()],
            pools: vec![60, 240],
            seeds: 3,
            base_seed: 1,
            rounds: 30,
            cohort: 16,
            budget: 4,
            shards: 4,
            quick: false,
            telemetry: false,
        }
    }

    pub fn arm_count(&self) -> usize {
        self.strategies.len()
            * self.compressors.len()
            * self.availabilities.len()
            * self.faults.len()
            * self.pools.len()
    }
}

/// One grid arm's seed-averaged summary (one CSV row).
#[derive(Clone, Debug)]
pub struct ArmSummary {
    pub strategy: String,
    pub compressor: String,
    pub availability: String,
    /// The fault arm's name (`none` for the fault-free arm).
    pub faults: String,
    pub pool: usize,
    pub seeds: u64,
    pub rounds: usize,
    pub final_train_loss: f64,
    pub final_accuracy: f64,
    pub mean_alpha: f64,
    pub total_uplink_bytes: u64,
    pub bytes_per_round: f64,
    pub mean_transmitted: f64,
    /// Rounds where no client was reachable (availability too hostile).
    pub noop_rounds: usize,
    /// Shard-rounds lost to correlated trace outages, summed over the
    /// arm's seeds (from [`CoordStats`]).
    pub shards_outaged: usize,
    /// Shard-rounds lost to missed deadlines, summed over seeds.
    pub shards_dropped: usize,
    /// Rounds actually driven across all the arm's seed runs
    /// (`spec.rounds × seeds` unless a run aborted).
    pub rounds_run: usize,
    /// Chaos-layer faults injected, summed over the arm's seeds
    /// (see [`crate::faults::FaultCounters::injected`]).
    pub faults_injected: u64,
    /// Chaos-layer repair actions taken, summed over the arm's seeds
    /// (see [`crate::faults::FaultCounters::repaired`]).
    pub faults_repaired: u64,
    /// Present when the sweep ran with [`SweepSpec::telemetry`]: the
    /// first seed's latency/counter rollup (distributions don't
    /// average — see `metrics::average_runs`).
    pub telemetry: Option<TelemetrySummary>,
}

impl ArmSummary {
    #[allow(clippy::too_many_arguments)]
    fn from_run(
        run: &RunResult,
        strategy: &Strategy,
        compressor: &Compressor,
        availability: &AvailabilityArm,
        fault: &FaultArm,
        pool: usize,
        seeds: u64,
        stats: &CoordStats,
    ) -> ArmSummary {
        let n = run.rounds.len().max(1);
        let noop_rounds =
            run.rounds.iter().filter(|r| r.train_loss.is_nan()).count();
        // last *finite* loss: a hostile arm whose final round drew an
        // empty cohort must not poison the headline column with NaN
        // (mirrors how final_accuracy skips non-eval rounds)
        let final_train_loss = run
            .rounds
            .iter()
            .rev()
            .find(|r| !r.train_loss.is_nan())
            .map(|r| r.train_loss)
            .unwrap_or(f64::NAN);
        let mean_transmitted = run
            .rounds
            .iter()
            .map(|r| r.transmitted as f64)
            .sum::<f64>()
            / n as f64;
        ArmSummary {
            strategy: strategy.name().into(),
            compressor: compressor.name(),
            availability: availability.name.clone(),
            faults: fault.name.clone(),
            pool,
            seeds,
            rounds: run.rounds.len(),
            final_train_loss,
            final_accuracy: run.final_accuracy(),
            mean_alpha: run.mean_alpha(),
            total_uplink_bytes: run.total_uplink_bytes(),
            bytes_per_round: run.total_uplink_bytes() as f64 / n as f64,
            mean_transmitted,
            noop_rounds,
            shards_outaged: stats.shards_outaged,
            shards_dropped: stats.shards_dropped,
            rounds_run: stats.rounds_run,
            faults_injected: stats.faults.injected(),
            faults_repaired: stats.faults.repaired(),
            telemetry: run.telemetry.clone(),
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("strategy", Json::str(self.strategy.clone())),
            ("compressor", Json::str(self.compressor.clone())),
            ("availability", Json::str(self.availability.clone())),
            ("faults", Json::str(self.faults.clone())),
            ("pool", Json::num(self.pool as f64)),
            ("seeds", Json::num(self.seeds as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("final_train_loss", Json::num(self.final_train_loss)),
            ("final_accuracy", Json::num(self.final_accuracy)),
            ("mean_alpha", Json::num(self.mean_alpha)),
            (
                "total_uplink_bytes",
                Json::num(self.total_uplink_bytes as f64),
            ),
            ("bytes_per_round", Json::num(self.bytes_per_round)),
            ("mean_transmitted", Json::num(self.mean_transmitted)),
            ("noop_rounds", Json::num(self.noop_rounds as f64)),
            ("shards_outaged", Json::num(self.shards_outaged as f64)),
            ("shards_dropped", Json::num(self.shards_dropped as f64)),
            ("rounds_run", Json::num(self.rounds_run as f64)),
            ("faults_injected", Json::num(self.faults_injected as f64)),
            ("faults_repaired", Json::num(self.faults_repaired as f64)),
        ];
        if let Some(t) = &self.telemetry {
            pairs.push(("telemetry", t.to_json()));
        }
        Json::obj(pairs)
    }

    fn to_csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            self.strategy,
            self.compressor,
            self.availability,
            self.faults,
            self.pool,
            self.seeds,
            self.rounds,
            self.final_train_loss,
            self.final_accuracy,
            self.mean_alpha,
            self.total_uplink_bytes,
            self.bytes_per_round,
            self.mean_transmitted,
            self.noop_rounds,
            self.shards_outaged,
            self.shards_dropped,
            self.rounds_run,
            self.faults_injected,
            self.faults_repaired
        )
    }
}

/// The CSV header [`SweepReport::to_csv`] emits (column semantics:
/// EXPERIMENTS.md §Scenarios).
pub const CSV_HEADER: &str = "strategy,compressor,availability,faults,pool,\
seeds,rounds,final_train_loss,final_accuracy,mean_alpha,\
total_uplink_bytes,bytes_per_round,mean_transmitted,noop_rounds,\
shards_outaged,shards_dropped,rounds_run,faults_injected,faults_repaired";

/// A completed grid.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub quick: bool,
    pub arms: Vec<ArmSummary>,
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str("sweep")),
            ("quick", Json::Bool(self.quick)),
            (
                "arms",
                Json::Arr(self.arms.iter().map(ArmSummary::to_json).collect()),
            ),
        ])
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(CSV_HEADER);
        s.push('\n');
        for arm in &self.arms {
            s.push_str(&arm.to_csv_row());
            s.push('\n');
        }
        s
    }

    /// Write `BENCH_sweep.json` + `BENCH_sweep.csv` into `dir`; returns
    /// the two paths. Crash-safe: each file is written to a temp path
    /// and atomically renamed (`checkpoint::write_atomic`), so a kill
    /// mid-write never leaves a truncated artifact.
    pub fn save(&self, dir: &str) -> Result<(String, String), String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("cannot create {dir}: {e}"))?;
        let json_path = format!("{dir}/BENCH_sweep.json");
        let csv_path = format!("{dir}/BENCH_sweep.csv");
        crate::checkpoint::write_atomic(
            &json_path,
            self.to_json().to_pretty().as_bytes(),
        )
        .map_err(String::from)?;
        crate::checkpoint::write_atomic(&csv_path, self.to_csv().as_bytes())
            .map_err(String::from)?;
        Ok((json_path, csv_path))
    }
}

/// The shared arm configuration (the perf suites' FedAvg/femnist shape,
/// availability and pool size swapped per arm).
fn arm_cfg(
    spec: &SweepSpec,
    strategy: &Strategy,
    compressor: &Compressor,
    availability: &AvailabilityArm,
    fault: &FaultArm,
    pool: usize,
) -> ExperimentConfig {
    ExperimentConfig {
        // fault-free arms keep the historical name (no suffix churn)
        name: format!(
            "sweep_{}_{}_{}_p{pool}{}",
            strategy.name(),
            compressor.name(),
            availability.name,
            if fault.plan.is_some() {
                format!("_{}", fault.name)
            } else {
                String::new()
            }
        ),
        seed: spec.base_seed,
        rounds: spec.rounds,
        cohort: spec.cohort,
        budget: spec.budget,
        strategy: strategy.clone(),
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: spec.rounds,
        eval_examples: 128,
        workers: 1,
        secure_updates: false,
        availability: 1.0,
        availability_trace: availability.trace.clone(),
        compressor: match compressor {
            Compressor::None => None,
            c => Some(c.clone()),
        },
        fault_plan: fault.plan.clone(),
    }
}

/// Prefix of the error [`run_sweep_resumable`] surfaces when
/// `abort_after` fires — the CLI maps it to exit code 3 (the same
/// planned-kill convention as `faults::MASTERKILL_ERR_PREFIX`), so the
/// sweep-resume CI smoke can tell a planned kill from a real failure.
pub const SWEEP_ABORT_ERR_PREFIX: &str = "sweep-abort:";

/// Fingerprint of the whole grid a spec expands to: FNV-1a over every
/// arm config's canonical JSON (in grid order) plus the seed/shard
/// shape. Two specs fingerprint equal iff they run the same units, so a
/// [`SweepLedger`] can refuse to resume a different grid.
pub fn spec_fingerprint(spec: &SweepSpec) -> u64 {
    let mut canon = String::new();
    for (pool, availability, fault, strategy, compressor) in build_grid(spec) {
        canon.push_str(
            &arm_cfg(spec, strategy, compressor, availability, fault, pool)
                .to_json()
                .to_pretty(),
        );
        canon.push('\n');
    }
    canon.push_str(&format!(
        "seeds={}|base_seed={}|shards={}",
        spec.seeds.max(1),
        spec.base_seed,
        spec.shards.max(1),
    ));
    fnv1a64(canon.as_bytes())
}

/// Fingerprint of one arm (seed-independent — the unit key in the
/// ledger is `(arm_fingerprint, seed offset)`).
fn arm_fingerprint(cfg: &ExperimentConfig, shards: usize) -> u64 {
    fnv1a64(format!("{}|shards={shards}", cfg.to_json().to_pretty()).as_bytes())
}

/// The grid in its canonical order (pools → availabilities → faults →
/// strategies → compressors) — the order arms appear in the report and
/// the order the ledger completes units in.
fn build_grid(
    spec: &SweepSpec,
) -> Vec<(usize, &AvailabilityArm, &FaultArm, &Strategy, &Compressor)> {
    let mut grid = Vec::with_capacity(spec.arm_count());
    for pool in &spec.pools {
        for availability in &spec.availabilities {
            for fault in &spec.faults {
                for strategy in &spec.strategies {
                    for compressor in &spec.compressors {
                        grid.push((
                            *pool,
                            availability,
                            fault,
                            strategy,
                            compressor,
                        ));
                    }
                }
            }
        }
    }
    grid
}

/// Run the full grid: every {strategy × compressor × availability ×
/// pool} arm, `spec.seeds` seeds each, seed runs averaged pointwise
/// (`metrics::average_runs`, the paper's mean-over-seeds convention).
pub fn run_sweep(spec: &SweepSpec, verbose: bool) -> Result<SweepReport, String> {
    run_sweep_resumable(spec, None, None, verbose)
}

/// [`run_sweep`] with a per-unit completion ledger.
///
/// With `ledger_path` set, every completed `(arm, seed)` unit is
/// appended to a [`SweepLedger`] at that path (written crash-safely
/// after each unit). A rerun against the same path loads the ledger,
/// verifies it belongs to this grid ([`spec_fingerprint`] — a mismatch
/// is a typed [`CheckpointError::SpecMismatch`]), replays the finished
/// units' bit-exact round records without re-running them, and resumes
/// at the first unfinished unit — the final report is **byte-identical**
/// to an uninterrupted sweep's.
///
/// `abort_after = Some(n)` aborts the sweep (with a
/// [`SWEEP_ABORT_ERR_PREFIX`] error) after `n` *newly* completed units —
/// the deterministic kill the resume tests and the CI smoke use.
///
/// Ledger mode requires `spec.telemetry == false`: the ledger stores
/// round records, not telemetry summaries, so a resumed telemetry sweep
/// could not reproduce the uninterrupted report.
pub fn run_sweep_resumable(
    spec: &SweepSpec,
    ledger_path: Option<&str>,
    abort_after: Option<usize>,
    verbose: bool,
) -> Result<SweepReport, String> {
    if spec.telemetry && ledger_path.is_some() {
        return Err(
            "--ledger cannot be combined with a telemetry sweep (the ledger \
             stores round records, not telemetry summaries)"
            .into(),
        );
    }
    let mut ledger = match ledger_path {
        Some(path) => {
            let want = spec_fingerprint(spec);
            if std::path::Path::new(path).exists() {
                let l = SweepLedger::load(path).map_err(String::from)?;
                if l.spec_fingerprint != want {
                    return Err(CheckpointError::SpecMismatch {
                        got: l.spec_fingerprint,
                        want,
                    }
                    .into());
                }
                Some(l)
            } else {
                Some(SweepLedger::new(want))
            }
        }
        None => None,
    };
    let mut newly_completed = 0usize;

    let mut arms = Vec::with_capacity(spec.arm_count());
    for (pool, availability, fault, strategy, compressor) in build_grid(spec) {
        let cfg = arm_cfg(spec, strategy, compressor, availability, fault, pool);
        let arm_fp = arm_fingerprint(&cfg, spec.shards.max(1));
        let train_opts = TrainOptions {
            telemetry: if spec.telemetry {
                TelemetryConfig::summary_only()
            } else {
                TelemetryConfig::off()
            },
            ..TrainOptions::default()
        };
        let mut runs = Vec::with_capacity(spec.seeds as usize);
        let mut stats = CoordStats::default();
        for s in 0..spec.seeds.max(1) {
            let mut c = cfg.clone();
            c.seed = spec.base_seed + s;
            let (run, run_stats) = match ledger.as_ref().and_then(|l| l.entry(arm_fp, s)) {
                Some(entry) => {
                    // unit already ran before the interruption: rebuild
                    // its run from the ledger's bit-exact records
                    let mut run = RunResult::new(&c.name, strategy.name());
                    run.rounds = entry.records.clone();
                    (run, entry.stats.clone())
                }
                None => {
                    let engine = build_native_engine(&c);
                    let mut runner = ParallelRunner::new(engine, 1);
                    let mut coordinator = Coordinator::new(CoordinatorOptions {
                        shards: spec.shards.max(1),
                        ..CoordinatorOptions::default()
                    });
                    let run = coordinator.run(&c, &mut runner, &train_opts)?;
                    if let (Some(l), Some(path)) = (ledger.as_mut(), ledger_path) {
                        l.entries.push(LedgerEntry {
                            arm_fingerprint: arm_fp,
                            seed: s,
                            records: run.rounds.clone(),
                            stats: coordinator.stats.clone(),
                        });
                        l.write_atomic(path).map_err(String::from)?;
                    }
                    newly_completed += 1;
                    let stats = coordinator.stats.clone();
                    if abort_after.is_some_and(|n| newly_completed >= n) {
                        return Err(format!(
                            "{SWEEP_ABORT_ERR_PREFIX} sweep aborted after \
                             {newly_completed} newly completed units"
                        ));
                    }
                    (run, stats)
                }
            };
            runs.push(run);
            stats.shards_dropped += run_stats.shards_dropped;
            stats.shards_outaged += run_stats.shards_outaged;
            stats.noop_rounds += run_stats.noop_rounds;
            stats.rounds_run += run_stats.rounds_run;
            stats.faults.absorb(&run_stats.faults);
        }
        let avg = average_runs(&runs);
        let summary = ArmSummary::from_run(
            &avg,
            strategy,
            compressor,
            availability,
            fault,
            pool,
            spec.seeds.max(1),
            &stats,
        );
        if verbose {
            println!(
                "sweep {}×{}×{}×{}×p{}: loss {:.4} acc {:.3} \
                 {:.0} B/round sent {:.1}/round",
                summary.strategy,
                summary.compressor,
                summary.availability,
                summary.faults,
                summary.pool,
                summary.final_train_loss,
                summary.final_accuracy,
                summary.bytes_per_round,
                summary.mean_transmitted,
            );
        }
        arms.push(summary);
    }
    Ok(SweepReport { quick: spec.quick, arms })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn availability_arm_grammar() {
        assert_eq!(
            parse_availability_arm("alwayson").unwrap(),
            AvailabilityArm::always_on()
        );
        let b = parse_availability_arm("bern0.5").unwrap();
        assert_eq!(b.trace.as_ref().unwrap().base_q, 0.5);
        assert!(b.trace.as_ref().unwrap().diurnal.is_none());
        let d = parse_availability_arm("diurnal0.8").unwrap();
        assert!(d.trace.as_ref().unwrap().diurnal.is_some());
        let c = parse_availability_arm("churn0.9").unwrap();
        assert!(c.trace.as_ref().unwrap().churn.is_some());
        let o = parse_availability_arm("outage0.1").unwrap();
        assert_eq!(o.trace.as_ref().unwrap().base_q, 1.0);
        assert!(o.trace.as_ref().unwrap().outage.is_some());
        assert!(parse_availability_arm("lunar").is_err());
        assert!(parse_availability_arm("bernX").is_err());
    }

    #[test]
    fn fault_arm_grammar() {
        let arms = parse_fault_arms("none,crash0.2+corrupt0.05").unwrap();
        assert_eq!(arms.len(), 2);
        assert_eq!(arms[0], FaultArm::none());
        assert_eq!(arms[1].name, "crash0.2+corrupt0.05");
        let plan = arms[1].plan.as_ref().unwrap();
        assert_eq!(plan.crash_pre, 0.2);
        assert_eq!(plan.crash_post, 0.2);
        assert_eq!(plan.corrupt, 0.05);
        assert_eq!(plan.stall, 0.0);
        let stall = parse_fault_arms("stall0.3+retries2").unwrap();
        assert_eq!(stall[0].plan.as_ref().unwrap().max_retries, 2);
        assert!(parse_fault_arms("").is_err());
        assert!(parse_fault_arms("gremlin0.1").is_err());
        assert!(parse_fault_arms("crash1.5").is_err());
    }

    /// Validate every arm config a spec's grid builds.
    fn validate_grid(spec: &SweepSpec) {
        for pool in &spec.pools {
            for avail in &spec.availabilities {
                for fault in &spec.faults {
                    for s in &spec.strategies {
                        for c in &spec.compressors {
                            arm_cfg(spec, s, c, avail, fault, *pool)
                                .validate()
                                .unwrap();
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn quick_spec_covers_the_acceptance_arms() {
        let spec = SweepSpec::quick();
        assert_eq!(spec.arm_count(), 24);
        let names: Vec<&str> =
            spec.strategies.iter().map(Strategy::name).collect();
        assert_eq!(
            names,
            vec!["full", "uniform", "aocs", "caocs", "clustered", "cyclic"]
        );
        assert!(spec
            .availabilities
            .iter()
            .any(|a| a.trace.is_none()));
        assert!(spec
            .availabilities
            .iter()
            .any(|a| matches!(&a.trace, Some(t) if t.base_q < 1.0)));
        // the CI smoke grid must include a fault-free arm and a chaos
        // arm that can actually fire
        assert!(spec.faults.iter().any(|f| f.plan.is_none()));
        assert!(spec
            .faults
            .iter()
            .any(|f| matches!(&f.plan, Some(p) if !p.is_zero())));
        validate_grid(&spec);
    }

    #[test]
    fn default_grid_validates() {
        let spec = SweepSpec::default_grid();
        assert_eq!(spec.arm_count(), 7 * 2 * 3 * 2);
        assert_eq!(spec.faults, vec![FaultArm::none()]);
        validate_grid(&spec);
    }

    #[test]
    fn tiny_sweep_produces_aligned_csv_and_json() {
        let spec = SweepSpec {
            strategies: vec![Strategy::Uniform],
            compressors: vec![Compressor::None],
            availabilities: vec![
                AvailabilityArm::always_on(),
                parse_availability_arm("bern0.6").unwrap(),
            ],
            faults: vec![FaultArm::none()],
            pools: vec![24],
            seeds: 1,
            base_seed: 5,
            rounds: 3,
            cohort: 8,
            budget: 2,
            shards: 3,
            quick: true,
            telemetry: false,
        };
        let report = run_sweep(&spec, false).unwrap();
        assert_eq!(report.arms.len(), 2);
        let csv = report.to_csv();
        assert!(csv.starts_with(CSV_HEADER));
        assert_eq!(csv.lines().count(), 3);
        // header and every row agree on the column count
        let cols = CSV_HEADER.split(',').count();
        for line in csv.lines() {
            assert_eq!(line.split(',').count(), cols, "{line}");
        }
        let j = report.to_json();
        assert_eq!(j.get("bench").as_str(), Some("sweep"));
        assert_eq!(j.get("arms").as_arr().unwrap().len(), 2);
        for arm in &report.arms {
            assert!(arm.total_uplink_bytes > 0, "{arm:?}");
            assert_eq!(arm.rounds, 3);
            // telemetry off: no rollup attached, and stats still flow
            assert!(arm.telemetry.is_none());
            assert_eq!(arm.rounds_run, 3);
            assert_eq!(
                arm.to_json().get("telemetry"),
                &crate::util::json::Json::Null
            );
        }
    }

    /// Satellite pin: an `outage` arm must surface its shard-outage
    /// count in the arm record (CoordStats flows through to CSV/JSON).
    #[test]
    fn outage_arm_reports_coordinator_stats() {
        let spec = SweepSpec {
            strategies: vec![Strategy::Uniform],
            compressors: vec![Compressor::None],
            availabilities: vec![
                AvailabilityArm::always_on(),
                parse_availability_arm("outage0.5").unwrap(),
            ],
            faults: vec![FaultArm::none()],
            pools: vec![24],
            seeds: 2,
            base_seed: 1,
            rounds: 8,
            cohort: 8,
            budget: 2,
            shards: 4,
            quick: true,
            telemetry: false,
        };
        let report = run_sweep(&spec, false).unwrap();
        assert_eq!(report.arms.len(), 2);
        let always = &report.arms[0];
        let outage = &report.arms[1];
        assert_eq!(always.availability, "alwayson");
        assert_eq!(always.shards_outaged, 0);
        assert_eq!(outage.availability, "outage0.5");
        // p=0.5 over 4 shards × 8 rounds × 2 seeds: astronomically
        // unlikely to dodge every outage draw (trace seed is pinned)
        assert!(outage.shards_outaged > 0, "{outage:?}");
        // the sweep runs no deadline policy: outages must not leak into
        // the deadline-drop column
        assert_eq!(outage.shards_dropped, 0);
        for arm in &report.arms {
            assert_eq!(arm.rounds_run, 8 * 2);
            let j = arm.to_json();
            assert_eq!(
                j.get("shards_outaged").as_usize(),
                Some(arm.shards_outaged)
            );
            assert_eq!(j.get("rounds_run").as_usize(), Some(16));
        }
        let header_cols = CSV_HEADER.split(',').count();
        for line in report.to_csv().lines() {
            assert_eq!(line.split(',').count(), header_cols);
        }
    }

    /// Satellite pin: a chaos arm surfaces its fault/repair tallies in
    /// the arm record while the fault-free arm of the same grid stays
    /// at zero, and the widened CSV stays column-aligned.
    #[test]
    fn fault_arm_reports_chaos_counters() {
        let spec = SweepSpec {
            strategies: vec![Strategy::Uniform],
            compressors: vec![Compressor::None],
            availabilities: vec![AvailabilityArm::always_on()],
            faults: parse_fault_arms("none,crash0.3+corrupt0.2").unwrap(),
            pools: vec![24],
            seeds: 2,
            base_seed: 1,
            rounds: 6,
            cohort: 8,
            budget: 4,
            shards: 3,
            quick: true,
            telemetry: false,
        };
        let report = run_sweep(&spec, false).unwrap();
        assert_eq!(report.arms.len(), 2);
        let clean = &report.arms[0];
        let chaos = &report.arms[1];
        assert_eq!(clean.faults, "none");
        assert_eq!(clean.faults_injected, 0);
        assert_eq!(clean.faults_repaired, 0);
        assert_eq!(chaos.faults, "crash0.3+corrupt0.2");
        // p=0.3 crash over ~4 transmitters × 6 rounds × 2 seeds:
        // astronomically unlikely to dodge every draw (seed is pinned)
        assert!(chaos.faults_injected > 0, "{chaos:?}");
        // chaos must not poison the headline metrics
        assert!(chaos.final_train_loss.is_finite());
        for arm in &report.arms {
            let j = arm.to_json();
            assert_eq!(
                j.get("faults_injected").as_usize(),
                Some(arm.faults_injected as usize)
            );
        }
        let header_cols = CSV_HEADER.split(',').count();
        for line in report.to_csv().lines() {
            assert_eq!(line.split(',').count(), header_cols);
        }
    }

    /// `telemetry: true` attaches a per-arm summary with all six phase
    /// spans and a consistent round count.
    #[test]
    fn telemetry_sweep_attaches_arm_summaries() {
        let mut spec = SweepSpec {
            strategies: vec![Strategy::Uniform],
            compressors: vec![Compressor::None],
            availabilities: vec![AvailabilityArm::always_on()],
            faults: vec![FaultArm::none()],
            pools: vec![24],
            seeds: 1,
            base_seed: 5,
            rounds: 3,
            cohort: 8,
            budget: 2,
            shards: 2,
            quick: true,
            telemetry: true,
        };
        let report = run_sweep(&spec, false).unwrap();
        let tel = report.arms[0]
            .telemetry
            .as_ref()
            .expect("telemetry sweep must attach a summary");
        assert_eq!(tel.rounds, 3);
        // every *round* phase fires once per round; the trailing
        // checkpoint span only fires on snapshot cadence rounds
        let round_phases =
            &crate::telemetry::PHASE_NAMES[..crate::telemetry::NUM_ROUND_PHASES];
        for &name in round_phases {
            let s = tel.phase(name).unwrap_or_else(|| {
                panic!("missing phase rollup for {name}")
            });
            assert_eq!(s.n, 3, "{name}");
        }
        assert_eq!(tel.phase("checkpoint").unwrap().n, 0);
        assert!(tel.counter("clients_transmitted") > 0);
        let j = report.arms[0].to_json();
        assert_eq!(j.get("telemetry").get("rounds").as_usize(), Some(3));
        // same grid with telemetry off: identical trajectory
        spec.telemetry = false;
        let off = run_sweep(&spec, false).unwrap();
        assert_eq!(
            off.arms[0].final_train_loss,
            report.arms[0].final_train_loss
        );
        assert_eq!(
            off.arms[0].total_uplink_bytes,
            report.arms[0].total_uplink_bytes
        );
    }

    fn resume_spec() -> SweepSpec {
        SweepSpec {
            strategies: vec![Strategy::Uniform, Strategy::Aocs { j_max: 4 }],
            compressors: vec![Compressor::None],
            availabilities: vec![
                AvailabilityArm::always_on(),
                parse_availability_arm("bern0.7").unwrap(),
            ],
            faults: vec![FaultArm::none()],
            pools: vec![24],
            seeds: 2,
            base_seed: 3,
            rounds: 4,
            cohort: 8,
            budget: 2,
            shards: 2,
            quick: true,
            telemetry: false,
        }
    }

    /// Tentpole pin: a sweep killed after k newly-completed units and
    /// resumed from its ledger emits a report byte-identical to the
    /// uninterrupted sweep's, for every possible kill point.
    #[test]
    fn interrupted_sweep_resumes_byte_identically() {
        let spec = resume_spec();
        let reference = run_sweep(&spec, false).unwrap();
        let ref_json = reference.to_json().to_pretty();
        let ref_csv = reference.to_csv();
        let total_units = spec.arm_count() * spec.seeds as usize;

        let dir = std::env::temp_dir()
            .join(format!("fedsamp_sweepledger_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for kill_after in [1, total_units / 2, total_units - 1] {
            let path = dir.join(format!("ledger_{kill_after}.bin"));
            let path = path.to_string_lossy().into_owned();
            let err = run_sweep_resumable(&spec, Some(&path), Some(kill_after), false)
                .unwrap_err();
            assert!(
                err.starts_with(SWEEP_ABORT_ERR_PREFIX),
                "expected planned abort, got: {err}"
            );
            // the ledger holds exactly the units finished before the kill
            let ledger = SweepLedger::load(&path).unwrap();
            assert_eq!(ledger.entries.len(), kill_after);
            let resumed =
                run_sweep_resumable(&spec, Some(&path), None, false).unwrap();
            assert_eq!(resumed.to_json().to_pretty(), ref_json, "kill at {kill_after}");
            assert_eq!(resumed.to_csv(), ref_csv, "kill at {kill_after}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A ledger from a different grid is rejected with a typed error,
    /// and ledger mode refuses telemetry sweeps.
    #[test]
    fn ledger_rejects_spec_drift_and_telemetry() {
        let spec = resume_spec();
        let dir = std::env::temp_dir()
            .join(format!("fedsamp_sweepdrift_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ledger.bin");
        let path = path.to_string_lossy().into_owned();
        let _ = run_sweep_resumable(&spec, Some(&path), None, false).unwrap();

        let mut other = resume_spec();
        other.rounds += 1;
        assert_ne!(spec_fingerprint(&spec), spec_fingerprint(&other));
        let err = run_sweep_resumable(&other, Some(&path), None, false).unwrap_err();
        assert!(err.contains("different sweep spec"), "{err}");

        let mut tele = resume_spec();
        tele.telemetry = true;
        let err = run_sweep_resumable(&tele, Some(&path), None, false).unwrap_err();
        assert!(err.contains("telemetry"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
