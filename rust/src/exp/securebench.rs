//! The `bench secure` suite: scalar-reference vs fused-kernel secure
//! aggregation masking (ns/element across roster size × dimension) plus
//! secure-vs-plain end-to-end sim rounds/sec — the regression harness
//! for the privacy-preserving path (EXPERIMENTS.md §Perf).
//!
//! Shared by the `fedsamp bench secure` CLI mode (which also emits
//! `BENCH_secure.json`) and `benches/micro_secure.rs`. Both arms of
//! every comparison are measured in the same process in the same run,
//! so machine variance cancels out of the speedup ratios.
//!
//! The scalar arm is the pre-kernel pipeline retained in
//! `kernels::reference`: materialize the scaled copy, fixed-point
//! encode, one full-vector pass with one PRG call per element per pair,
//! then fold the masked vector into the shard accumulator. The kernel
//! arm is the fused `scale_encode_mask_accumulate` (block PRG draws, no
//! scaled copy, no mask vector) — bit-identical by the property tests,
//! so the comparison is pure speed.

use std::hint::black_box;
use std::time::Duration;

use crate::bench::{f, Bench, Table};
use crate::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use crate::coordinator::{Coordinator, CoordinatorOptions, ParallelRunner};
use crate::fl::{train, TrainOptions};
use crate::secure_agg::SecureAggregator;
use crate::sim::build_native_engine;
use crate::tensor::dispatch;
use crate::tensor::kernels::{self, reference, Scratch};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Roster sizes the masking comparison is swept over.
pub const PARTICIPANTS: [usize; 3] = [8, 32, 128];

/// Update dimensions the masking comparison is swept over. The 1M arm
/// stresses memory bandwidth rather than cache (ROADMAP item 3).
pub const DIMS: [usize; 3] = [1_000, 100_000, 1_000_000];

/// One scalar-vs-kernel masking comparison: the cost of masking one
/// participant's update against a roster of `participants` members.
#[derive(Clone, Debug)]
pub struct MaskMeasurement {
    pub participants: usize,
    pub dim: usize,
    pub scalar_ns_per_element: f64,
    pub kernel_ns_per_element: f64,
}

impl MaskMeasurement {
    pub fn speedup(&self) -> f64 {
        self.scalar_ns_per_element / self.kernel_ns_per_element
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("participants", Json::num(self.participants as f64)),
            ("dim", Json::num(self.dim as f64)),
            ("scalar_ns_per_element", Json::num(self.scalar_ns_per_element)),
            ("kernel_ns_per_element", Json::num(self.kernel_ns_per_element)),
            ("speedup", Json::num(self.speedup())),
        ])
    }
}

fn bench(group: &str, quick: bool) -> Bench {
    let min_time = if quick {
        Duration::from_millis(1)
    } else {
        Duration::from_millis(200)
    };
    Bench::new(group).with_min_time(min_time)
}

/// Mask-one-participant cost, scalar pipeline vs fused kernel, across
/// [`PARTICIPANTS`] × [`DIMS`]. Stream derivation is measured inside
/// both arms — the round pays it per member either way.
fn mask_measurements(quick: bool) -> Vec<MaskMeasurement> {
    let mut rng = Rng::new(0x5EC0);
    let mut out = Vec::new();
    for &m in &PARTICIPANTS {
        for &dim in &DIMS {
            let b = bench(&format!("secure/mask m={m},d={dim}"), quick);
            let agg = SecureAggregator::new(0xA6);
            let roster: Vec<u64> = (0..m as u64).collect();
            let values: Vec<f32> =
                (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let factor = 0.7f32;
            let mut acc = vec![0u64; dim];

            // scalar arm: scaled copy + encode + per-element PRG passes
            // + separate masked fold (the pre-kernel pipeline)
            let mut streams = Vec::new();
            let scalar_ns = b.run("scalar", || {
                agg.pair_streams_into(0, &roster, &mut streams);
                let masked = reference::scale_encode_mask(
                    black_box(&values),
                    factor,
                    &mut streams,
                );
                kernels::wrapping_accumulate(&mut acc, &[masked.as_slice()]);
            });

            // kernel arm: one fused chunked pass over a reused arena
            let mut scratch = Scratch::new();
            let kernel_ns = b.run("kernel", || {
                agg.pair_streams_into(0, &roster, &mut scratch.streams);
                kernels::scale_encode_mask_accumulate(
                    &mut acc,
                    black_box(&values),
                    factor,
                    &mut scratch.streams,
                    &mut scratch.ring,
                );
            });

            out.push(MaskMeasurement {
                participants: m,
                dim,
                scalar_ns_per_element: scalar_ns / dim as f64,
                kernel_ns_per_element: kernel_ns / dim as f64,
            });
        }
    }
    out
}

/// Shard/worker provisioning for the pooled sim arm: enough shards to
/// give every worker a masked fold per round.
const POOLED_SHARDS: usize = 4;
const POOLED_WORKERS: usize = 3;

/// End-to-end sim rounds/sec with secure aggregation on vs off — the
/// number that shows what the privacy-preserving configuration costs
/// over the plain path. `workers > 1` routes the run through the
/// sharded coordinator's worker pool, exercising the `MaskFold`
/// fan-out (trajectory-identical to the inline path — ring sums
/// commute — so the arms differ only in execution).
fn sim_rounds_per_sec(
    secure: bool,
    workers: usize,
    quick: bool,
) -> (f64, usize) {
    let rounds = if quick { 2 } else { 10 };
    let tag = match (secure, workers > 1) {
        (true, true) => "secure_pooled",
        (true, false) => "secure",
        (false, _) => "plain",
    };
    let cfg = ExperimentConfig {
        name: format!("bench_secure_sim_{tag}"),
        seed: 9,
        rounds,
        cohort: 16,
        budget: 4,
        strategy: Strategy::Aocs { j_max: 4 },
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 40, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: rounds,
        eval_examples: 128,
        workers,
        secure_updates: secure,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    };
    let b = bench("secure/sim", quick);
    let name = format!("{tag}_rounds");
    let ns = if workers > 1 {
        let engine = build_native_engine(&cfg);
        let mut runner = ParallelRunner::new(engine, workers);
        let mut coordinator = Coordinator::new(CoordinatorOptions {
            shards: POOLED_SHARDS,
            ..CoordinatorOptions::default()
        });
        b.run(&name, || {
            let run = coordinator
                .run(&cfg, &mut runner, &TrainOptions::default())
                .unwrap();
            black_box(run);
        })
    } else {
        let mut engine = build_native_engine(&cfg);
        b.run(&name, || {
            let run =
                train(&cfg, &mut engine, &TrainOptions::default()).unwrap();
            black_box(run);
        })
    };
    (rounds as f64 / (ns * 1e-9), rounds)
}

/// Run the full suite; returns the `BENCH_secure.json` document. The
/// active kernel backend (scalar or simd — `--kernel-backend` /
/// `FEDSAMP_KERNEL_BACKEND`) applies to the kernel arm of every
/// comparison and is recorded in the document.
pub fn run_secure_suite(quick: bool) -> Json {
    let backend = dispatch::active();
    let masks = mask_measurements(quick);
    let (secure_rps, rounds) = sim_rounds_per_sec(true, 1, quick);
    let (pooled_rps, _) = sim_rounds_per_sec(true, POOLED_WORKERS, quick);
    let (plain_rps, _) = sim_rounds_per_sec(false, 1, quick);
    println!(
        "\nsim throughput: secure {secure_rps:.2} (pooled {pooled_rps:.2}, \
         {POOLED_WORKERS} workers/{POOLED_SHARDS} shards) vs plain \
         {plain_rps:.2} rounds/sec ({rounds}-round FedAvg, pool=40)"
    );
    println!("kernel backend: {}", backend.name());
    let mut table = Table::new(&[
        "participants",
        "dim",
        "scalar ns/elem",
        "kernel ns/elem",
        "speedup",
    ]);
    for m in &masks {
        table.row(vec![
            m.participants.to_string(),
            m.dim.to_string(),
            f(m.scalar_ns_per_element, 2),
            f(m.kernel_ns_per_element, 2),
            format!("{:.2}x", m.speedup()),
        ]);
    }
    table.print();
    Json::obj(vec![
        ("bench", Json::str("secure")),
        ("quick", Json::Bool(quick)),
        ("kernel_backend", Json::str(backend.name())),
        (
            "mask",
            Json::Arr(masks.iter().map(MaskMeasurement::to_json).collect()),
        ),
        (
            "sim_rounds_per_sec",
            Json::obj(vec![
                ("config", Json::str("fedavg_femnist40")),
                ("rounds_per_run", Json::num(rounds as f64)),
                ("secure", Json::num(secure_rps)),
                ("secure_pooled", Json::num(pooled_rps)),
                ("pooled_workers", Json::num(POOLED_WORKERS as f64)),
                ("pooled_shards", Json::num(POOLED_SHARDS as f64)),
                ("plain", Json::num(plain_rps)),
                ("secure_over_plain", Json::num(secure_rps / plain_rps)),
            ]),
        ),
    ])
}
