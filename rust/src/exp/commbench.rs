//! The `bench comm` suite: the typed wire layer's regression harness
//! (EXPERIMENTS.md §Comm).
//!
//! Three measurements:
//!
//! * **Fold micro** — the payload-native sparse scatter fold
//!   (`aggregate::payload_weighted_partial`) vs the retained
//!   densify-then-accumulate reference
//!   (`aggregate::densified_weighted_partial`) on rand-k payloads with
//!   k ≪ d. The two are bit-identical (property-tested), so the ratio
//!   is pure speed: the reference pays an O(d) densify + O(d) fold per
//!   member, the scatter fold pays O(k).
//! * **Wire codec** — encode+decode ns/element per payload kind, the
//!   cost of the byte-exact framing the meter measures with.
//! * **End-to-end sim arms** — rounds/sec and *measured* bytes/round
//!   across compressor × strategy, plus the sparse-fold vs
//!   densified-fold comparison on the rand-k arm.
//!
//! Shared by the `fedsamp bench comm` CLI mode (which also emits
//! `BENCH_comm.json`) and `benches/micro_comm.rs`. Both arms of every
//! comparison run in the same process in the same run, so machine
//! variance cancels out of the ratios.

use std::hint::black_box;
use std::time::Duration;

use crate::bench::Bench;
use crate::compress::Compressor;
use crate::config::{Algorithm, DataSpec, ExperimentConfig, Strategy};
use crate::coordinator::aggregate::{
    densified_weighted_partial, payload_weighted_partial,
};
use crate::fl::{train, TrainOptions};
use crate::sim::build_native_engine;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::wire::Payload;

/// Dimensions the fold comparison is swept over.
pub const FOLD_DIMS: [usize; 2] = [10_000, 100_000];

/// Members per shard group in the fold comparison.
const FOLD_MEMBERS: usize = 8;

fn bench(group: &str, quick: bool) -> Bench {
    let min_time = if quick {
        Duration::from_millis(1)
    } else {
        Duration::from_millis(200)
    };
    Bench::new(group).with_min_time(min_time)
}

/// One sparse-fold vs densified-fold comparison at dimension `d`
/// (k = d/100 retained coordinates per member).
#[derive(Clone, Debug)]
pub struct FoldMeasurement {
    pub dim: usize,
    pub k: usize,
    pub sparse_ns: f64,
    pub densified_ns: f64,
}

impl FoldMeasurement {
    pub fn speedup(&self) -> f64 {
        self.densified_ns / self.sparse_ns
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dim", Json::num(self.dim as f64)),
            ("k", Json::num(self.k as f64)),
            ("sparse_ns_per_fold", Json::num(self.sparse_ns)),
            ("densified_ns_per_fold", Json::num(self.densified_ns)),
            ("speedup", Json::num(self.speedup())),
        ])
    }
}

fn fold_measurements(quick: bool) -> Vec<FoldMeasurement> {
    let mut rng = Rng::new(0xC0_33);
    let mut out = Vec::new();
    for &d in &FOLD_DIMS {
        let k = (d / 100).max(1);
        let b = bench(&format!("comm/fold d={d},k={k}"), quick);
        let c = Compressor::RandK { k };
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let payloads: Vec<Payload> =
            (0..FOLD_MEMBERS).map(|_| c.compress(&x, &mut rng)).collect();
        let members: Vec<&Payload> = payloads.iter().collect();
        let weights: Vec<f32> =
            (0..FOLD_MEMBERS).map(|i| 0.4 + i as f32 * 0.1).collect();
        let sparse_ns = b.run("sparse", || {
            black_box(payload_weighted_partial(d, &members, &weights));
        });
        let densified_ns = b.run("densified", || {
            black_box(densified_weighted_partial(d, &members, &weights));
        });
        out.push(FoldMeasurement { dim: d, k, sparse_ns, densified_ns });
    }
    out
}

/// Encode+decode round-trip cost per payload kind at a fixed dimension.
fn wire_measurements(quick: bool) -> Vec<Json> {
    let d = 10_000;
    let mut rng = Rng::new(0xE2C0);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let b = bench(&format!("comm/wire d={d}"), quick);
    let mut out = Vec::new();
    for c in [
        Compressor::None,
        Compressor::RandK { k: d / 100 },
        Compressor::QsgdQuant { levels: 4 },
    ] {
        let p = c.compress(&x, &mut rng);
        let bytes = p.wire_bytes();
        let mut frame = Vec::new();
        let ns = b.run(&c.name(), || {
            frame.clear();
            p.encode_into(&mut frame);
            black_box(Payload::decode(&frame).expect("round trip"));
        });
        out.push(Json::obj(vec![
            ("compressor", Json::str(c.name())),
            ("wire_bytes", Json::num(bytes as f64)),
            ("estimated_bytes", Json::num(c.bits(d) as f64 / 8.0)),
            ("roundtrip_ns", Json::num(ns)),
        ]));
    }
    out
}

/// One end-to-end sim arm: rounds/sec plus measured bytes/round.
struct SimArm {
    strategy: &'static str,
    compressor: String,
    fold: &'static str,
    rounds_per_sec: f64,
    bytes_per_round: f64,
}

impl SimArm {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", Json::str(self.strategy)),
            ("compressor", Json::str(self.compressor.clone())),
            ("fold", Json::str(self.fold)),
            ("rounds_per_sec", Json::num(self.rounds_per_sec)),
            ("bytes_per_round", Json::num(self.bytes_per_round)),
        ])
    }
}

/// The sim config every arm shares: plain (non-secure) aggregation so
/// the payload-native plain folds are on the measured path; the secure
/// configuration's densify boundary is covered by `bench secure`.
fn arm_cfg(tag: &str, rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("bench_comm_{tag}"),
        seed: 9,
        rounds,
        cohort: 16,
        budget: 4,
        strategy: Strategy::Aocs { j_max: 4 },
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: 0.05,
        },
        data: DataSpec::FemnistLike { pool: 40, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: rounds,
        eval_examples: 128,
        workers: 1,
        secure_updates: false,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

fn sim_arm(
    strategy: Strategy,
    compressor: Option<Compressor>,
    densify_folds: bool,
    quick: bool,
) -> SimArm {
    let rounds = if quick { 2 } else { 10 };
    let cname =
        compressor.as_ref().map_or_else(|| "none".into(), Compressor::name);
    let fold = if densify_folds { "densified" } else { "sparse" };
    let sname = strategy.name();
    let tag = format!("{sname}_{cname}_{fold}");
    let cfg = arm_cfg(&tag, rounds).with_strategy(strategy);
    let opts =
        TrainOptions {
            compressor,
            verbose_every: 0,
            densify_folds,
            ..TrainOptions::default()
        };
    let mut engine = build_native_engine(&cfg);
    let b = bench("comm/sim", quick);
    let mut bytes_per_round = 0.0;
    let ns = b.run(&tag, || {
        let run = train(&cfg, &mut engine, &opts).unwrap();
        bytes_per_round =
            run.total_uplink_bytes() as f64 / run.rounds.len() as f64;
        black_box(run);
    });
    SimArm {
        strategy: sname,
        compressor: cname,
        fold,
        rounds_per_sec: rounds as f64 / (ns * 1e-9),
        bytes_per_round,
    }
}

/// Run the full suite; returns the `BENCH_comm.json` document.
pub fn run_comm_suite(quick: bool) -> Json {
    let folds = fold_measurements(quick);
    let wire = wire_measurements(quick);

    // compressor × strategy grid, payload-native folds
    let mut arms = Vec::new();
    for strategy in [Strategy::Full, Strategy::Aocs { j_max: 4 }] {
        for compressor in [
            None,
            Some(Compressor::RandK { k: 64 }),
            Some(Compressor::QsgdQuant { levels: 4 }),
        ] {
            arms.push(sim_arm(strategy.clone(), compressor, false, quick));
        }
    }
    // the sparse-vs-densified end-to-end comparison on the rand-k arm
    let densified_arm = sim_arm(
        Strategy::Aocs { j_max: 4 },
        Some(Compressor::RandK { k: 64 }),
        true,
        quick,
    );
    let sparse_rps = arms
        .iter()
        .find(|a| a.strategy == "aocs" && a.compressor == "randk64")
        .map(|a| a.rounds_per_sec)
        .unwrap_or(f64::NAN);

    for f in &folds {
        println!(
            "fold d={:>6} k={:>4}: sparse {:.2}x over densified \
             ({:.0} vs {:.0} ns/fold)",
            f.dim,
            f.k,
            f.speedup(),
            f.sparse_ns,
            f.densified_ns
        );
    }
    for a in &arms {
        println!(
            "sim {}×{}: {:.2} rounds/sec, {:.0} measured bytes/round",
            a.strategy, a.compressor, a.rounds_per_sec, a.bytes_per_round
        );
    }
    println!(
        "sim aocs×randk64 fold comparison: sparse {sparse_rps:.2} vs \
         densified {:.2} rounds/sec",
        densified_arm.rounds_per_sec
    );

    let mut arm_docs: Vec<Json> = arms.iter().map(SimArm::to_json).collect();
    arm_docs.push(densified_arm.to_json());
    Json::obj(vec![
        ("bench", Json::str("comm")),
        ("quick", Json::Bool(quick)),
        (
            "kernel_backend",
            Json::str(crate::tensor::dispatch::active().name()),
        ),
        (
            "fold",
            Json::Arr(folds.iter().map(FoldMeasurement::to_json).collect()),
        ),
        ("wire", Json::Arr(wire)),
        ("sim_arms", Json::Arr(arm_docs)),
        (
            "sparse_vs_densified_rounds_per_sec",
            Json::obj(vec![
                ("sparse", Json::num(sparse_rps)),
                ("densified", Json::num(densified_arm.rounds_per_sec)),
            ]),
        ),
    ])
}
