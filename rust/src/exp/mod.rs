//! Experiment drivers: the code that regenerates every figure of the
//! paper's evaluation (used by the CLI, the examples and the benches).

pub mod commbench;
pub mod figures;
pub mod kernelbench;
pub mod securebench;
pub mod sweep;

use crate::config::{presets, ExperimentConfig, Strategy};
use crate::data;
use crate::fl::{train, ClientEngine, TrainOptions};
use crate::metrics::{average_runs, RunResult};
use crate::runtime::engine::XlaEngine;
use crate::sim::run_sim_with;

/// Default artifacts directory (relative to the crate root).
pub fn default_artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

/// Whether AOT artifacts are present.
pub fn have_artifacts(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}

/// Run one experiment, picking the engine from `cfg.model`:
/// `native:*` → sim path; otherwise the XLA path via `artifacts_dir`.
pub fn run_experiment(
    cfg: &ExperimentConfig,
    artifacts_dir: &str,
    opts: &TrainOptions,
) -> Result<RunResult, String> {
    if cfg.model.starts_with("native:") {
        return run_sim_with(cfg, opts);
    }
    if !have_artifacts(artifacts_dir) {
        return Err(format!(
            "artifacts missing in {artifacts_dir}; run `make artifacts` \
             (or use a native:* model for the sim path)"
        ));
    }
    let fd = data::build(&cfg.data, cfg.eval_examples, cfg.seed);
    let mut engine = XlaEngine::new(
        artifacts_dir,
        &cfg.model,
        fd,
        cfg.algorithm.clone(),
        cfg.workers,
        cfg.seed,
    )
    .map_err(|e| e.to_string())?;
    train(cfg, &mut engine as &mut dyn ClientEngine, opts)
}

/// One comparison arm: strategy + per-seed-averaged result.
pub struct Arm {
    pub strategy: Strategy,
    pub result: RunResult,
}

/// Run the paper's three-way comparison (full / uniform / AOCS) for a
/// base config, averaging over `seeds` seeds, with the per-arm tuned
/// local step size from Appendix F (presets::tuned_eta_l).
pub fn run_comparison(
    base: &ExperimentConfig,
    seeds: u64,
    artifacts_dir: &str,
    opts: &TrainOptions,
) -> Result<Vec<Arm>, String> {
    let strategies = [
        Strategy::Full,
        Strategy::Uniform,
        Strategy::Aocs { j_max: 4 },
    ];
    let dataset = base.data.name();
    let mut arms = Vec::new();
    for s in strategies {
        let mut cfg = base.with_strategy(s.clone());
        // re-tune η_l per arm as the paper does (Appendix F)
        if let crate::config::Algorithm::FedAvg { local_epochs, eta_g, .. } =
            cfg.algorithm
        {
            cfg.algorithm = crate::config::Algorithm::FedAvg {
                local_epochs,
                eta_g,
                eta_l: presets::tuned_eta_l(&dataset, &s),
            };
        }
        let mut runs = Vec::new();
        for seed in 0..seeds {
            let mut c = cfg.clone();
            c.seed = base.seed + seed;
            runs.push(run_experiment(&c, artifacts_dir, opts)?);
        }
        arms.push(Arm { strategy: s, result: average_runs(&runs) });
    }
    Ok(arms)
}

/// Save each arm's series to `<out>/<name>.json` + `.csv`.
pub fn save_arms(arms: &[Arm], out_dir: &str) -> Result<Vec<String>, String> {
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    let mut paths = Vec::new();
    for arm in arms {
        let p = arm
            .result
            .save(out_dir)
            .map_err(|e| e.to_string())?;
        let csv_path = p.replace(".json", ".csv");
        std::fs::write(&csv_path, arm.result.to_csv())
            .map_err(|e| e.to_string())?;
        paths.push(p);
        paths.push(csv_path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataSpec;

    #[test]
    fn native_model_routes_to_sim() {
        let mut cfg = presets::dsgd_theory(4, 0.05);
        cfg.rounds = 5;
        cfg.data = DataSpec::FemnistLike { pool: 16, variant: 1 };
        cfg.secure_updates = false;
        let run = run_experiment(&cfg, "/nonexistent", &TrainOptions::default())
            .unwrap();
        assert_eq!(run.rounds.len(), 5);
    }

    #[test]
    fn missing_artifacts_is_a_clear_error() {
        let mut cfg = presets::femnist(1, 3);
        cfg.rounds = 2;
        let err = run_experiment(&cfg, "/nonexistent", &TrainOptions::default());
        assert!(err.is_err());
        assert!(err.unwrap_err().contains("artifacts missing"));
    }

    #[test]
    fn comparison_retunes_eta_per_arm() {
        let mut base = presets::femnist(1, 3);
        base.rounds = 4;
        base.model = "native:logistic".into();
        base.data = DataSpec::FemnistLike { pool: 24, variant: 1 };
        base.eval_examples = 124;
        base.secure_updates = false;
        let arms =
            run_comparison(&base, 1, "/nonexistent", &TrainOptions::default())
                .unwrap();
        assert_eq!(arms.len(), 3);
        let names: Vec<_> =
            arms.iter().map(|a| a.strategy.name()).collect();
        assert_eq!(names, vec!["full", "uniform", "aocs"]);
    }
}
