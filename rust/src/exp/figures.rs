//! Per-figure regeneration: builds the config for each paper figure,
//! runs the comparison, and prints the series the paper plots.

use crate::bench::{f, Table};
use crate::config::{presets, ExperimentConfig};
use crate::data;
use crate::fl::TrainOptions;
use crate::util::stats::Histogram;

use super::{run_comparison, save_arms, Arm};

/// Scale knob: `quick` shrinks rounds/pool so benches finish in seconds;
/// `full` is the paper's setting (151 rounds, pool-scale data).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Scale, String> {
        match s {
            "quick" => Ok(Scale::Quick),
            "full" => Ok(Scale::Full),
            other => Err(format!("scale must be quick|full, got '{other}'")),
        }
    }
}

/// Apply the scale knob to a preset.
pub fn scaled(mut cfg: ExperimentConfig, scale: Scale) -> ExperimentConfig {
    if scale == Scale::Quick {
        cfg.rounds = 30;
        cfg.eval_every = 5;
        cfg.eval_examples = 320;
        cfg.data = match cfg.data {
            crate::config::DataSpec::FemnistLike { variant, .. } => {
                crate::config::DataSpec::FemnistLike { pool: 80, variant }
            }
            crate::config::DataSpec::ShakespeareLike { .. } => {
                crate::config::DataSpec::ShakespeareLike { pool: 120 }
            }
            crate::config::DataSpec::CifarLike { .. } => {
                crate::config::DataSpec::CifarLike { pool: 60, per_client: 60 }
            }
        };
        cfg.secure_updates = false; // masking cost off the quick path
    }
    cfg
}

/// Figure 2: client-size distributions of the three modified FEMNIST
/// training sets.
pub fn figure2(pool: usize, seed: u64) {
    println!("\n=== Figure 2: FEMNIST client-size distributions ===");
    for variant in 1..=3u8 {
        let fd = data::build(
            &crate::config::DataSpec::FemnistLike { pool, variant },
            16,
            seed,
        );
        let sizes = fd.client_sizes();
        let (s, a, b) = data::synth_image::unbalance_params(variant);
        let mut h = Histogram::new(0.0, 400.0, 10);
        for &n in &sizes {
            h.push(n as f64);
        }
        println!(
            "\nDataset {variant} (s={s}, a={a}, b={b}): {} clients, \
             {} examples total",
            sizes.len(),
            fd.total_examples()
        );
        print!("{}", h.ascii(40));
    }
}

/// The per-figure series table: one row per (strategy, eval round).
pub fn print_series(fig: &str, arms: &[Arm]) {
    println!("\n=== {fig}: validation accuracy / train loss series ===");
    let mut t = Table::new(&[
        "strategy", "round", "train_loss", "val_acc", "best_acc",
        "uplink_Mbits",
    ]);
    for arm in arms {
        let mut best = f64::NAN;
        for r in &arm.result.rounds {
            if r.val_accuracy.is_nan() {
                continue;
            }
            best = if best.is_nan() {
                r.val_accuracy
            } else {
                best.max(r.val_accuracy)
            };
            t.row(vec![
                arm.strategy.name().into(),
                r.round.to_string(),
                f(r.train_loss, 4),
                f(r.val_accuracy, 4),
                f(best, 4),
                f(r.uplink_bits as f64 / 1e6, 2),
            ]);
        }
    }
    t.print();
}

/// The headline summary the paper narrates (§5.4): rounds- and
/// bits-to-target-accuracy per strategy.
pub fn print_summary(fig: &str, arms: &[Arm]) {
    // target = 90% of the best accuracy any arm reached
    let best_overall = arms
        .iter()
        .map(|a| a.result.best_accuracy())
        .fold(f64::NAN, f64::max);
    let target = best_overall * 0.9;
    println!(
        "\n=== {fig}: summary (target = {:.3} = 90% of best) ===",
        target
    );
    let mut t = Table::new(&[
        "strategy",
        "final_acc",
        "best_acc",
        "rounds_to_target",
        "Mbits_to_target",
        "total_Mbits",
        "mean_alpha",
    ]);
    for arm in arms {
        let r = &arm.result;
        t.row(vec![
            arm.strategy.name().into(),
            f(r.final_accuracy(), 4),
            f(r.best_accuracy(), 4),
            r.rounds_to_accuracy(target)
                .map(|x| x.to_string())
                .unwrap_or_else(|| "-".into()),
            r.bits_to_accuracy(target)
                .map(|b| f(b as f64 / 1e6, 2))
                .unwrap_or_else(|| "-".into()),
            f(r.total_uplink_bits() as f64 / 1e6, 2),
            f(r.mean_alpha(), 3),
        ]);
    }
    t.print();
}

/// Build the preset list for a figure id ("3".."7", "13").
pub fn figure_configs(fig: &str, scale: Scale) -> Vec<ExperimentConfig> {
    presets::by_figure(fig)
        .into_iter()
        .map(|c| scaled(c, scale))
        .collect()
}

/// Run and print one whole figure; returns the arms of each sub-panel.
pub fn run_figure(
    fig: &str,
    scale: Scale,
    seeds: u64,
    artifacts_dir: &str,
    use_sim: bool,
    out_dir: Option<&str>,
    opts: &TrainOptions,
) -> Result<Vec<Vec<Arm>>, String> {
    if fig == "2" {
        figure2(350, 1);
        return Ok(vec![]);
    }
    let mut all = Vec::new();
    for mut cfg in figure_configs(fig, scale) {
        if use_sim {
            cfg.model = "native:logistic".into();
        }
        let label = format!("Figure {fig} ({})", cfg.name);
        let arms = run_comparison(&cfg, seeds, artifacts_dir, opts)?;
        print_series(&label, &arms);
        print_summary(&label, &arms);
        if let Some(dir) = out_dir {
            save_arms(&arms, dir)?;
        }
        all.push(arms);
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("quick").unwrap(), Scale::Quick);
        assert_eq!(Scale::parse("full").unwrap(), Scale::Full);
        assert!(Scale::parse("medium").is_err());
    }

    #[test]
    fn quick_scale_shrinks() {
        let cfg = scaled(presets::femnist(1, 3), Scale::Quick);
        assert_eq!(cfg.rounds, 30);
        assert!(!cfg.secure_updates);
        let full = scaled(presets::femnist(1, 3), Scale::Full);
        assert_eq!(full.rounds, 151);
    }

    #[test]
    fn figure_configs_cover_eval() {
        for fig in ["3", "4", "5", "6", "7", "13"] {
            assert!(!figure_configs(fig, Scale::Quick).is_empty());
        }
    }

    #[test]
    fn sim_figure_runs_end_to_end() {
        let mut cfgs = figure_configs("3", Scale::Quick);
        let mut cfg = cfgs.remove(0);
        cfg.rounds = 6;
        cfg.model = "native:logistic".into();
        cfg.data = crate::config::DataSpec::FemnistLike { pool: 30, variant: 1 };
        let arms = run_comparison(&cfg, 1, "/nonexistent",
            &TrainOptions::default()).unwrap();
        print_series("test", &arms);
        print_summary("test", &arms);
        assert_eq!(arms.len(), 3);
    }
}
