//! Theory-validation drivers: measure the quantities Theorems 13–18 bound
//! on the quadratic testbed where `x*`, L and µ are known exactly.

use crate::model::quadratic::QuadraticProblem;
use crate::sampling::{probability, variance, Sampler};
use crate::tensor;
use crate::util::rng::Rng;

/// Per-round observables of a DSGD run on a quadratic problem.
#[derive(Clone, Debug)]
pub struct TheoryRound {
    pub round: usize,
    /// ‖x^k − x*‖² — the Theorem-13 Lyapunov value
    pub dist_sq: f64,
    /// f(x^k) − f*
    pub suboptimality: f64,
    pub alpha: f64,
    pub gamma: f64,
}

/// Result of a DSGD theory run.
#[derive(Clone, Debug)]
pub struct TheoryRun {
    pub strategy: String,
    pub eta: f64,
    pub rounds: Vec<TheoryRound>,
    pub diverged: bool,
}

impl TheoryRun {
    pub fn final_dist(&self) -> f64 {
        self.rounds.last().map(|r| r.dist_sq).unwrap_or(f64::NAN)
    }

    pub fn mean_gamma(&self) -> f64 {
        if self.rounds.is_empty() {
            return f64::NAN;
        }
        self.rounds.iter().map(|r| r.gamma).sum::<f64>()
            / self.rounds.len() as f64
    }
}

/// Run DSGD (Eq. 2) with *exact* local gradients on a quadratic problem,
/// tracking the Theorem-13 recursion quantities.
///
/// `noise` adds optional gradient noise with std σ (Assumption 7's σ).
pub fn run_dsgd_quadratic(
    problem: &QuadraticProblem,
    sampler: &Sampler,
    m: usize,
    eta: f64,
    rounds: usize,
    noise: f64,
    seed: u64,
) -> TheoryRun {
    let n = problem.clients.len();
    let dim = problem.dim;
    let xstar = problem.minimizer();
    let fstar = problem.loss(&xstar);
    let mut rng = Rng::new(seed ^ 0x7E0);
    let mut x = vec![0.0f32; dim];
    let mut out = TheoryRun {
        strategy: sampler.name().into(),
        eta,
        rounds: Vec::with_capacity(rounds),
        diverged: false,
    };

    let mut grads: Vec<Vec<f32>> = vec![vec![0.0; dim]; n];
    for round in 0..rounds {
        // every client computes g_i = ∇f_i(x) (+ noise)
        for (i, c) in problem.clients.iter().enumerate() {
            c.grad(&x, &mut grads[i]);
            if noise > 0.0 {
                for g in grads[i].iter_mut() {
                    *g += rng.normal_f32(0.0, noise as f32);
                }
            }
        }
        let norms: Vec<f64> = grads
            .iter()
            .zip(&problem.weights)
            .map(|(g, &w)| w * tensor::norm(g))
            .collect();
        if norms.iter().any(|u| !u.is_finite()) {
            out.diverged = true; // gradient overflow: count as divergence
            break;
        }
        let decision = sampler.decide(&norms, m);
        let alpha = if n > m {
            variance::improvement_factor(&norms, m)
        } else {
            0.0
        };
        let gamma = variance::gamma(alpha, n, m);
        let sel = probability::draw_independent(&decision.probs, &mut rng);
        let mut agg = vec![0.0f32; dim];
        for i in 0..n {
            if sel[i] && decision.probs[i] > 0.0 {
                let f = (problem.weights[i] / decision.probs[i]) as f32;
                tensor::axpy(&mut agg, f, &grads[i]);
            }
        }
        tensor::axpy(&mut x, -(eta as f32), &agg);
        if !tensor::all_finite(&x) {
            out.diverged = true;
            break;
        }
        out.rounds.push(TheoryRound {
            round,
            dist_sq: tensor::dist_sq(&x, &xstar),
            suboptimality: problem.loss(&x) - fstar,
            alpha,
            gamma,
        });
    }
    out
}

/// Largest *usable* step size for a strategy: bisection over "the run
/// makes clear progress on ‖x − x*‖² within the horizon" — the §5.4
/// "optimal sampling allows larger learning rates" experiment. (The
/// paper tunes η_l for best accuracy; a step size whose sampling-
/// variance floor swallows all progress is not usable even if it does
/// not blow up, so the criterion is progress, not mere non-divergence.)
pub fn max_stable_eta(
    problem: &QuadraticProblem,
    sampler: &Sampler,
    m: usize,
    rounds: usize,
    seed: u64,
) -> f64 {
    let stable = |eta: f64| -> bool {
        let run =
            run_dsgd_quadratic(problem, sampler, m, eta, rounds, 0.0, seed);
        if run.diverged || run.rounds.is_empty() {
            return false;
        }
        // progress: the tail must sit well below the first-round value
        // (averaging the tail de-noises the stochastic floor)
        let first = run.rounds[0].dist_sq;
        let tail = run.rounds.iter().rev().take(10);
        let tail_mean =
            tail.clone().map(|r| r.dist_sq).sum::<f64>() / 10.0_f64.min(run.rounds.len() as f64);
        tail_mean < first * 0.5
    };
    let mut lo = 1e-4;
    let mut hi = 64.0;
    if !stable(lo) {
        return 0.0;
    }
    while stable(hi) {
        hi *= 2.0;
        if hi > 1e6 {
            return hi;
        }
    }
    for _ in 0..30 {
        let mid = 0.5 * (lo + hi);
        if stable(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problem() -> QuadraticProblem {
        QuadraticProblem::generate(32, 16, 3.0, 8.0, None, 11)
    }

    #[test]
    fn dsgd_converges_with_safe_step() {
        let p = problem();
        let eta = 0.5 / p.smoothness();
        let run =
            run_dsgd_quadratic(&p, &Sampler::Full, 32, eta, 300, 0.0, 1);
        assert!(!run.diverged);
        assert!(run.final_dist() < run.rounds[0].dist_sq * 1e-3);
    }

    #[test]
    fn gamma_tracks_strategy_order() {
        // Theorem 13: full ⇒ γ=1; uniform ⇒ γ=m/n; OCS in between
        let p = problem();
        let eta = 0.2 / p.smoothness();
        let m = 4;
        let g = |s: &Sampler| {
            run_dsgd_quadratic(&p, s, m, eta, 60, 0.0, 2).mean_gamma()
        };
        let ocs = g(&Sampler::Ocs);
        assert!(ocs > 4.0 / 32.0 - 1e-9, "γ below m/n: {ocs}");
        assert!(ocs <= 1.0 + 1e-9);
    }

    #[test]
    fn ocs_converges_faster_than_uniform_at_same_eta() {
        // single trajectories are noisy at the variance floor: compare the
        // mean tail suboptimality over several seeds
        let p = problem();
        let eta = 0.25 / p.smoothness();
        let m = 3;
        let tail_mean = |s: &Sampler| -> f64 {
            let mut acc = 0.0;
            let mut count = 0usize;
            for seed in 0..5 {
                let run = run_dsgd_quadratic(&p, s, m, eta, 400, 0.0, seed);
                assert!(!run.diverged, "{} diverged", s.name());
                for r in run.rounds.iter().rev().take(100) {
                    acc += r.suboptimality;
                    count += 1;
                }
            }
            acc / count as f64
        };
        let ocs = tail_mean(&Sampler::Ocs);
        let uni = tail_mean(&Sampler::Uniform);
        assert!(
            ocs < uni,
            "ocs tail suboptimality {ocs} !< uniform {uni}"
        );
    }

    #[test]
    fn larger_stable_step_for_ocs_than_uniform() {
        // the §5.4 claim on the measurable testbed; needs genuine norm
        // heterogeneity (skewed client scales), else the two coincide
        let p = QuadraticProblem::generate_skewed(
            32, 16, 3.0, 2.0, 8.0, None, 11,
        );
        let m = 3;
        let e_ocs = max_stable_eta(&p, &Sampler::Ocs, m, 150, 5);
        let e_uni = max_stable_eta(&p, &Sampler::Uniform, m, 150, 5);
        assert!(
            e_ocs >= e_uni * 0.98,
            "OCS max stable η {e_ocs} < uniform {e_uni}"
        );
    }

    #[test]
    fn alpha_decreases_with_skew() {
        // the heterogeneity knob works: skew ↑ ⇒ α ↓
        let mean_alpha = |skew: f64| {
            let p = QuadraticProblem::generate_skewed(
                32, 16, 3.0, skew, 8.0, None, 13,
            );
            let eta = 0.05 / p.smoothness();
            let run =
                run_dsgd_quadratic(&p, &Sampler::Ocs, 4, eta, 80, 0.0, 3);
            run.rounds.iter().map(|r| r.alpha).sum::<f64>()
                / run.rounds.len() as f64
        };
        let lo = mean_alpha(0.0);
        let hi = mean_alpha(3.0);
        assert!(hi < lo, "alpha(skew=3)={hi} !< alpha(skew=0)={lo}");
    }

    #[test]
    fn noise_floor_scales_with_sigma() {
        let p = problem();
        let eta = 0.1 / p.smoothness();
        let quiet =
            run_dsgd_quadratic(&p, &Sampler::Full, 32, eta, 400, 0.01, 7);
        let loud =
            run_dsgd_quadratic(&p, &Sampler::Full, 32, eta, 400, 1.0, 7);
        assert!(quiet.final_dist() < loud.final_dist());
    }
}
