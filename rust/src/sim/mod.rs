//! Pure-rust simulation path: the same FL protocol as the XLA path, with
//! exact-gradient native models — fast enough for the theory experiments
//! (Theorems 13/15/17/18) and large parameter sweeps.

pub mod theory;

use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::{
    ClientCompute, Coordinator, CoordinatorOptions, ParallelRunner,
};
use crate::data::{self, ClientData, FederatedData};
use crate::fl::{train, ClientEngine, EvalOutcome, LocalOutcome, TrainOptions};
use crate::metrics::RunResult;
use crate::model::logistic::Logistic;
use crate::model::NativeModel;
use crate::tensor;
use crate::tensor::kernels::{self, Scratch};
use crate::util::rng::Rng;

/// Native engine: clients run SGD on a [`NativeModel`] over
/// [`FederatedData`] with closed-form gradients.
pub struct NativeEngine<M: NativeModel> {
    pub model: M,
    pub dataset: FederatedData,
    pub algorithm: Algorithm,
    pub batch_size: usize,
    seed: u64,
    /// engine-owned arena for the legacy [`ClientEngine::run_local`]
    /// path — allocated once for the engine's lifetime, matching the
    /// pool workers' allocate-once contract (DESIGN.md §5)
    scratch: Scratch,
}

impl<M: NativeModel> NativeEngine<M> {
    pub fn new(
        model: M,
        dataset: FederatedData,
        algorithm: Algorithm,
        batch_size: usize,
        seed: u64,
    ) -> Self {
        NativeEngine {
            model,
            dataset,
            algorithm,
            batch_size,
            seed,
            scratch: Scratch::new(),
        }
    }

    /// One client's local work, allocation-free on the hot path: the
    /// gradient/params/logits/index buffers all live in the per-worker
    /// `scratch` arena; the only allocation left is the `delta` the
    /// [`LocalOutcome`] must own.
    fn local_pass(
        &self,
        round: usize,
        global: &[f32],
        client_id: usize,
        scratch: &mut Scratch,
    ) -> LocalOutcome {
        let data = &self.dataset.clients[client_id];
        let mut rng =
            Rng::new(self.seed ^ 0x10CA1).fork(round as u64).fork(client_id as u64);
        let dim = self.model.dim();
        Scratch::ensure(&mut scratch.grad, dim);
        match self.algorithm {
            Algorithm::Dsgd { .. } => {
                // one stochastic gradient g_i^k (Eq. 2); U_i = g_i
                scratch.idx.clear();
                for _ in 0..self.batch_size.min(data.len()) {
                    scratch.idx.push(rng.range(0, data.len()));
                }
                let loss = self.model.loss_grad_scratch(
                    global,
                    data,
                    &scratch.idx,
                    &mut scratch.grad,
                    &mut scratch.work,
                );
                LocalOutcome {
                    delta: scratch.grad.clone(),
                    train_loss: loss,
                    examples: data.len(),
                }
            }
            Algorithm::FedAvg { local_epochs, eta_l, .. } => {
                // R local SGD steps; U_i = x^k − y_{i,R} (Algorithm 3).
                // The epoch walk consumes the exact RNG stream the
                // historical `epoch_batches` materialization did:
                // shuffle, then wrap-around pads for the final window.
                scratch.y.clear();
                scratch.y.extend_from_slice(global);
                let mut loss_sum = 0.0f64;
                let mut steps = 0usize;
                let n = data.len();
                let bsz = self.batch_size;
                assert!(bsz > 0); // the invariant epoch_batches enforced
                for _ in 0..local_epochs {
                    data.epoch_order_into(&mut scratch.idx, &mut rng);
                    let mut i = 0;
                    while i < n {
                        let end = (i + bsz).min(n);
                        let loss = if end - i == bsz {
                            self.model.loss_grad_scratch(
                                &scratch.y,
                                data,
                                &scratch.idx[i..end],
                                &mut scratch.grad,
                                &mut scratch.work,
                            )
                        } else {
                            scratch.tail.clear();
                            scratch.tail.extend_from_slice(&scratch.idx[i..end]);
                            while scratch.tail.len() < bsz {
                                let j = rng.range(0, n);
                                scratch.tail.push(scratch.idx[j]);
                            }
                            self.model.loss_grad_scratch(
                                &scratch.y,
                                data,
                                &scratch.tail,
                                &mut scratch.grad,
                                &mut scratch.work,
                            )
                        };
                        tensor::axpy(
                            &mut scratch.y,
                            -(eta_l as f32),
                            &scratch.grad,
                        );
                        loss_sum += loss;
                        steps += 1;
                        i += bsz;
                    }
                }
                let mut delta = vec![0.0f32; dim];
                tensor::sub_into(&mut delta, global, &scratch.y);
                LocalOutcome {
                    delta,
                    train_loss: loss_sum / steps.max(1) as f64,
                    examples: data.len(),
                }
            }
        }
    }
}

/// The sim engines are plain shared data + closed-form math, so one
/// instance can serve every worker thread of a coordinator shard pool:
/// `local_pass` depends only on `(round, client, global)`.
impl<M: NativeModel + 'static> ClientCompute for NativeEngine<M> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn num_clients(&self) -> usize {
        self.dataset.clients.len()
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        self.model.init_params(seed)
    }

    fn local_one(
        &self,
        round: usize,
        global: &[f32],
        client: usize,
        scratch: &mut Scratch,
    ) -> LocalOutcome {
        self.local_pass(round, global, client, scratch)
    }

    fn evaluate(&self, global: &[f32]) -> EvalOutcome {
        EvalOutcome {
            loss: self.model.loss(global, &self.dataset.validation),
            accuracy: self.model.accuracy(global, &self.dataset.validation),
        }
    }
}

impl<M: NativeModel> ClientEngine for NativeEngine<M> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn num_clients(&self) -> usize {
        self.dataset.clients.len()
    }

    fn client_examples(&self, id: usize) -> usize {
        self.dataset.clients[id].len()
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        self.model.init_params(seed)
    }

    fn run_local(
        &mut self,
        round: usize,
        global: &[f32],
        cohort: &[usize],
    ) -> Vec<LocalOutcome> {
        // the engine-owned arena serves the whole cohort sweep (taken
        // and restored around the borrow of `self`; a move, not a copy)
        let mut scratch = std::mem::take(&mut self.scratch);
        let outs = cohort
            .iter()
            .map(|&id| self.local_pass(round, global, id, &mut scratch))
            .collect();
        self.scratch = scratch;
        outs
    }

    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome {
        EvalOutcome {
            loss: self.model.loss(global, &self.dataset.validation),
            accuracy: self.model.accuracy(global, &self.dataset.validation),
        }
    }
}

/// Feature-space compression for the sim path: the native logistic model
/// on raw 784/3072-dim images is slow at pool scale, so sim runs reduce
/// images via a fixed random projection (deterministic in the seed).
pub fn project_dataset(fd: &FederatedData, out_dim: usize, seed: u64) -> FederatedData {
    assert!(!fd.is_tokens, "projection applies to dense data");
    let in_dim = fd.input_dim;
    let mut rng = Rng::new(seed ^ 0x9801);
    let scale = 1.0 / (in_dim as f32).sqrt();
    let proj: Vec<f32> =
        (0..in_dim * out_dim).map(|_| rng.normal_f32(0.0, scale)).collect();
    let project_client = |c: &ClientData| -> ClientData {
        // one blocked GEMM per client: X (n × in_dim) · P (in_dim ×
        // out_dim); bit-identical to the seed per-row walk (ascending-j
        // accumulation, zero-skip preserved)
        let n = c.len();
        let mut x = vec![0.0f32; n * out_dim];
        kernels::gemm_block(n, in_dim, out_dim, &c.x_dense, &proj, None, &mut x);
        ClientData { x_dense: x, x_tokens: vec![], labels: c.labels.clone(), dim: out_dim }
    };
    FederatedData {
        clients: fd.clients.iter().map(project_client).collect(),
        validation: project_client(&fd.validation),
        num_classes: fd.num_classes,
        input_dim: out_dim,
        is_tokens: false,
    }
}

/// Sim-path projected feature dimension.
pub const SIM_FEATURE_DIM: usize = 64;

/// Build the sim-path engine for a config: dataset (featurized for the
/// native logistic model) + [`NativeEngine`].
///
/// Token datasets are represented by positional one-hot features; dense
/// image datasets are reduced through a fixed random projection.
pub fn build_native_engine(cfg: &ExperimentConfig) -> NativeEngine<Logistic> {
    let fd = data::build(&cfg.data, cfg.eval_examples, cfg.seed);
    let fd = if fd.is_tokens {
        tokens_to_positional_onehot(&fd)
    } else {
        project_dataset(&fd, SIM_FEATURE_DIM, cfg.seed)
    };
    let model = Logistic::new(fd.input_dim, fd.num_classes, 1e-4);
    NativeEngine::new(model, fd, cfg.algorithm.clone(), cfg.batch_size, cfg.seed)
}

/// Run a config end-to-end on the sim path (native logistic model).
pub fn run_sim(cfg: &ExperimentConfig) -> Result<RunResult, String> {
    run_sim_with(cfg, &TrainOptions::default())
}

/// [`run_sim`] with explicit [`TrainOptions`].
///
/// `cfg.workers > 1` routes through the coordinator's shard worker pool
/// (single shard — trajectories are identical to the sequential path by
/// construction; results are placed by cohort position, never by
/// completion order). `workers <= 1` keeps the inline engine path.
pub fn run_sim_with(
    cfg: &ExperimentConfig,
    opts: &TrainOptions,
) -> Result<RunResult, String> {
    let engine = build_native_engine(cfg);
    if cfg.workers > 1 {
        let mut runner = ParallelRunner::new(engine, cfg.workers);
        let mut coordinator =
            Coordinator::new(CoordinatorOptions::single_shard());
        coordinator.run(cfg, &mut runner, opts)
    } else {
        let mut engine = engine;
        train(cfg, &mut engine, opts)
    }
}

/// Positional one-hot featurization for token data (sim path only):
/// each of the seq_len positions contributes a one-hot block, so the
/// logistic model can read the order-sensitive context (bag-of-chars
/// would destroy the Markov structure).
fn tokens_to_positional_onehot(fd: &FederatedData) -> FederatedData {
    let vocab = fd.num_classes;
    let conv = |c: &ClientData| -> ClientData {
        let n = c.len();
        let seq = c.dim;
        let dim = seq * vocab;
        let mut x = vec![0.0f32; n * dim];
        kernels::one_hot_expand(&c.x_tokens, seq, vocab, &mut x);
        ClientData { x_dense: x, x_tokens: vec![], labels: c.labels.clone(), dim }
    };
    FederatedData {
        clients: fd.clients.iter().map(conv).collect(),
        validation: conv(&fd.validation),
        num_classes: vocab,
        input_dim: fd.input_dim * vocab,
        is_tokens: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::config::{DataSpec, Strategy};

    fn quick_cfg(strategy: Strategy) -> ExperimentConfig {
        let mut cfg = presets::femnist(1, 3).with_strategy(strategy);
        cfg.rounds = 25;
        cfg.eval_examples = 248;
        cfg.data = DataSpec::FemnistLike { pool: 60, variant: 1 };
        cfg.secure_updates = false; // speed
        cfg
    }

    #[test]
    fn sim_femnist_loss_decreases() {
        let run = run_sim(&quick_cfg(Strategy::Aocs { j_max: 4 })).unwrap();
        let first = run.rounds[0].train_loss;
        let last = run.final_train_loss();
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        assert!(run.final_accuracy() > 1.0 / 62.0 * 3.0, "no learning");
    }

    #[test]
    fn sim_config_compressor_reduces_measured_bytes() {
        // the config-level compressor rides the whole sim path: native
        // sparse payloads on the wire, measured bytes shrinking
        use crate::compress::Compressor;
        let dense_cfg = quick_cfg(Strategy::Aocs { j_max: 4 });
        let dense = run_sim(&dense_cfg).unwrap();
        let mut sparse_cfg = quick_cfg(Strategy::Aocs { j_max: 4 });
        sparse_cfg.compressor = Some(Compressor::RandK { k: 64 });
        let sparse = run_sim(&sparse_cfg).unwrap();
        assert!(
            sparse.total_uplink_bytes() < dense.total_uplink_bytes() / 2,
            "{} vs {}",
            sparse.total_uplink_bytes(),
            dense.total_uplink_bytes()
        );
        assert_eq!(
            sparse.total_uplink_bits(),
            sparse.total_uplink_bytes() * 8
        );
        assert!(sparse.final_train_loss().is_finite());
    }

    #[test]
    fn sim_token_dataset_runs() {
        let mut cfg = quick_cfg(Strategy::Uniform);
        cfg.data = DataSpec::ShakespeareLike { pool: 30 };
        cfg.batch_size = 8;
        cfg.rounds = 10;
        let run = run_sim(&cfg).unwrap();
        assert_eq!(run.rounds.len(), 10);
        assert!(run.final_train_loss().is_finite());
    }

    #[test]
    fn projection_preserves_labels_and_count() {
        let fd = data::build(
            &DataSpec::FemnistLike { pool: 5, variant: 0 },
            64,
            3,
        );
        let p = project_dataset(&fd, 16, 3);
        assert_eq!(p.input_dim, 16);
        assert_eq!(p.num_clients(), fd.num_clients());
        for (a, b) in p.clients.iter().zip(&fd.clients) {
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.x_dense.len(), a.len() * 16);
        }
    }

    #[test]
    fn strategies_rank_as_paper_predicts() {
        // full ≥ ocs > uniform in final train loss (averaged over seeds)
        let loss_for = |s: Strategy| -> f64 {
            let mut acc = 0.0;
            for seed in 0..3 {
                let mut cfg = quick_cfg(s.clone());
                cfg.seed = seed;
                cfg.rounds = 40;
                acc += run_sim(&cfg).unwrap().final_train_loss();
            }
            acc / 3.0
        };
        let full = loss_for(Strategy::Full);
        let ocs = loss_for(Strategy::Ocs);
        let uniform = loss_for(Strategy::Uniform);
        assert!(
            ocs < uniform,
            "optimal sampling must beat uniform: {ocs} vs {uniform}"
        );
        assert!(
            full <= ocs * 1.15,
            "full participation should be ≈ best: {full} vs {ocs}"
        );
    }
}
