//! Rust-native models with closed-form gradients for the sim path.
//!
//! The sim path runs the *same* FL orchestration as the XLA path but
//! swaps the per-client compute for exact-gradient rust models — fast
//! enough for 10⁴-round theory sweeps (Theorems 13/15/17/18) and for the
//! property tests. Two models:
//!
//! * [`logistic`] — multinomial logistic regression over [`crate::data`]
//!   features (convex, L-smooth: matches the convex theory sections);
//! * [`quadratic`] — per-client quadratics with controllable conditioning
//!   and heterogeneity (strongly convex; exact minimizer known, so the
//!   `E‖x^k − x*‖²` recursion of Theorem 13 is directly measurable).

pub mod logistic;
pub mod quadratic;

use crate::data::ClientData;

/// A model usable by the native FL engine.
pub trait NativeModel: Send + Sync {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;

    /// Mean loss and gradient over the given example indices of a client
    /// dataset. The gradient is written into `grad` (len = dim()).
    fn loss_grad(
        &self,
        params: &[f32],
        data: &ClientData,
        batch: &[usize],
        grad: &mut [f32],
    ) -> f64;

    /// [`NativeModel::loss_grad`] with a caller-owned workspace (the
    /// per-worker scratch arena — see `tensor::kernels::Scratch`).
    /// Models whose gradient needs intermediate buffers (batch logits)
    /// override this to run allocation-free; the default ignores the
    /// workspace.
    fn loss_grad_scratch(
        &self,
        params: &[f32],
        data: &ClientData,
        batch: &[usize],
        grad: &mut [f32],
        work: &mut Vec<f32>,
    ) -> f64 {
        let _ = work;
        self.loss_grad(params, data, batch, grad)
    }

    /// Mean loss over a full dataset (no gradient).
    fn loss(&self, params: &[f32], data: &ClientData) -> f64;

    /// Classification accuracy over a dataset (NaN if not a classifier).
    fn accuracy(&self, params: &[f32], data: &ClientData) -> f64;

    /// Deterministic parameter initialization.
    fn init_params(&self, seed: u64) -> Vec<f32>;
}

/// Numerical gradient check helper shared by model tests.
#[cfg(test)]
pub(crate) fn finite_diff_check(
    model: &dyn NativeModel,
    params: &[f32],
    data: &ClientData,
    batch: &[usize],
    tol: f64,
) {
    let d = model.dim();
    let mut grad = vec![0.0f32; d];
    model.loss_grad(params, data, batch, &mut grad);
    let eps = 5e-3f32;
    // spot-check a handful of coordinates
    let stride = (d / 7).max(1);
    for i in (0..d).step_by(stride) {
        let mut p = params.to_vec();
        p[i] += eps;
        let mut scratch = vec![0.0f32; d];
        let up = model.loss_grad(&p, data, batch, &mut scratch);
        p[i] -= 2.0 * eps;
        let down = model.loss_grad(&p, data, batch, &mut scratch);
        let fd = (up - down) / (2.0 * eps as f64);
        assert!(
            (fd - grad[i] as f64).abs() < tol * (1.0 + fd.abs()),
            "coord {i}: finite-diff {fd} vs analytic {}",
            grad[i]
        );
    }
}
