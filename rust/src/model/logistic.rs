//! Multinomial logistic regression with closed-form gradients.
//!
//! Parameters are `[W (dim × classes) row-major | b (classes)]` flattened.
//! Convex and L-smooth, matching the assumptions of Theorems 13/17; used
//! by the sim path for fast end-to-end federated runs.

use super::NativeModel;
use crate::data::ClientData;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Logistic {
    pub input_dim: usize,
    pub classes: usize,
    /// L2 regularization (λ/2‖θ‖²) — λ > 0 makes the objective strongly
    /// convex (Theorem 13's setting).
    pub l2: f64,
}

impl Logistic {
    pub fn new(input_dim: usize, classes: usize, l2: f64) -> Logistic {
        Logistic { input_dim, classes, l2 }
    }

    fn logits(&self, params: &[f32], x: &[f32], out: &mut [f32]) {
        let c = self.classes;
        let bias = &params[self.input_dim * c..];
        out.copy_from_slice(bias);
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let row = &params[j * c..(j + 1) * c];
            for (o, &w) in out.iter_mut().zip(row) {
                *o += xj * w;
            }
        }
    }

    /// log-softmax in place; returns logsumexp.
    fn log_softmax(logits: &mut [f32]) -> f32 {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = max
            + logits
                .iter()
                .map(|&z| (z - max).exp())
                .sum::<f32>()
                .ln();
        for z in logits.iter_mut() {
            *z -= lse;
        }
        lse
    }
}

impl NativeModel for Logistic {
    fn dim(&self) -> usize {
        (self.input_dim + 1) * self.classes
    }

    fn loss_grad(
        &self,
        params: &[f32],
        data: &ClientData,
        batch: &[usize],
        grad: &mut [f32],
    ) -> f64 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        assert!(!batch.is_empty());
        let c = self.classes;
        grad.fill(0.0);
        let mut logits = vec![0.0f32; c];
        let mut total = 0.0f64;
        for &i in batch {
            let x = data.dense_row(i);
            let y = data.labels[i] as usize;
            self.logits(params, x, &mut logits);
            Self::log_softmax(&mut logits);
            total += -logits[y] as f64;
            // dlogits = softmax - onehot
            for (j, z) in logits.iter().enumerate() {
                let d = z.exp() - (j == y) as u8 as f32;
                // bias grad
                grad[self.input_dim * c + j] += d;
                // weight grads (only non-zero features)
                for (k, &xk) in x.iter().enumerate() {
                    if xk != 0.0 {
                        grad[k * c + j] += d * xk;
                    }
                }
            }
        }
        let inv = 1.0 / batch.len() as f32;
        for (g, p) in grad.iter_mut().zip(params) {
            *g = *g * inv + self.l2 as f32 * p;
        }
        total / batch.len() as f64
            + 0.5 * self.l2 * params.iter().map(|&p| (p as f64) * p as f64).sum::<f64>()
    }

    fn loss(&self, params: &[f32], data: &ClientData) -> f64 {
        let c = self.classes;
        let mut logits = vec![0.0f32; c];
        let mut total = 0.0f64;
        for i in 0..data.len() {
            self.logits(params, data.dense_row(i), &mut logits);
            Self::log_softmax(&mut logits);
            total += -logits[data.labels[i] as usize] as f64;
        }
        total / data.len().max(1) as f64
            + 0.5 * self.l2 * params.iter().map(|&p| (p as f64) * p as f64).sum::<f64>()
    }

    fn accuracy(&self, params: &[f32], data: &ClientData) -> f64 {
        let c = self.classes;
        let mut logits = vec![0.0f32; c];
        let mut correct = 0usize;
        for i in 0..data.len() {
            self.logits(params, data.dense_row(i), &mut logits);
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += (pred == data.labels[i] as usize) as usize;
        }
        correct as f64 / data.len().max(1) as f64
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ 0x10615_71C);
        let scale = 1.0 / (self.input_dim as f32).sqrt();
        let mut p: Vec<f32> = (0..self.input_dim * self.classes)
            .map(|_| rng.normal_f32(0.0, scale))
            .collect();
        p.extend(std::iter::repeat(0.0f32).take(self.classes));
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::finite_diff_check;

    fn toy_data(n: usize, dim: usize, classes: usize, seed: u64) -> ClientData {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * dim);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let y = rng.range(0, classes) as u32;
            for j in 0..dim {
                // class-dependent mean => separable-ish
                let mu = if j % classes == y as usize { 1.0 } else { 0.0 };
                x.push(rng.normal_f32(mu, 0.5));
            }
            labels.push(y);
        }
        ClientData { x_dense: x, x_tokens: vec![], labels, dim }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let model = Logistic::new(6, 3, 0.01);
        let data = toy_data(12, 6, 3, 1);
        let params = model.init_params(2);
        let batch: Vec<usize> = (0..12).collect();
        finite_diff_check(&model, &params, &data, &batch, 2e-2);
    }

    #[test]
    fn sgd_reduces_loss_and_learns() {
        let model = Logistic::new(8, 4, 0.0);
        let data = toy_data(200, 8, 4, 3);
        let mut params = model.init_params(4);
        let mut grad = vec![0.0f32; model.dim()];
        let first = model.loss(&params, &data);
        let mut rng = Rng::new(5);
        for _ in 0..300 {
            let batch: Vec<usize> =
                (0..16).map(|_| rng.range(0, data.len())).collect();
            model.loss_grad(&params, &data, &batch, &mut grad);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 0.3 * g;
            }
        }
        let last = model.loss(&params, &data);
        assert!(last < first * 0.7, "{first} -> {last}");
        assert!(model.accuracy(&params, &data) > 0.5);
    }

    #[test]
    fn l2_pulls_loss_up_and_grad_toward_params() {
        let m0 = Logistic::new(4, 2, 0.0);
        let m1 = Logistic::new(4, 2, 1.0);
        let data = toy_data(8, 4, 2, 7);
        let params = vec![0.5f32; m0.dim()];
        assert!(m1.loss(&params, &data) > m0.loss(&params, &data));
        let batch: Vec<usize> = (0..8).collect();
        let mut g0 = vec![0.0f32; m0.dim()];
        let mut g1 = vec![0.0f32; m1.dim()];
        m0.loss_grad(&params, &data, &batch, &mut g0);
        m1.loss_grad(&params, &data, &batch, &mut g1);
        for (a, b) in g0.iter().zip(&g1) {
            assert!((b - a - 0.5).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn init_deterministic() {
        let m = Logistic::new(5, 3, 0.0);
        assert_eq!(m.init_params(9), m.init_params(9));
        assert_ne!(m.init_params(9), m.init_params(10));
    }
}
