//! Multinomial logistic regression with closed-form gradients.
//!
//! Parameters are `[W (dim × classes) row-major | b (classes)]` flattened.
//! Convex and L-smooth, matching the assumptions of Theorems 13/17; used
//! by the sim path for fast end-to-end federated runs.
//!
//! The gradient is computed batch-level on the `tensor::kernels` layer:
//! one gathered logits GEMM per batch plus a rank-1 outer-product
//! accumulation per sample, instead of the seed's per-sample row walks
//! (which wrote the weight gradient with stride `classes` — the worst
//! access pattern in the crate; EXPERIMENTS.md §Perf). The kernel path
//! is bit-identical to [`Logistic::loss_grad_scalar`], the retained
//! scalar reference: every gradient element accumulates its per-sample
//! contributions in the same order with the same fused ops.

use super::NativeModel;
use crate::data::ClientData;
use crate::tensor::kernels;
use crate::util::rng::Rng;

/// Rows per gathered-GEMM block on the (full-dataset) eval path.
const EVAL_BLOCK: usize = 128;

#[derive(Clone, Debug)]
pub struct Logistic {
    pub input_dim: usize,
    pub classes: usize,
    /// L2 regularization (λ/2‖θ‖²) — λ > 0 makes the objective strongly
    /// convex (Theorem 13's setting).
    pub l2: f64,
}

impl Logistic {
    pub fn new(input_dim: usize, classes: usize, l2: f64) -> Logistic {
        Logistic { input_dim, classes, l2 }
    }

    /// Per-sample scalar logits walk (reference path only).
    fn logits_scalar(&self, params: &[f32], x: &[f32], out: &mut [f32]) {
        let c = self.classes;
        let bias = &params[self.input_dim * c..];
        out.copy_from_slice(bias);
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let row = &params[j * c..(j + 1) * c];
            for (o, &w) in out.iter_mut().zip(row) {
                *o += xj * w;
            }
        }
    }

    /// log-softmax in place.
    fn log_softmax(logits: &mut [f32]) {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = max
            + logits
                .iter()
                .map(|&z| (z - max).exp())
                .sum::<f32>()
                .ln();
        for z in logits.iter_mut() {
            *z -= lse;
        }
    }

    /// λ/2‖θ‖² — the one L2-penalty summation shared by `loss`,
    /// `loss_grad_scratch` and `loss_grad_scalar` (sequential fold: part
    /// of the seed trajectory contract).
    fn l2_penalty(&self, params: &[f32]) -> f64 {
        0.5 * self.l2
            * params.iter().map(|&p| (p as f64) * p as f64).sum::<f64>()
    }

    /// The seed per-sample scalar gradient — retained as the correctness
    /// oracle for the kernel property tests and the baseline arm of
    /// `fedsamp bench kernels` / `benches/micro_kernels.rs`.
    pub fn loss_grad_scalar(
        &self,
        params: &[f32],
        data: &ClientData,
        batch: &[usize],
        grad: &mut [f32],
    ) -> f64 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        assert!(!batch.is_empty());
        let c = self.classes;
        grad.fill(0.0);
        let mut logits = vec![0.0f32; c];
        let mut total = 0.0f64;
        for &i in batch {
            let x = data.dense_row(i);
            let y = data.labels[i] as usize;
            self.logits_scalar(params, x, &mut logits);
            Self::log_softmax(&mut logits);
            total += -logits[y] as f64;
            // dlogits = softmax - onehot
            for (j, z) in logits.iter().enumerate() {
                let d = z.exp() - (j == y) as u8 as f32;
                // bias grad
                grad[self.input_dim * c + j] += d;
                // weight grads (only non-zero features)
                for (k, &xk) in x.iter().enumerate() {
                    if xk != 0.0 {
                        grad[k * c + j] += d * xk;
                    }
                }
            }
        }
        let inv = 1.0 / batch.len() as f32;
        for (g, p) in grad.iter_mut().zip(params) {
            *g = *g * inv + self.l2 as f32 * p;
        }
        total / batch.len() as f64 + self.l2_penalty(params)
    }
}

impl NativeModel for Logistic {
    fn dim(&self) -> usize {
        (self.input_dim + 1) * self.classes
    }

    fn loss_grad(
        &self,
        params: &[f32],
        data: &ClientData,
        batch: &[usize],
        grad: &mut [f32],
    ) -> f64 {
        let mut work = Vec::new();
        self.loss_grad_scratch(params, data, batch, grad, &mut work)
    }

    /// Batch-level kernel formulation: one gathered logits GEMM for the
    /// whole batch, then per-sample softmax + rank-1 gradient
    /// accumulation with contiguous inner loops. `work` holds the
    /// batch × classes logits block (no allocation once warm).
    fn loss_grad_scratch(
        &self,
        params: &[f32],
        data: &ClientData,
        batch: &[usize],
        grad: &mut [f32],
        work: &mut Vec<f32>,
    ) -> f64 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        assert!(!batch.is_empty());
        assert_eq!(data.dim, self.input_dim, "data/model dim mismatch");
        let c = self.classes;
        let d = self.input_dim;
        grad.fill(0.0);
        let (wm, bias) = params.split_at(d * c);
        kernels::Scratch::ensure(work, batch.len() * c);
        kernels::gemm_gather_block(
            &data.x_dense,
            batch,
            d,
            wm,
            c,
            Some(bias),
            work,
        );
        let (gw, gb) = grad.split_at_mut(d * c);
        let mut total = 0.0f64;
        for (bi, &i) in batch.iter().enumerate() {
            let y = data.labels[i] as usize;
            let row = &mut work[bi * c..(bi + 1) * c];
            Self::log_softmax(row);
            total += -row[y] as f64;
            // dlogits = softmax - onehot, in place
            for (j, z) in row.iter_mut().enumerate() {
                *z = z.exp() - (j == y) as u8 as f32;
            }
            kernels::add_assign(gb, row);
            kernels::rank1_accumulate(gw, data.dense_row(i), row);
        }
        let inv = 1.0 / batch.len() as f32;
        for (g, p) in grad.iter_mut().zip(params) {
            *g = *g * inv + self.l2 as f32 * p;
        }
        total / batch.len() as f64 + self.l2_penalty(params)
    }

    fn loss(&self, params: &[f32], data: &ClientData) -> f64 {
        let c = self.classes;
        let (wm, bias) = params.split_at(self.input_dim * c);
        let n = data.len();
        let mut total = 0.0f64;
        let mut logits: Vec<f32> = Vec::new();
        let mut rows: Vec<usize> = Vec::new();
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + EVAL_BLOCK).min(n);
            rows.clear();
            rows.extend(i0..i1);
            kernels::Scratch::ensure(&mut logits, (i1 - i0) * c);
            kernels::gemm_gather_block(
                &data.x_dense,
                &rows,
                self.input_dim,
                wm,
                c,
                Some(bias),
                &mut logits,
            );
            for (r, &y) in logits.chunks_exact_mut(c).zip(&data.labels[i0..i1])
            {
                Self::log_softmax(r);
                total += -r[y as usize] as f64;
            }
            i0 = i1;
        }
        total / n.max(1) as f64 + self.l2_penalty(params)
    }

    fn accuracy(&self, params: &[f32], data: &ClientData) -> f64 {
        let c = self.classes;
        let (wm, bias) = params.split_at(self.input_dim * c);
        let n = data.len();
        let mut correct = 0usize;
        let mut logits: Vec<f32> = Vec::new();
        let mut rows: Vec<usize> = Vec::new();
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + EVAL_BLOCK).min(n);
            rows.clear();
            rows.extend(i0..i1);
            kernels::Scratch::ensure(&mut logits, (i1 - i0) * c);
            kernels::gemm_gather_block(
                &data.x_dense,
                &rows,
                self.input_dim,
                wm,
                c,
                Some(bias),
                &mut logits,
            );
            for (r, &y) in logits.chunks_exact(c).zip(&data.labels[i0..i1]) {
                let pred = r
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += (pred == y as usize) as usize;
            }
            i0 = i1;
        }
        correct as f64 / n.max(1) as f64
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ 0x10615_71C);
        let scale = 1.0 / (self.input_dim as f32).sqrt();
        let mut p: Vec<f32> = (0..self.input_dim * self.classes)
            .map(|_| rng.normal_f32(0.0, scale))
            .collect();
        p.extend(std::iter::repeat(0.0f32).take(self.classes));
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::finite_diff_check;
    use crate::util::prop::quick;

    fn toy_data(n: usize, dim: usize, classes: usize, seed: u64) -> ClientData {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * dim);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let y = rng.range(0, classes) as u32;
            for j in 0..dim {
                // class-dependent mean => separable-ish
                let mu = if j % classes == y as usize { 1.0 } else { 0.0 };
                x.push(rng.normal_f32(mu, 0.5));
            }
            labels.push(y);
        }
        ClientData { x_dense: x, x_tokens: vec![], labels, dim }
    }

    /// toy_data with a fraction of exact-zero features, to exercise the
    /// sparse-skip path of the kernels.
    fn sparse_toy_data(
        n: usize,
        dim: usize,
        classes: usize,
        seed: u64,
    ) -> ClientData {
        let mut d = toy_data(n, dim, classes, seed);
        let mut rng = Rng::new(seed ^ 0xD0);
        for v in d.x_dense.iter_mut() {
            if rng.bernoulli(0.4) {
                *v = 0.0;
            }
        }
        d
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let model = Logistic::new(6, 3, 0.01);
        let data = toy_data(12, 6, 3, 1);
        let params = model.init_params(2);
        let batch: Vec<usize> = (0..12).collect();
        finite_diff_check(&model, &params, &data, &batch, 2e-2);
    }

    #[test]
    fn prop_kernel_grad_matches_scalar_reference() {
        quick("logistic-kernel-vs-scalar", |rng, case| {
            let classes = rng.range(2, 8);
            let dim = rng.range(1, 90);
            let n = rng.range(2, 20);
            let model = Logistic::new(dim, classes, 0.01);
            let data = if case % 2 == 0 {
                toy_data(n, dim, classes, case as u64)
            } else {
                sparse_toy_data(n, dim, classes, case as u64)
            };
            let params = model.init_params(case as u64 ^ 0xA1);
            let batch: Vec<usize> =
                (0..rng.range(1, n + 1)).map(|_| rng.range(0, n)).collect();
            let mut gk = vec![0.0f32; model.dim()];
            let mut gs = vec![0.0f32; model.dim()];
            let lk = model.loss_grad(&params, &data, &batch, &mut gk);
            let ls = model.loss_grad_scalar(&params, &data, &batch, &mut gs);
            if (lk - ls).abs() > 1e-6 * (1.0 + ls.abs()) {
                return Err(format!("loss {lk} vs {ls}"));
            }
            for (i, (a, b)) in gk.iter().zip(&gs).enumerate() {
                let (a, b) = (*a as f64, *b as f64);
                if (a - b).abs() > 1e-6 * (1.0 + b.abs()) {
                    return Err(format!("grad[{i}]: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kernel_grad_is_bit_identical_to_scalar_on_sparse_rows() {
        // the stronger contract the trajectory-exactness tests rely on
        let model = Logistic::new(40, 5, 1e-3);
        let data = sparse_toy_data(30, 40, 5, 77);
        let params = model.init_params(8);
        let batch: Vec<usize> = (0..30).collect();
        let mut gk = vec![0.0f32; model.dim()];
        let mut gs = vec![0.0f32; model.dim()];
        let lk = model.loss_grad(&params, &data, &batch, &mut gk);
        let ls = model.loss_grad_scalar(&params, &data, &batch, &mut gs);
        assert_eq!(lk, ls);
        assert_eq!(gk, gs);
    }

    #[test]
    fn scratch_reuse_changes_nothing() {
        let model = Logistic::new(12, 4, 0.01);
        let data = toy_data(20, 12, 4, 3);
        let params = model.init_params(4);
        let mut work = Vec::new();
        let mut g1 = vec![0.0f32; model.dim()];
        let mut g2 = vec![0.0f32; model.dim()];
        // a big batch first warms the scratch past the small batch's need
        let big: Vec<usize> = (0..20).collect();
        model.loss_grad_scratch(&params, &data, &big, &mut g1, &mut work);
        let small: Vec<usize> = vec![3, 7];
        let with_warm =
            model.loss_grad_scratch(&params, &data, &small, &mut g1, &mut work);
        let fresh = model.loss_grad(&params, &data, &small, &mut g2);
        assert_eq!(with_warm, fresh);
        assert_eq!(g1, g2);
    }

    #[test]
    fn sgd_reduces_loss_and_learns() {
        let model = Logistic::new(8, 4, 0.0);
        let data = toy_data(200, 8, 4, 3);
        let mut params = model.init_params(4);
        let mut grad = vec![0.0f32; model.dim()];
        let first = model.loss(&params, &data);
        let mut rng = Rng::new(5);
        for _ in 0..300 {
            let batch: Vec<usize> =
                (0..16).map(|_| rng.range(0, data.len())).collect();
            model.loss_grad(&params, &data, &batch, &mut grad);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 0.3 * g;
            }
        }
        let last = model.loss(&params, &data);
        assert!(last < first * 0.7, "{first} -> {last}");
        assert!(model.accuracy(&params, &data) > 0.5);
    }

    #[test]
    fn l2_pulls_loss_up_and_grad_toward_params() {
        let m0 = Logistic::new(4, 2, 0.0);
        let m1 = Logistic::new(4, 2, 1.0);
        let data = toy_data(8, 4, 2, 7);
        let params = vec![0.5f32; m0.dim()];
        assert!(m1.loss(&params, &data) > m0.loss(&params, &data));
        let batch: Vec<usize> = (0..8).collect();
        let mut g0 = vec![0.0f32; m0.dim()];
        let mut g1 = vec![0.0f32; m1.dim()];
        m0.loss_grad(&params, &data, &batch, &mut g0);
        m1.loss_grad(&params, &data, &batch, &mut g1);
        for (a, b) in g0.iter().zip(&g1) {
            assert!((b - a - 0.5).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn eval_blocks_cover_ragged_tails() {
        // dataset bigger than one EVAL_BLOCK with a partial final block
        let model = Logistic::new(4, 3, 0.0);
        let data = toy_data(EVAL_BLOCK + 37, 4, 3, 9);
        let params = model.init_params(1);
        let loss = model.loss(&params, &data);
        assert!(loss.is_finite());
        // blocked eval must agree with a per-sample scalar walk
        let mut logits = vec![0.0f32; 3];
        let mut total = 0.0f64;
        for i in 0..data.len() {
            model.logits_scalar(&params, data.dense_row(i), &mut logits);
            Logistic::log_softmax(&mut logits);
            total += -logits[data.labels[i] as usize] as f64;
        }
        let want = total / data.len() as f64 + model.l2_penalty(&params);
        assert_eq!(loss, want);
        let acc = model.accuracy(&params, &data);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn init_deterministic() {
        let m = Logistic::new(5, 3, 0.0);
        assert_eq!(m.init_params(9), m.init_params(9));
        assert_ne!(m.init_params(9), m.init_params(10));
    }
}
