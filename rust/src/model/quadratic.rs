//! Per-client quadratic objectives with a known global minimizer.
//!
//! `f_i(x) = ½ (x − c_i)ᵀ A_i (x − c_i)` with diagonal PSD `A_i`.
//! `f = Σ w_i f_i` is µ-strongly convex and L-smooth with
//! `µ = min_j Σ_i w_i a_{ij}`, `L = max_j Σ_i w_i a_{ij}`, and the global
//! minimizer solves the weighted normal equations coordinate-wise —
//! so Theorem 13's `E‖x^k − x*‖²` recursion is directly measurable.
//!
//! Client heterogeneity (how far apart the `c_i` sit, how skewed the
//! curvatures are) controls the update-norm spread and therefore α^k.

use crate::tensor;
use crate::tensor::kernels;
use crate::util::rng::Rng;

/// One client's quadratic.
#[derive(Clone, Debug)]
pub struct ClientQuadratic {
    /// diagonal of A_i (all entries > 0)
    pub curvature: Vec<f32>,
    /// minimizer c_i of the local objective
    pub center: Vec<f32>,
}

impl ClientQuadratic {
    pub fn loss(&self, x: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for ((&a, &c), &xi) in
            self.curvature.iter().zip(&self.center).zip(x)
        {
            let d = (xi - c) as f64;
            acc += 0.5 * a as f64 * d * d;
        }
        acc
    }

    /// ∇f_i(x) = A_i (x − c_i), written into `grad` (fused diagonal
    /// kernel; elementwise-identical to the seed loop).
    pub fn grad(&self, x: &[f32], grad: &mut [f32]) {
        kernels::scaled_diff(grad, &self.curvature, x, &self.center);
    }
}

/// The federated quadratic problem: n clients + weights.
#[derive(Clone, Debug)]
pub struct QuadraticProblem {
    pub clients: Vec<ClientQuadratic>,
    pub weights: Vec<f64>,
    pub dim: usize,
}

impl QuadraticProblem {
    /// Build a heterogeneous problem.
    ///
    /// * `spread` — scale of the distance between client centers
    ///   (larger ⇒ more heterogeneous gradients ⇒ smaller α^k);
    /// * `cond` — curvature range [1, cond] (condition number knob);
    /// * `weights` — client weights (normalized internally).
    pub fn generate(
        n: usize,
        dim: usize,
        spread: f64,
        cond: f64,
        weights: Option<Vec<f64>>,
        seed: u64,
    ) -> QuadraticProblem {
        Self::generate_skewed(n, dim, spread, 1.0, cond, weights, seed)
    }

    /// [`QuadraticProblem::generate`] with an explicit heterogeneity knob.
    ///
    /// Per-client center scales are log-normal `spread·exp(skew·g_i)`:
    /// `skew = 0` makes all client objectives equally far from the origin
    /// (similar update norms ⇒ α^k → 1, OCS ≈ uniform), large `skew`
    /// concentrates the gradient mass on a few clients (α^k → 0, OCS ≈
    /// full participation). Note α^k is invariant to `spread` itself —
    /// it only sets the absolute scale.
    pub fn generate_skewed(
        n: usize,
        dim: usize,
        spread: f64,
        skew: f64,
        cond: f64,
        weights: Option<Vec<f64>>,
        seed: u64,
    ) -> QuadraticProblem {
        assert!(n > 0 && dim > 0 && cond >= 1.0);
        let root = Rng::new(seed ^ 0x0112_AD);
        let clients = (0..n)
            .map(|i| {
                let mut rng = root.fork(i as u64);
                // log-normal center scale: heterogeneity ∝ skew
                let scale = spread * (skew * rng.gaussian()).exp();
                ClientQuadratic {
                    curvature: (0..dim)
                        .map(|_| (1.0 + rng.f64() * (cond - 1.0)) as f32)
                        .collect(),
                    center: (0..dim)
                        .map(|_| rng.normal_f32(0.0, scale as f32))
                        .collect(),
                }
            })
            .collect();
        let mut w = weights.unwrap_or_else(|| vec![1.0; n]);
        let total: f64 = w.iter().sum();
        for wi in &mut w {
            *wi /= total;
        }
        QuadraticProblem { clients, weights: w, dim }
    }

    /// Global objective f(x) = Σ w_i f_i(x).
    pub fn loss(&self, x: &[f32]) -> f64 {
        self.clients
            .iter()
            .zip(&self.weights)
            .map(|(c, &w)| w * c.loss(x))
            .sum()
    }

    /// Exact global minimizer: x*_j = Σ_i w_i a_ij c_ij / Σ_i w_i a_ij.
    pub fn minimizer(&self) -> Vec<f32> {
        let mut num = vec![0.0f64; self.dim];
        let mut den = vec![0.0f64; self.dim];
        for (c, &w) in self.clients.iter().zip(&self.weights) {
            for j in 0..self.dim {
                num[j] += w * c.curvature[j] as f64 * c.center[j] as f64;
                den[j] += w * c.curvature[j] as f64;
            }
        }
        num.iter().zip(&den).map(|(n, d)| (n / d) as f32).collect()
    }

    /// Smoothness constant L of f (max aggregated curvature).
    pub fn smoothness(&self) -> f64 {
        (0..self.dim)
            .map(|j| {
                self.clients
                    .iter()
                    .zip(&self.weights)
                    .map(|(c, &w)| w * c.curvature[j] as f64)
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }

    /// Strong-convexity constant µ of f (min aggregated curvature).
    pub fn strong_convexity(&self) -> f64 {
        (0..self.dim)
            .map(|j| {
                self.clients
                    .iter()
                    .zip(&self.weights)
                    .map(|(c, &w)| w * c.curvature[j] as f64)
                    .sum::<f64>()
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Squared distance to the optimum (the Theorem-13 Lyapunov value).
    pub fn dist_to_opt_sq(&self, x: &[f32]) -> f64 {
        tensor::dist_sq(x, &self.minimizer())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problem() -> QuadraticProblem {
        QuadraticProblem::generate(8, 16, 2.0, 10.0, None, 5)
    }

    #[test]
    fn minimizer_has_zero_gradient() {
        let p = problem();
        let xstar = p.minimizer();
        let mut agg = vec![0.0f64; p.dim];
        let mut g = vec![0.0f32; p.dim];
        for (c, &w) in p.clients.iter().zip(&p.weights) {
            c.grad(&xstar, &mut g);
            for (a, &gi) in agg.iter_mut().zip(&g) {
                *a += w * gi as f64;
            }
        }
        for a in agg {
            assert!(a.abs() < 1e-4, "∇f(x*) component {a}");
        }
    }

    #[test]
    fn minimizer_is_a_minimum() {
        let p = problem();
        let xstar = p.minimizer();
        let fstar = p.loss(&xstar);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let perturbed: Vec<f32> = xstar
                .iter()
                .map(|&x| x + rng.normal_f32(0.0, 0.5))
                .collect();
            assert!(p.loss(&perturbed) >= fstar - 1e-9);
        }
    }

    #[test]
    fn constants_ordering() {
        let p = problem();
        assert!(p.strong_convexity() > 0.0);
        assert!(p.smoothness() >= p.strong_convexity());
    }

    #[test]
    fn gradient_descent_converges_linearly() {
        let p = problem();
        let mut x = vec![0.0f32; p.dim];
        let eta = 1.0 / p.smoothness();
        let mut g = vec![0.0f32; p.dim];
        let mut agg = vec![0.0f32; p.dim];
        let d0 = p.dist_to_opt_sq(&x);
        for _ in 0..200 {
            agg.fill(0.0);
            for (c, &w) in p.clients.iter().zip(&p.weights) {
                c.grad(&x, &mut g);
                tensor::axpy(&mut agg, w as f32, &g);
            }
            tensor::axpy(&mut x, -(eta as f32), &agg);
        }
        assert!(p.dist_to_opt_sq(&x) < d0 * 1e-4);
    }

    #[test]
    fn weights_normalized() {
        let p = QuadraticProblem::generate(4, 3, 1.0, 2.0,
            Some(vec![1.0, 2.0, 3.0, 4.0]), 7);
        assert!((p.weights.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((p.weights[3] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn spread_controls_heterogeneity() {
        let tight = QuadraticProblem::generate(16, 8, 0.1, 2.0, None, 9);
        let wide = QuadraticProblem::generate(16, 8, 10.0, 2.0, None, 9);
        let x = vec![0.0f32; 8];
        let grad_norms = |p: &QuadraticProblem| -> f64 {
            let mut g = vec![0.0f32; p.dim];
            let norms: Vec<f64> = p
                .clients
                .iter()
                .map(|c| {
                    c.grad(&x, &mut g);
                    tensor::norm(&g)
                })
                .collect();
            let m = norms.iter().sum::<f64>() / norms.len() as f64;
            norms.iter().map(|n| (n - m) * (n - m)).sum::<f64>().sqrt()
        };
        assert!(grad_norms(&wide) > grad_norms(&tight) * 5.0);
    }
}
