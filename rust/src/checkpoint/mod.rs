//! Durable coordinator checkpoints: crash-safe snapshots of the full
//! master-side state, restored byte-for-byte so a killed run resumes
//! onto the *identical* trajectory.
//!
//! The subsystem carries the same contract every other layer pins:
//!
//! * **Byte-exact framing.** A [`Snapshot`] serializes through the
//!   `wire`-style little-endian codec (versioned magic header, length
//!   framing, typed [`CheckpointError`] on every way a damaged file can
//!   lie, a trailing FNV-1a checksum over the whole frame). Floats are
//!   stored as raw IEEE-754 bits (`to_bits`/`from_bits`), so NaN
//!   accuracies and last-ulp loss values survive the round trip
//!   untouched.
//! * **Crash-safe writes.** [`Snapshot::write_atomic`] writes to
//!   `<path>.tmp`, fsyncs, then atomically renames over `<path>`: a kill
//!   mid-write can never leave a truncated snapshot at the real path.
//! * **What is snapshotted is only what round index cannot derive.**
//!   The round RNG is forked fresh from the experiment seed each round
//!   (`Rng::fork` is pure), the registry is stateless arithmetic, and
//!   every fault/availability draw is a pure function of
//!   `(client, round)` — so the checkpoint stores the *round index*, not
//!   RNG stream positions, alongside the genuinely mutable state: model
//!   vector, uplink meter, metrics history, coordinator/fault counters,
//!   the AOCS last-good probability cache, and telemetry run totals.
//! * **Config fingerprinting.** A snapshot binds to the canonical JSON
//!   of its [`ExperimentConfig`] via [`config_fingerprint`]; resuming
//!   under a different config is a typed
//!   [`CheckpointError::ConfigMismatch`], not a silently divergent run.
//!
//! The same codec underlies the sweep's per-arm completion ledger
//! ([`SweepLedger`]): one entry per finished `(arm, seed)` unit, so an
//! interrupted grid resumes at the first unfinished unit and emits
//! byte-identical `BENCH_sweep.json`/`.csv` (see `exp::sweep`).
//!
//! ```
//! use fedsamp::checkpoint::{Snapshot, config_fingerprint};
//! use fedsamp::config::presets;
//! let cfg = presets::femnist(1, 3);
//! let snap = Snapshot::empty(config_fingerprint(&cfg), 0);
//! let bytes = snap.to_bytes();
//! let back = Snapshot::from_bytes(&bytes).unwrap();
//! assert_eq!(back.to_bytes(), bytes); // byte-exact round trip
//! ```

use std::io::Write as _;

use crate::config::ExperimentConfig;
use crate::coordinator::CoordStats;
use crate::faults::FaultCounters;
use crate::metrics::RoundRecord;

/// Snapshot file magic ("FSNP": fedsamp snapshot).
const SNAP_MAGIC: [u8; 4] = *b"FSNP";
/// Sweep-ledger file magic ("FSLG": fedsamp sweep ledger).
const LEDGER_MAGIC: [u8; 4] = *b"FSLG";
/// Current snapshot/ledger format version.
pub const FORMAT_VERSION: u32 = 1;

/// FNV-1a 64-bit — the checksum and fingerprint hash. In-tree (no deps),
/// deterministic across platforms, and a single flipped byte always
/// changes the digest (the per-byte XOR→multiply step is injective in
/// the running state).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

/// Fingerprint of an experiment config: FNV-1a over its canonical JSON
/// rendering. Any field that can steer the trajectory (seed, rounds,
/// strategy, data, compressor, fault plan, …) is part of the canonical
/// form, so two configs fingerprint equal iff a run under either is the
/// same run.
pub fn config_fingerprint(cfg: &ExperimentConfig) -> u64 {
    fnv1a64(cfg.to_json().to_pretty().as_bytes())
}

/// Typed failure decoding or loading a snapshot/ledger — the checkpoint
/// analogue of `wire::DecodeError`: every way a damaged or mismatched
/// file can lie is a variant, not a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckpointError {
    /// File ended before the field at byte `at` (needed `need` more).
    Truncated { at: usize, need: usize },
    /// Bytes left over after a complete frame.
    TrailingBytes(usize),
    /// Leading magic is not a fedsamp snapshot/ledger.
    BadMagic([u8; 4]),
    /// Format version this build does not understand.
    UnsupportedVersion(u32),
    /// Trailing checksum does not match the frame contents.
    ChecksumMismatch { got: u64, want: u64 },
    /// Snapshot was taken under a different experiment config.
    ConfigMismatch { got: u64, want: u64 },
    /// Snapshot model dimension disagrees with the runner's.
    DimMismatch { got: usize, want: usize },
    /// Ledger belongs to a different sweep spec.
    SpecMismatch { got: u64, want: u64 },
    /// Filesystem failure reading or writing `path`.
    Io { path: String, message: String },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Truncated { at, need } => write!(
                f,
                "truncated checkpoint at byte {at} (need {need} more)"
            ),
            CheckpointError::TrailingBytes(n) => {
                write!(f, "{n} trailing bytes after checkpoint frame")
            }
            CheckpointError::BadMagic(m) => {
                write!(f, "not a fedsamp checkpoint (magic {m:02x?})")
            }
            CheckpointError::UnsupportedVersion(v) => write!(
                f,
                "unsupported checkpoint format version {v} \
                 (this build reads {FORMAT_VERSION})"
            ),
            CheckpointError::ChecksumMismatch { got, want } => write!(
                f,
                "checkpoint checksum mismatch (got {got:#018x}, \
                 want {want:#018x}) — file is corrupt"
            ),
            CheckpointError::ConfigMismatch { got, want } => write!(
                f,
                "checkpoint was taken under a different experiment config \
                 (snapshot fingerprint {got:#018x}, current {want:#018x}); \
                 resume with the exact flags of the original run"
            ),
            CheckpointError::DimMismatch { got, want } => write!(
                f,
                "checkpoint model dimension {got} does not match the \
                 runner dimension {want}"
            ),
            CheckpointError::SpecMismatch { got, want } => write!(
                f,
                "sweep ledger belongs to a different sweep spec \
                 (ledger fingerprint {got:#018x}, current {want:#018x}); \
                 rerun with the original grid flags or delete the ledger"
            ),
            CheckpointError::Io { path, message } => {
                write!(f, "checkpoint I/O on {path}: {message}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<CheckpointError> for String {
    fn from(e: CheckpointError) -> String {
        e.to_string()
    }
}

/// Typed CLI-surface parse failure for the checkpoint flags — carries
/// the offending token so `--checkpoint-every banana` names the culprit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckpointSpecError {
    /// `--checkpoint-every` is not a positive integer.
    BadEvery { token: String },
    /// `--resume` was given an empty path.
    EmptyResumePath,
}

impl std::fmt::Display for CheckpointSpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointSpecError::BadEvery { token } => write!(
                f,
                "bad --checkpoint-every '{token}' (want a round count, \
                 e.g. --checkpoint-every 10; 0 disables)"
            ),
            CheckpointSpecError::EmptyResumePath => {
                write!(f, "--resume needs a snapshot path")
            }
        }
    }
}

impl std::error::Error for CheckpointSpecError {}

impl From<CheckpointSpecError> for String {
    fn from(e: CheckpointSpecError) -> String {
        e.to_string()
    }
}

/// Parse the `--checkpoint-every` token: a non-negative round count
/// (`0` = checkpointing disabled).
pub fn parse_checkpoint_every(token: &str) -> Result<usize, CheckpointSpecError> {
    token
        .trim()
        .parse::<usize>()
        .map_err(|_| CheckpointSpecError::BadEvery { token: token.to_string() })
}

/// Parse the `--resume` token: any non-empty path.
pub fn parse_resume_path(token: &str) -> Result<String, CheckpointSpecError> {
    let t = token.trim();
    if t.is_empty() {
        return Err(CheckpointSpecError::EmptyResumePath);
    }
    Ok(t.to_string())
}

/// Checkpoint knobs threaded through `TrainOptions` into the
/// coordinator. Default = fully disabled (bitwise inert: the round loop
/// takes no checkpoint branch, reads no clock, writes no file).
#[derive(Clone, Debug, Default)]
pub struct CheckpointOptions {
    /// Snapshot cadence in rounds (`0` = never checkpoint).
    pub every: usize,
    /// Snapshot path; required when `every > 0`.
    pub out: Option<String>,
    /// Restore from this snapshot before round 0 (and disarm a
    /// `masterkill` fault — the kill already happened).
    pub resume: Option<String>,
}

impl CheckpointOptions {
    /// Enabled cadence + path, validated: `every > 0` without a path is
    /// a config error the CLI surfaces before the run starts.
    pub fn validate(&self) -> Result<(), String> {
        if self.every > 0 && self.out.is_none() {
            return Err(
                "--checkpoint-every needs --checkpoint-out <path>".into()
            );
        }
        Ok(())
    }
}

/// Chaos-layer state carried across a resume: the running fault/repair
/// tally plus the AOCS last-good probability cache (serialized sorted by
/// client id so encoding is deterministic).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultState {
    pub counters: FaultCounters,
    /// `(client id, last negotiated inclusion probability)`, ascending
    /// by client id.
    pub last_probs: Vec<(u64, f64)>,
}

/// One coordinator snapshot: everything the round loop mutates across
/// rounds. See the module docs for why RNG stream positions and the
/// registry cursor are *not* here (both derive from the round index).
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    /// [`config_fingerprint`] of the experiment this state belongs to.
    pub config_fingerprint: u64,
    /// First round the resumed loop should execute.
    pub next_round: u64,
    /// Global model vector, bit-exact f32s.
    pub x: Vec<f32>,
    /// Cumulative uplink bytes (`fl::comm::BitMeter`).
    pub meter_bytes: u64,
    /// Per-round metrics history (`metrics::RunResult::rounds`),
    /// f64 fields bit-exact.
    pub records: Vec<RoundRecord>,
    /// Coordinator observability counters, fault tally included.
    pub stats: CoordStats,
    /// Chaos context state (`None` when the run carries no live plan).
    pub fault: Option<FaultState>,
    /// Telemetry run-total counters (empty when telemetry is off).
    pub tel_counters: Vec<u64>,
    /// Telemetry rounds flushed so far.
    pub tel_rounds: u64,
}

impl Snapshot {
    /// A round-zero snapshot with no history (doc tests, codec tests).
    pub fn empty(config_fingerprint: u64, next_round: u64) -> Snapshot {
        Snapshot { config_fingerprint, next_round, ..Snapshot::default() }
    }

    /// Encode the full frame: magic + version + body + FNV-1a checksum
    /// of everything preceding it.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + 4 * self.x.len() + 80 * self.records.len());
        out.extend_from_slice(&SNAP_MAGIC);
        put_u32(&mut out, FORMAT_VERSION);
        put_u64(&mut out, self.config_fingerprint);
        put_u64(&mut out, self.next_round);
        put_u32(&mut out, self.x.len() as u32);
        for &v in &self.x {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        put_u64(&mut out, self.meter_bytes);
        put_u32(&mut out, self.records.len() as u32);
        for r in &self.records {
            put_record(&mut out, r);
        }
        put_stats(&mut out, &self.stats);
        match &self.fault {
            None => out.push(0),
            Some(fs) => {
                out.push(1);
                put_fault_counters(&mut out, &fs.counters);
                put_u32(&mut out, fs.last_probs.len() as u32);
                for &(client, p) in &fs.last_probs {
                    put_u64(&mut out, client);
                    put_u64(&mut out, p.to_bits());
                }
            }
        }
        put_u32(&mut out, self.tel_counters.len() as u32);
        for &c in &self.tel_counters {
            put_u64(&mut out, c);
        }
        put_u64(&mut out, self.tel_rounds);
        let sum = fnv1a64(&out);
        put_u64(&mut out, sum);
        out
    }

    /// Decode one frame; the input must be exactly one snapshot
    /// (truncation, trailing bytes, bad magic/version and checksum
    /// mismatches are all typed errors, mirroring `wire::Payload::decode`).
    pub fn from_bytes(bytes: &[u8]) -> Result<Snapshot, CheckpointError> {
        let body_len = check_frame(bytes, &SNAP_MAGIC)?;
        let mut r = Reader { b: &bytes[..body_len], i: 8 };
        let config_fingerprint = r.u64()?;
        let next_round = r.u64()?;
        let n = r.u32()? as usize;
        // bounded preallocation: a corrupt length prefix yields the
        // truncation error, not an attempted multi-GiB allocation
        let mut x = Vec::with_capacity(n.min(r.remaining() / 4));
        for _ in 0..n {
            x.push(f32::from_bits(r.u32()?));
        }
        let meter_bytes = r.u64()?;
        let n = r.u32()? as usize;
        let mut records = Vec::with_capacity(n.min(r.remaining() / 72));
        for _ in 0..n {
            records.push(get_record(&mut r)?);
        }
        let stats = get_stats(&mut r)?;
        let fault = match r.u8()? {
            0 => None,
            _ => {
                let counters = get_fault_counters(&mut r)?;
                let k = r.u32()? as usize;
                let mut last_probs = Vec::with_capacity(k.min(r.remaining() / 16));
                for _ in 0..k {
                    let client = r.u64()?;
                    let p = f64::from_bits(r.u64()?);
                    last_probs.push((client, p));
                }
                Some(FaultState { counters, last_probs })
            }
        };
        let k = r.u32()? as usize;
        let mut tel_counters = Vec::with_capacity(k.min(r.remaining() / 8));
        for _ in 0..k {
            tel_counters.push(r.u64()?);
        }
        let tel_rounds = r.u64()?;
        if r.i != body_len {
            return Err(CheckpointError::TrailingBytes(body_len - r.i));
        }
        Ok(Snapshot {
            config_fingerprint,
            next_round,
            x,
            meter_bytes,
            records,
            stats,
            fault,
            tel_counters,
            tel_rounds,
        })
    }

    /// Crash-safe write: encode, write to `<path>.tmp`, fsync, rename
    /// over `path`. Returns the snapshot's encoded size in bytes.
    pub fn write_atomic(&self, path: &str) -> Result<usize, CheckpointError> {
        let bytes = self.to_bytes();
        write_atomic(path, &bytes)?;
        Ok(bytes.len())
    }

    /// Load and decode a snapshot file.
    pub fn load(path: &str) -> Result<Snapshot, CheckpointError> {
        let bytes = std::fs::read(path).map_err(|e| CheckpointError::Io {
            path: path.to_string(),
            message: e.to_string(),
        })?;
        Snapshot::from_bytes(&bytes)
    }
}

/// Write `bytes` to `<path>.tmp`, fsync, and atomically rename over
/// `path` — the shared crash-write sequence for snapshots, ledgers and
/// the BENCH/run artifacts (DESIGN.md §11).
pub fn write_atomic(path: &str, bytes: &[u8]) -> Result<(), CheckpointError> {
    let io = |e: std::io::Error| CheckpointError::Io {
        path: path.to_string(),
        message: e.to_string(),
    };
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(io)?;
        }
    }
    let tmp = format!("{path}.tmp");
    let mut f = std::fs::File::create(&tmp).map_err(io)?;
    f.write_all(bytes).map_err(io)?;
    f.sync_all().map_err(io)?;
    drop(f);
    std::fs::rename(&tmp, path).map_err(io)?;
    Ok(())
}

/// One finished `(arm, seed)` unit of a sweep grid: the per-round
/// metrics history plus the coordinator stats the arm summary needs.
#[derive(Clone, Debug)]
pub struct LedgerEntry {
    /// Fingerprint of the arm's experiment config (seed-independent).
    pub arm_fingerprint: u64,
    /// The unit's seed offset (`base_seed + seed` ran this unit).
    pub seed: u64,
    pub records: Vec<RoundRecord>,
    pub stats: CoordStats,
}

/// The sweep's per-arm completion ledger: which `(arm, seed)` units of a
/// grid already ran, with enough bit-exact state to rebuild their arm
/// summaries without re-running them. Written atomically after every
/// completed unit, so an interrupted `fedsamp sweep --ledger` resumes at
/// the first unfinished unit and emits byte-identical BENCH_sweep
/// artifacts.
#[derive(Clone, Debug, Default)]
pub struct SweepLedger {
    /// Fingerprint of the sweep spec the ledger belongs to.
    pub spec_fingerprint: u64,
    pub entries: Vec<LedgerEntry>,
}

impl SweepLedger {
    pub fn new(spec_fingerprint: u64) -> SweepLedger {
        SweepLedger { spec_fingerprint, entries: Vec::new() }
    }

    /// Find a finished unit.
    pub fn entry(&self, arm_fingerprint: u64, seed: u64) -> Option<&LedgerEntry> {
        self.entries
            .iter()
            .find(|e| e.arm_fingerprint == arm_fingerprint && e.seed == seed)
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&LEDGER_MAGIC);
        put_u32(&mut out, FORMAT_VERSION);
        put_u64(&mut out, self.spec_fingerprint);
        put_u32(&mut out, self.entries.len() as u32);
        for e in &self.entries {
            put_u64(&mut out, e.arm_fingerprint);
            put_u64(&mut out, e.seed);
            put_u32(&mut out, e.records.len() as u32);
            for r in &e.records {
                put_record(&mut out, r);
            }
            put_stats(&mut out, &e.stats);
        }
        let sum = fnv1a64(&out);
        put_u64(&mut out, sum);
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<SweepLedger, CheckpointError> {
        let body_len = check_frame(bytes, &LEDGER_MAGIC)?;
        let mut r = Reader { b: &bytes[..body_len], i: 8 };
        let spec_fingerprint = r.u64()?;
        let n = r.u32()? as usize;
        let mut entries = Vec::with_capacity(n.min(r.remaining() / 24));
        for _ in 0..n {
            let arm_fingerprint = r.u64()?;
            let seed = r.u64()?;
            let k = r.u32()? as usize;
            let mut records = Vec::with_capacity(k.min(r.remaining() / 72));
            for _ in 0..k {
                records.push(get_record(&mut r)?);
            }
            let stats = get_stats(&mut r)?;
            entries.push(LedgerEntry { arm_fingerprint, seed, records, stats });
        }
        if r.i != body_len {
            return Err(CheckpointError::TrailingBytes(body_len - r.i));
        }
        Ok(SweepLedger { spec_fingerprint, entries })
    }

    pub fn write_atomic(&self, path: &str) -> Result<(), CheckpointError> {
        write_atomic(path, &self.to_bytes())
    }

    pub fn load(path: &str) -> Result<SweepLedger, CheckpointError> {
        let bytes = std::fs::read(path).map_err(|e| CheckpointError::Io {
            path: path.to_string(),
            message: e.to_string(),
        })?;
        SweepLedger::from_bytes(&bytes)
    }
}

// ---------------------------------------------------------------------
// Shared frame plumbing

/// Validate magic, version and the trailing checksum; return the body
/// length (frame length minus the 8 checksum bytes).
fn check_frame(bytes: &[u8], magic: &[u8; 4]) -> Result<usize, CheckpointError> {
    if bytes.len() < 4 {
        return Err(CheckpointError::Truncated { at: bytes.len(), need: 4 - bytes.len() });
    }
    if &bytes[..4] != magic {
        return Err(CheckpointError::BadMagic([bytes[0], bytes[1], bytes[2], bytes[3]]));
    }
    if bytes.len() < 8 {
        return Err(CheckpointError::Truncated { at: bytes.len(), need: 8 - bytes.len() });
    }
    let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if version != FORMAT_VERSION {
        return Err(CheckpointError::UnsupportedVersion(version));
    }
    if bytes.len() < 16 {
        return Err(CheckpointError::Truncated { at: bytes.len(), need: 16 - bytes.len() });
    }
    let body_len = bytes.len() - 8;
    let want = u64::from_le_bytes(bytes[body_len..].try_into().unwrap());
    let got = fnv1a64(&bytes[..body_len]);
    if got != want {
        return Err(CheckpointError::ChecksumMismatch { got, want });
    }
    Ok(body_len)
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        if self.remaining() < n {
            return Err(CheckpointError::Truncated {
                at: self.i,
                need: n - self.remaining(),
            });
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CheckpointError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CheckpointError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CheckpointError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_record(out: &mut Vec<u8>, r: &RoundRecord) {
    put_u64(out, r.round as u64);
    put_u64(out, r.train_loss.to_bits());
    put_u64(out, r.val_accuracy.to_bits());
    put_u64(out, r.uplink_bits);
    put_u64(out, r.uplink_bytes);
    put_u64(out, r.transmitted as u64);
    put_u64(out, r.expected_budget.to_bits());
    put_u64(out, r.alpha.to_bits());
    put_u64(out, r.gamma.to_bits());
}

fn get_record(r: &mut Reader) -> Result<RoundRecord, CheckpointError> {
    Ok(RoundRecord {
        round: r.u64()? as usize,
        train_loss: f64::from_bits(r.u64()?),
        val_accuracy: f64::from_bits(r.u64()?),
        uplink_bits: r.u64()?,
        uplink_bytes: r.u64()?,
        transmitted: r.u64()? as usize,
        expected_budget: f64::from_bits(r.u64()?),
        alpha: f64::from_bits(r.u64()?),
        gamma: f64::from_bits(r.u64()?),
    })
}

fn put_stats(out: &mut Vec<u8>, s: &CoordStats) {
    put_u64(out, s.shards_dropped as u64);
    put_u64(out, s.shards_outaged as u64);
    put_u64(out, s.noop_rounds as u64);
    put_u64(out, s.rounds_run as u64);
    put_fault_counters(out, &s.faults);
}

fn get_stats(r: &mut Reader) -> Result<CoordStats, CheckpointError> {
    Ok(CoordStats {
        shards_dropped: r.u64()? as usize,
        shards_outaged: r.u64()? as usize,
        noop_rounds: r.u64()? as usize,
        rounds_run: r.u64()? as usize,
        faults: get_fault_counters(r)?,
    })
}

fn put_fault_counters(out: &mut Vec<u8>, c: &FaultCounters) {
    for v in [
        c.crash_pre,
        c.crash_post,
        c.corrupt,
        c.quarantined,
        c.stalls,
        c.retries,
        c.shards_degraded,
        c.mask_repairs,
    ] {
        put_u64(out, v);
    }
}

fn get_fault_counters(r: &mut Reader) -> Result<FaultCounters, CheckpointError> {
    Ok(FaultCounters {
        crash_pre: r.u64()?,
        crash_post: r.u64()?,
        corrupt: r.u64()?,
        quarantined: r.u64()?,
        stalls: r.u64()?,
        retries: r.u64()?,
        shards_degraded: r.u64()?,
        mask_repairs: r.u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::prop::quick;
    use crate::util::rng::Rng;

    fn arb_record(rng: &mut Rng) -> RoundRecord {
        let arb_f64 = |rng: &mut Rng| match rng.below(5) {
            0 => f64::NAN,
            1 => 0.0,
            2 => -rng.f64() * 1e300,
            _ => rng.f64(),
        };
        RoundRecord {
            round: rng.next_u64() as usize,
            train_loss: arb_f64(rng),
            val_accuracy: arb_f64(rng),
            uplink_bits: rng.next_u64(),
            uplink_bytes: rng.next_u64(),
            transmitted: rng.below(1 << 20) as usize,
            expected_budget: arb_f64(rng),
            alpha: arb_f64(rng),
            gamma: arb_f64(rng),
        }
    }

    fn arb_snapshot(rng: &mut Rng) -> Snapshot {
        let dim = rng.below(64) as usize;
        let n_rec = rng.below(16) as usize;
        let fault = match rng.below(3) {
            0 => None,
            // empty and partial AOCS caches both covered
            k => Some(FaultState {
                counters: FaultCounters {
                    crash_pre: rng.next_u64() % 100,
                    crash_post: rng.next_u64() % 100,
                    corrupt: rng.next_u64() % 100,
                    quarantined: rng.next_u64() % 100,
                    stalls: rng.next_u64() % 100,
                    retries: rng.next_u64() % 100,
                    shards_degraded: rng.next_u64() % 100,
                    mask_repairs: rng.next_u64() % 100,
                },
                last_probs: (0..if k == 1 { 0 } else { rng.below(20) })
                    .map(|i| (i * 7, rng.f64()))
                    .collect(),
            }),
        };
        Snapshot {
            config_fingerprint: rng.next_u64(),
            // zero and max round indices exercised explicitly
            next_round: match rng.below(4) {
                0 => 0,
                1 => u64::MAX,
                _ => rng.next_u64(),
            },
            x: (0..dim).map(|_| f32::from_bits(rng.next_u64() as u32)).collect(),
            meter_bytes: rng.next_u64(),
            records: (0..n_rec).map(|_| arb_record(rng)).collect(),
            stats: CoordStats {
                shards_dropped: rng.below(1000) as usize,
                shards_outaged: rng.below(1000) as usize,
                noop_rounds: rng.below(1000) as usize,
                rounds_run: rng.below(1000) as usize,
                faults: FaultCounters::default(),
            },
            fault,
            tel_counters: (0..rng.below(30)).map(|_| rng.next_u64()).collect(),
            tel_rounds: rng.next_u64(),
        }
    }

    #[test]
    fn prop_snapshot_codec_round_trips_bit_exactly() {
        quick("snapshot-roundtrip", |rng, _| {
            let snap = arb_snapshot(rng);
            let bytes = snap.to_bytes();
            let back = Snapshot::from_bytes(&bytes)
                .map_err(|e| format!("decode failed: {e}"))?;
            // byte-exact round trip: re-encoding the decoded snapshot
            // reproduces the frame (covers every field bit, NaNs incl.)
            if back.to_bytes() != bytes {
                return Err("re-encoded snapshot differs".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_truncation_never_panics_and_always_errors() {
        quick("snapshot-truncation", |rng, _| {
            let bytes = arb_snapshot(rng).to_bytes();
            let cut = rng.below(bytes.len() as u64) as usize;
            match Snapshot::from_bytes(&bytes[..cut]) {
                Ok(_) => Err(format!("truncation to {cut} bytes decoded")),
                Err(_) => Ok(()),
            }
        });
    }

    #[test]
    fn prop_single_byte_mutation_is_detected() {
        quick("snapshot-mutation", |rng, _| {
            let mut bytes = arb_snapshot(rng).to_bytes();
            let pos = rng.below(bytes.len() as u64) as usize;
            bytes[pos] ^= 1 + rng.below(255) as u8;
            match Snapshot::from_bytes(&bytes) {
                Ok(_) => Err(format!("flip at byte {pos} went unnoticed")),
                Err(_) => Ok(()),
            }
        });
    }

    #[test]
    fn frame_errors_are_typed() {
        let snap = Snapshot::empty(7, 3);
        let good = snap.to_bytes();

        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(matches!(
            Snapshot::from_bytes(&bad_magic),
            Err(CheckpointError::BadMagic(_))
        ));

        let mut bad_version = good.clone();
        bad_version[4] = 99;
        assert!(matches!(
            Snapshot::from_bytes(&bad_version),
            Err(CheckpointError::UnsupportedVersion(99))
        ));

        let mut bad_sum = good.clone();
        let last = bad_sum.len() - 1;
        bad_sum[last] ^= 0xFF;
        assert!(matches!(
            Snapshot::from_bytes(&bad_sum),
            Err(CheckpointError::ChecksumMismatch { .. })
        ));

        assert!(matches!(
            Snapshot::from_bytes(&good[..10]),
            Err(CheckpointError::Truncated { .. })
        ));

        let mut trailing = good.clone();
        // splice an extra byte into the body and re-checksum so only
        // the TrailingBytes check can fire
        trailing.truncate(good.len() - 8);
        trailing.push(0);
        let sum = fnv1a64(&trailing);
        trailing.extend_from_slice(&sum.to_le_bytes());
        assert!(matches!(
            Snapshot::from_bytes(&trailing),
            Err(CheckpointError::TrailingBytes(1))
        ));
    }

    #[test]
    fn atomic_write_round_trips_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join(format!(
            "fedsamp_ckpt_{}",
            std::process::id()
        ));
        let path = dir.join("snap.bin");
        let path = path.to_string_lossy().into_owned();
        let mut snap = Snapshot::empty(42, 9);
        snap.x = vec![1.5, -2.25, f32::NAN];
        snap.meter_bytes = 1234;
        let bytes = snap.write_atomic(&path).unwrap();
        assert_eq!(bytes, snap.to_bytes().len());
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let back = Snapshot::load(&path).unwrap();
        assert_eq!(back.to_bytes(), snap.to_bytes());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_fingerprint_separates_configs() {
        let a = presets::femnist(1, 3);
        let mut b = a.clone();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        b.seed += 1;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        let mut c = a.clone();
        c.rounds += 1;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
    }

    #[test]
    fn checkpoint_cli_tokens_parse_with_typed_errors() {
        assert_eq!(parse_checkpoint_every("10"), Ok(10));
        assert_eq!(parse_checkpoint_every(" 0 "), Ok(0));
        assert_eq!(
            parse_checkpoint_every("banana"),
            Err(CheckpointSpecError::BadEvery { token: "banana".into() })
        );
        assert_eq!(
            parse_checkpoint_every("-3"),
            Err(CheckpointSpecError::BadEvery { token: "-3".into() })
        );
        assert_eq!(parse_resume_path("snap.bin"), Ok("snap.bin".into()));
        assert_eq!(
            parse_resume_path("  "),
            Err(CheckpointSpecError::EmptyResumePath)
        );
        // the messages carry the offending token
        let e: String = CheckpointSpecError::BadEvery { token: "banana".into() }.into();
        assert!(e.contains("banana"));
    }

    #[test]
    fn options_validate_cadence_needs_path() {
        assert!(CheckpointOptions::default().validate().is_ok());
        let bad = CheckpointOptions { every: 2, ..CheckpointOptions::default() };
        assert!(bad.validate().is_err());
        let ok = CheckpointOptions {
            every: 2,
            out: Some("snap.bin".into()),
            ..CheckpointOptions::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn ledger_round_trips_and_rejects_spec_drift() {
        let mut rng = Rng::new(5);
        let mut ledger = SweepLedger::new(77);
        for i in 0..4u64 {
            ledger.entries.push(LedgerEntry {
                arm_fingerprint: 1000 + i,
                seed: i % 2,
                records: (0..3).map(|_| arb_record_pub(&mut rng)).collect(),
                stats: CoordStats::default(),
            });
        }
        let bytes = ledger.to_bytes();
        let back = SweepLedger::from_bytes(&bytes).unwrap();
        assert_eq!(back.to_bytes(), bytes);
        assert!(back.entry(1002, 0).is_some());
        assert!(back.entry(1002, 1).is_none());
        // file-level tampering is caught
        let mut bad = bytes.clone();
        bad[20] ^= 0x40;
        assert!(SweepLedger::from_bytes(&bad).is_err());
    }

    fn arb_record_pub(rng: &mut Rng) -> RoundRecord {
        arb_record(rng)
    }
}
