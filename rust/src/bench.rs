//! Bench harness substrate (no criterion available offline).
//!
//! `cargo bench` targets use [`Bench`] for wall-clock micro/meso
//! benchmarks (adaptive iteration count, warmup, mean ± std, throughput),
//! and [`Table`] for printing the paper's figure series as aligned rows.

use std::time::{Duration, Instant};

use crate::util::stats::summarize;

/// One benchmark group; prints rows like
/// `name                      12.345 µs/iter (± 0.6) [n=480]`.
pub struct Bench {
    group: String,
    min_time: Duration,
    max_iters: u64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        println!("\n== bench group: {group} ==");
        Bench {
            group: group.to_string(),
            min_time: Duration::from_millis(300),
            max_iters: 1_000_000,
        }
    }

    pub fn with_min_time(mut self, d: Duration) -> Self {
        self.min_time = d;
        self
    }

    /// Measure `f`, auto-scaling iteration count; returns ns/iter mean.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> f64 {
        // warmup + calibration
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_nanos().max(1) as u64;
        let target = self.min_time.as_nanos() as u64;
        let batch = (target / once / 10).clamp(1, self.max_iters);

        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.min_time && samples.len() < 50 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
        }
        let s = summarize(&samples);
        println!(
            "{:<44} {:>12}/iter (± {}) p50={} p90={} [batch={} samples={}]",
            format!("{}/{}", self.group, name),
            fmt_ns(s.mean),
            fmt_ns(s.std),
            fmt_ns(s.median),
            fmt_ns(s.p90),
            batch,
            s.n
        );
        s.mean
    }

    /// Measure and report throughput in `items/s`.
    pub fn run_throughput<F: FnMut()>(&self, name: &str, items: u64, f: F) -> f64 {
        let ns = self.run(name, f);
        let per_s = items as f64 / (ns * 1e-9);
        println!("{:<44} {:>12.0} items/s", format!("{}/{name}", self.group), per_s);
        per_s
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns.is_nan() {
        "nan".into()
    } else if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.3} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Aligned-table printer for figure/table regeneration output.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        for (w, c) in self.widths.iter_mut().zip(&cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:>width$}  ", width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers, &self.widths);
        let total: usize = self.widths.iter().sum::<usize>() + 2 * self.widths.len();
        println!("{}", "-".repeat(total));
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Format a float with fixed precision (helper for Table rows).
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench::new("test").with_min_time(Duration::from_millis(10));
        let mut acc = 0u64;
        let ns = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(ns > 0.0 && ns < 1e7);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["1".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
    }
}
