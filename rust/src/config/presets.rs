//! Per-figure experiment presets mirroring the paper's evaluation setup
//! (Section 5 + Appendices F/G). Each preset is the *optimal-sampling*
//! configuration; use [`ExperimentConfig::with_strategy`] to derive the
//! full/uniform comparison arms (the paper tunes η_l per arm — the tuned
//! values from Appendix F are baked into [`tuned_eta_l`]).

use super::{Algorithm, DataSpec, ExperimentConfig, Strategy};

/// The paper's tuned local step sizes (Appendix F.1/F.2, Appendix G).
///
/// dataset ∈ {"femnist1","femnist2","femnist3","shakespeare","cifar"}.
pub fn tuned_eta_l(dataset: &str, strategy: &Strategy) -> f64 {
    let uniform = matches!(strategy, Strategy::Uniform);
    match dataset {
        // full/optimal: 2^-3; uniform: 2^-5 (DS1) or 2^-4 (DS2/3)
        "femnist1" => {
            if uniform {
                0.03125
            } else {
                0.125
            }
        }
        "femnist2" | "femnist3" => {
            if uniform {
                0.0625
            } else {
                0.125
            }
        }
        // full/optimal: 2^-2; uniform: 2^-3
        "shakespeare" => {
            if uniform {
                0.125
            } else {
                0.25
            }
        }
        // full/optimal: 1e-3; uniform: 3e-4
        "cifar" => {
            if uniform {
                3e-4
            } else {
                1e-3
            }
        }
        _ => 0.1,
    }
}

fn base(name: &str, data: DataSpec, model: &str, cohort: usize, m: usize,
        batch: usize) -> ExperimentConfig {
    let dataset = data.name();
    let strategy = Strategy::Aocs { j_max: 4 };
    ExperimentConfig {
        name: name.to_string(),
        seed: 1,
        rounds: 151,
        cohort,
        budget: m,
        algorithm: Algorithm::FedAvg {
            local_epochs: 1,
            eta_g: 1.0,
            eta_l: tuned_eta_l(&dataset, &strategy),
        },
        strategy,
        data,
        model: model.to_string(),
        batch_size: batch,
        eval_every: 5,
        eval_examples: 1024,
        workers: 4,
        secure_updates: true,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

/// Figures 3–5 (+8–10): FEMNIST datasets 1–3, n=32, m ∈ {3, 6}.
pub fn femnist(variant: u8, m: usize) -> ExperimentConfig {
    assert!((1..=3).contains(&variant));
    base(
        &format!("fig{}_femnist{}_m{}", 2 + variant as usize, variant, m),
        DataSpec::FemnistLike { pool: 350, variant },
        "femnist_mlp",
        32,
        m,
        20,
    )
}

/// Figures 6–7 (+11–12): Shakespeare, n ∈ {32, 128}, m ∈ {2,4,6,12}.
pub fn shakespeare(cohort: usize, m: usize) -> ExperimentConfig {
    base(
        &format!("fig_shakespeare_n{cohort}_m{m}"),
        DataSpec::ShakespeareLike { pool: 715 },
        "shakespeare_gru",
        cohort,
        m,
        8,
    )
}

/// Figure 13: CIFAR100-like balanced, n=32, m=3.
pub fn cifar(m: usize) -> ExperimentConfig {
    base(
        &format!("fig13_cifar_m{m}"),
        DataSpec::CifarLike { pool: 500, per_client: 100 },
        "cifar_mlp",
        32,
        m,
        20,
    )
}

/// Theory experiments (Thms 13/15): DSGD on the rust-native logistic
/// model — fast enough for long-horizon recursion measurements.
pub fn dsgd_theory(m: usize, eta: f64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("theory_dsgd_m{m}"),
        seed: 1,
        rounds: 400,
        cohort: 32,
        budget: m,
        strategy: Strategy::Ocs,
        algorithm: Algorithm::Dsgd { eta },
        data: DataSpec::FemnistLike { pool: 32, variant: 1 },
        model: "native:logistic".into(),
        batch_size: 20,
        eval_every: 10,
        eval_examples: 512,
        workers: 1,
        secure_updates: true,
        availability: 1.0,
        availability_trace: None,
        compressor: None,
        fault_plan: None,
    }
}

/// Look a preset up by figure id (CLI `figures --fig N`).
pub fn by_figure(fig: &str) -> Vec<ExperimentConfig> {
    match fig {
        "3" => vec![femnist(1, 3), femnist(1, 6)],
        "4" => vec![femnist(2, 3), femnist(2, 6)],
        "5" => vec![femnist(3, 3), femnist(3, 6)],
        "6" => vec![shakespeare(32, 2), shakespeare(32, 6)],
        "7" => vec![shakespeare(128, 4), shakespeare(128, 12)],
        "13" => vec![cifar(3)],
        _ => vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for cfg in [
            femnist(1, 3),
            femnist(2, 6),
            femnist(3, 3),
            shakespeare(32, 2),
            shakespeare(128, 12),
            cifar(3),
            dsgd_theory(8, 0.5),
        ] {
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
        }
    }

    #[test]
    fn tuned_lrs_match_paper() {
        // §5.4: OCS admits larger step sizes than uniform — always true here
        for ds in ["femnist1", "femnist2", "femnist3", "shakespeare", "cifar"] {
            let ocs = tuned_eta_l(ds, &Strategy::Ocs);
            let uni = tuned_eta_l(ds, &Strategy::Uniform);
            assert!(ocs > uni, "{ds}: {ocs} <= {uni}");
            let full = tuned_eta_l(ds, &Strategy::Full);
            assert_eq!(ocs, full, "{ds}: full and optimal share the tuned lr");
        }
    }

    #[test]
    fn by_figure_covers_eval_figures() {
        for fig in ["3", "4", "5", "6", "7", "13"] {
            assert!(!by_figure(fig).is_empty(), "fig {fig}");
        }
        assert!(by_figure("99").is_empty());
    }
}
