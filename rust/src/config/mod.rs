//! Experiment configuration: typed config structs, JSON (de)serialization,
//! and presets for every figure in the paper's evaluation.

pub mod presets;

use crate::compress::Compressor;
use crate::faults::FaultPlan;
use crate::fl::availability::Trace;
use crate::util::json::Json;

/// Default AOCS/CAOCS rescaling-iteration cap when a spec gives none.
pub const DEFAULT_J_MAX: usize = 4;
/// Default cluster count for a bare `clustered` spec.
pub const DEFAULT_CLUSTERS: usize = 4;
/// Default group count for a bare `cyclic` spec.
pub const DEFAULT_GROUPS: usize = 4;

/// Client sampling strategy (the paper's comparison axis, plus the
/// related-work zoo of DESIGN.md §13).
#[derive(Clone, Debug, PartialEq)]
pub enum Strategy {
    /// Every cohort client communicates (upper baseline).
    Full,
    /// Independent uniform sampling with p_i = m/n (lower baseline).
    Uniform,
    /// Exact optimal client sampling, Eq. (7) / Algorithm 1.
    Ocs,
    /// Approximate OCS, Algorithm 2 (secure-aggregation compatible).
    Aocs { j_max: usize },
    /// Clustered sampling (arXiv 2105.05883): k-means grouping of the
    /// cohort by update-norm history, mass-proportional per-cluster
    /// quotas, uniform draws within a cluster.
    Clustered { k: usize },
    /// Regularized cyclic participation (arXiv 2302.03662): g fixed
    /// seed-hashed client groups visited round-robin; the round's
    /// cohort is restricted to the scheduled group at Announce.
    Cyclic { g: usize },
    /// Compression-aware AOCS (arXiv 2306.03240): Algorithm 2 run on
    /// the *compressed* payload norms w_i‖C(U_i)‖, so the compressor
    /// choice feeds the participation probabilities.
    Caocs { j_max: usize },
}

/// Typed failure parsing a strategy spec — each variant carries the
/// offending token, so `--strategy clusteredX` names `clusteredX`
/// instead of dying with a generic message (the `--faults`
/// [`crate::faults::FaultSpecError`] convention).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StrategySpecError {
    /// Spec starts with no known strategy name.
    UnknownStrategy { token: String },
    /// An `aocs<j>` / `caocs<j>` suffix is not a non-negative integer.
    BadJMax { token: String },
    /// A `clustered<k>` suffix is not an integer ≥ 1.
    BadClusterCount { token: String },
    /// A `cyclic<g>` suffix is not an integer ≥ 1.
    BadGroupCount { token: String },
}

impl std::fmt::Display for StrategySpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StrategySpecError::UnknownStrategy { token } => write!(
                f,
                "unknown strategy '{token}' (want full|uniform|ocs|\
                 aocs[<j>]|caocs[<j>]|clustered[<k>]|cyclic[<g>])"
            ),
            StrategySpecError::BadJMax { token } => {
                write!(f, "bad j_max suffix in strategy '{token}'")
            }
            StrategySpecError::BadClusterCount { token } => write!(
                f,
                "bad cluster count in strategy '{token}' (want an \
                 integer >= 1)"
            ),
            StrategySpecError::BadGroupCount { token } => write!(
                f,
                "bad group count in strategy '{token}' (want an \
                 integer >= 1)"
            ),
        }
    }
}

impl std::error::Error for StrategySpecError {}

impl From<StrategySpecError> for String {
    fn from(e: StrategySpecError) -> String {
        e.to_string()
    }
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Full => "full",
            Strategy::Uniform => "uniform",
            Strategy::Ocs => "ocs",
            Strategy::Aocs { .. } => "aocs",
            Strategy::Clustered { .. } => "clustered",
            Strategy::Cyclic { .. } => "cyclic",
            Strategy::Caocs { .. } => "caocs",
        }
    }

    /// Parse a strategy spec — the single grammar behind config JSON,
    /// `--strategy`, and the sweep `--strategies` arm list:
    ///
    /// `full | uniform | ocs | aocs[<j>] | caocs[<j>] |
    ///  clustered[<k>] | cyclic[<g>]`
    ///
    /// Bare parameterized names take the defaults ([`DEFAULT_J_MAX`],
    /// [`DEFAULT_CLUSTERS`], [`DEFAULT_GROUPS`]); `clustered0` /
    /// `cyclic0` are rejected here (and again by
    /// [`ExperimentConfig::validate`] for configs built in code).
    pub fn parse(spec: &str) -> Result<Strategy, StrategySpecError> {
        let s = spec.trim();
        // exact names first: the unparameterized strategies take no
        // suffix, so `ocs3` falls through to UnknownStrategy
        match s {
            "full" => return Ok(Strategy::Full),
            "uniform" => return Ok(Strategy::Uniform),
            "ocs" => return Ok(Strategy::Ocs),
            _ => {}
        }
        let token = || s.to_string();
        // longest prefixes first; none of the parameterized names is a
        // prefix of another, but `caocs` must not reach the bare-`ocs`
        // exact match above (it cannot: exact match only)
        if let Some(rest) = s.strip_prefix("clustered") {
            if rest.is_empty() {
                return Ok(Strategy::Clustered { k: DEFAULT_CLUSTERS });
            }
            return match rest.parse::<usize>() {
                Ok(k) if k >= 1 => Ok(Strategy::Clustered { k }),
                _ => Err(StrategySpecError::BadClusterCount { token: token() }),
            };
        }
        if let Some(rest) = s.strip_prefix("cyclic") {
            if rest.is_empty() {
                return Ok(Strategy::Cyclic { g: DEFAULT_GROUPS });
            }
            return match rest.parse::<usize>() {
                Ok(g) if g >= 1 => Ok(Strategy::Cyclic { g }),
                _ => Err(StrategySpecError::BadGroupCount { token: token() }),
            };
        }
        if let Some(rest) = s.strip_prefix("caocs") {
            if rest.is_empty() {
                return Ok(Strategy::Caocs { j_max: DEFAULT_J_MAX });
            }
            return match rest.parse::<usize>() {
                Ok(j_max) => Ok(Strategy::Caocs { j_max }),
                Err(_) => Err(StrategySpecError::BadJMax { token: token() }),
            };
        }
        if let Some(rest) = s.strip_prefix("aocs") {
            if rest.is_empty() {
                return Ok(Strategy::Aocs { j_max: DEFAULT_J_MAX });
            }
            return match rest.parse::<usize>() {
                Ok(j_max) => Ok(Strategy::Aocs { j_max }),
                Err(_) => Err(StrategySpecError::BadJMax { token: token() }),
            };
        }
        Err(StrategySpecError::UnknownStrategy { token: token() })
    }

    fn to_json(&self) -> Json {
        match self {
            Strategy::Aocs { j_max } => Json::obj(vec![
                ("kind", Json::str("aocs")),
                ("j_max", Json::num(*j_max as f64)),
            ]),
            Strategy::Caocs { j_max } => Json::obj(vec![
                ("kind", Json::str("caocs")),
                ("j_max", Json::num(*j_max as f64)),
            ]),
            Strategy::Clustered { k } => Json::obj(vec![
                ("kind", Json::str("clustered")),
                ("k", Json::num(*k as f64)),
            ]),
            Strategy::Cyclic { g } => Json::obj(vec![
                ("kind", Json::str("cyclic")),
                ("g", Json::num(*g as f64)),
            ]),
            s => Json::obj(vec![("kind", Json::str(s.name()))]),
        }
    }

    fn from_json(v: &Json) -> Result<Strategy, String> {
        let kind = v.get("kind").as_str().ok_or("strategy.kind missing")?;
        // the kind field goes through the one CLI grammar (so
        // `"kind": "clustered3"` also works), then explicit parameter
        // fields override the spec/defaults
        let mut s = Strategy::parse(kind).map_err(String::from)?;
        match &mut s {
            Strategy::Aocs { j_max } | Strategy::Caocs { j_max } => {
                if let Some(j) = v.get("j_max").as_usize() {
                    *j_max = j;
                }
            }
            Strategy::Clustered { k } => {
                if let Some(x) = v.get("k").as_usize() {
                    *k = x;
                }
            }
            Strategy::Cyclic { g } => {
                if let Some(x) = v.get("g").as_usize() {
                    *g = x;
                }
            }
            _ => {}
        }
        Ok(s)
    }
}

/// Underlying learning method.
#[derive(Clone, Debug, PartialEq)]
pub enum Algorithm {
    /// FedAvg (Algorithm 3): R local SGD steps, global step η_g on Δx.
    FedAvg { local_epochs: usize, eta_g: f64, eta_l: f64 },
    /// Distributed SGD (Eq. 2): one gradient per client per round.
    Dsgd { eta: f64 },
}

impl Algorithm {
    fn to_json(&self) -> Json {
        match self {
            Algorithm::FedAvg { local_epochs, eta_g, eta_l } => Json::obj(vec![
                ("kind", Json::str("fedavg")),
                ("local_epochs", Json::num(*local_epochs as f64)),
                ("eta_g", Json::num(*eta_g)),
                ("eta_l", Json::num(*eta_l)),
            ]),
            Algorithm::Dsgd { eta } => Json::obj(vec![
                ("kind", Json::str("dsgd")),
                ("eta", Json::num(*eta)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Algorithm, String> {
        match v.get("kind").as_str() {
            Some("fedavg") => Ok(Algorithm::FedAvg {
                local_epochs: v.get("local_epochs").as_usize().unwrap_or(1),
                eta_g: v.get("eta_g").as_f64().unwrap_or(1.0),
                eta_l: v.get("eta_l").as_f64().ok_or("fedavg.eta_l missing")?,
            }),
            Some("dsgd") => Ok(Algorithm::Dsgd {
                eta: v.get("eta").as_f64().ok_or("dsgd.eta missing")?,
            }),
            _ => Err("algorithm.kind must be fedavg|dsgd".into()),
        }
    }

    pub fn local_lr(&self) -> f64 {
        match self {
            Algorithm::FedAvg { eta_l, .. } => *eta_l,
            Algorithm::Dsgd { eta } => *eta,
        }
    }
}

/// Synthetic federated dataset selector (DESIGN.md substitution table).
#[derive(Clone, Debug, PartialEq)]
pub enum DataSpec {
    /// FEMNIST-like synthetic images. `variant`: 0 = original balance,
    /// 1..=3 = the paper's three (s, a, b) unbalanced modifications.
    FemnistLike { pool: usize, variant: u8 },
    /// Shakespeare-like synthetic char sequences (715-client pool).
    ShakespeareLike { pool: usize },
    /// CIFAR100-like balanced images (Appendix G).
    CifarLike { pool: usize, per_client: usize },
}

impl DataSpec {
    pub fn name(&self) -> String {
        match self {
            DataSpec::FemnistLike { variant, .. } => format!("femnist{variant}"),
            DataSpec::ShakespeareLike { .. } => "shakespeare".into(),
            DataSpec::CifarLike { .. } => "cifar".into(),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            DataSpec::FemnistLike { pool, variant } => Json::obj(vec![
                ("kind", Json::str("femnist")),
                ("pool", Json::num(*pool as f64)),
                ("variant", Json::num(*variant as f64)),
            ]),
            DataSpec::ShakespeareLike { pool } => Json::obj(vec![
                ("kind", Json::str("shakespeare")),
                ("pool", Json::num(*pool as f64)),
            ]),
            DataSpec::CifarLike { pool, per_client } => Json::obj(vec![
                ("kind", Json::str("cifar")),
                ("pool", Json::num(*pool as f64)),
                ("per_client", Json::num(*per_client as f64)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<DataSpec, String> {
        match v.get("kind").as_str() {
            Some("femnist") => Ok(DataSpec::FemnistLike {
                pool: v.get("pool").as_usize().unwrap_or(350),
                variant: v.get("variant").as_usize().unwrap_or(1) as u8,
            }),
            Some("shakespeare") => Ok(DataSpec::ShakespeareLike {
                pool: v.get("pool").as_usize().unwrap_or(715),
            }),
            Some("cifar") => Ok(DataSpec::CifarLike {
                pool: v.get("pool").as_usize().unwrap_or(500),
                per_client: v.get("per_client").as_usize().unwrap_or(100),
            }),
            _ => Err("data.kind must be femnist|shakespeare|cifar".into()),
        }
    }
}

/// Full experiment description — everything a run needs.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// communication rounds (paper: 151)
    pub rounds: usize,
    /// cohort size sampled from the pool each round (paper: n = 32/128)
    pub cohort: usize,
    /// expected communication budget m ≤ n
    pub budget: usize,
    pub strategy: Strategy,
    pub algorithm: Algorithm,
    pub data: DataSpec,
    /// artifact model name (XLA path) or "native:<kind>" (sim path)
    pub model: String,
    pub batch_size: usize,
    /// evaluate every this many rounds (paper: 5)
    pub eval_every: usize,
    /// validation examples
    pub eval_examples: usize,
    /// worker threads for client training (XLA path)
    pub workers: usize,
    /// mask update vectors through the secure-aggregation protocol
    /// (always on for the AOCS scalar negotiation; this flag covers the
    /// O(|S|²·d) vector masking, which large benches may disable)
    pub secure_updates: bool,
    /// per-round client availability probability q (Appendix E); 1.0 = the
    /// main-paper setting where every pool client is always available
    pub availability: f64,
    /// time-varying availability trace (scenario engine): diurnal
    /// Bernoulli schedule, per-client session churn, correlated shard
    /// outages. Replaces the scalar `availability` when set (the scalar
    /// must then stay at 1.0 — the trace's `base_q` is the baseline)
    pub availability_trace: Option<Trace>,
    /// update compression applied to participant uploads (§6 composition;
    /// wire-payload kind). `TrainOptions::compressor` overrides when set.
    pub compressor: Option<Compressor>,
    /// chaos layer: seeded deterministic fault injection (mid-round
    /// crashes, payload corruption, stalled negotiation partials) plus
    /// the Repair phase that makes the estimator survive them. `None`
    /// (or an all-zero plan) is bitwise identical to no chaos at all.
    pub fault_plan: Option<FaultPlan>,
}

impl ExperimentConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.budget == 0 || self.budget > self.cohort {
            return Err(format!(
                "budget m={} must satisfy 1 <= m <= cohort n={}",
                self.budget, self.cohort
            ));
        }
        if self.rounds == 0 {
            return Err("rounds must be positive".into());
        }
        match &self.strategy {
            Strategy::Clustered { k } if *k == 0 => {
                return Err("clustered strategy needs k >= 1 clusters".into());
            }
            Strategy::Cyclic { g } if *g == 0 => {
                return Err("cyclic strategy needs g >= 1 groups".into());
            }
            _ => {}
        }
        if self.eval_every == 0 {
            return Err("eval_every must be positive".into());
        }
        if let Algorithm::FedAvg { local_epochs, .. } = self.algorithm {
            if local_epochs == 0 {
                return Err("local_epochs must be positive".into());
            }
        }
        if !(0.0 < self.availability && self.availability <= 1.0) {
            return Err("availability must be in (0, 1]".into());
        }
        if let Some(p) = &self.fault_plan {
            p.validate()?;
        }
        if let Some(t) = &self.availability_trace {
            t.validate()?;
            if self.availability < 1.0 {
                return Err(
                    "availability_trace replaces the scalar availability; \
                     leave availability at 1.0 and set the trace's base_q"
                        .into(),
                );
            }
        }
        Ok(())
    }

    /// Canonical JSON rendering — every trajectory-steering field is
    /// here, which is what makes this the input of
    /// [`crate::checkpoint::config_fingerprint`] (a snapshot refuses to
    /// resume under a config whose canonical form differs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("cohort", Json::num(self.cohort as f64)),
            ("budget", Json::num(self.budget as f64)),
            ("strategy", self.strategy.to_json()),
            ("algorithm", self.algorithm.to_json()),
            ("data", self.data.to_json()),
            ("model", Json::str(self.model.clone())),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("eval_examples", Json::num(self.eval_examples as f64)),
            ("workers", Json::num(self.workers as f64)),
            ("secure_updates", Json::Bool(self.secure_updates)),
            ("availability", Json::num(self.availability)),
            (
                "availability_trace",
                match &self.availability_trace {
                    Some(t) => t.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "compressor",
                match &self.compressor {
                    Some(c) => c.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "fault_plan",
                match &self.fault_plan {
                    Some(p) => p.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ExperimentConfig, String> {
        let compressor = match v.get("compressor") {
            Json::Null => None,
            j => Some(Compressor::from_json(j)?),
        };
        let availability_trace = match v.get("availability_trace") {
            Json::Null => None,
            j => Some(Trace::from_json(j)?),
        };
        let fault_plan = match v.get("fault_plan") {
            Json::Null => None,
            j => Some(FaultPlan::from_json(j)?),
        };
        let cfg = ExperimentConfig {
            name: v.get("name").as_str().unwrap_or("experiment").to_string(),
            seed: v.get("seed").as_f64().unwrap_or(0.0) as u64,
            rounds: v.get("rounds").as_usize().ok_or("rounds missing")?,
            cohort: v.get("cohort").as_usize().ok_or("cohort missing")?,
            budget: v.get("budget").as_usize().ok_or("budget missing")?,
            strategy: Strategy::from_json(v.get("strategy"))?,
            algorithm: Algorithm::from_json(v.get("algorithm"))?,
            data: DataSpec::from_json(v.get("data"))?,
            model: v.get("model").as_str().unwrap_or("native:logistic").into(),
            batch_size: v.get("batch_size").as_usize().unwrap_or(20),
            eval_every: v.get("eval_every").as_usize().unwrap_or(5),
            eval_examples: v.get("eval_examples").as_usize().unwrap_or(1024),
            workers: v.get("workers").as_usize().unwrap_or(4),
            secure_updates: v.get("secure_updates").as_bool().unwrap_or(true),
            availability: v.get("availability").as_f64().unwrap_or(1.0),
            availability_trace,
            compressor,
            fault_plan,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        let v = Json::parse(&text).map_err(|e| e.to_string())?;
        ExperimentConfig::from_json(&v)
    }

    /// Derive a copy with a different strategy (for the 3-way comparison).
    pub fn with_strategy(&self, strategy: Strategy) -> ExperimentConfig {
        let mut c = self.clone();
        c.name = format!("{}_{}", self.name, strategy.name());
        c.strategy = strategy;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentConfig {
        ExperimentConfig {
            name: "t".into(),
            seed: 1,
            rounds: 151,
            cohort: 32,
            budget: 3,
            strategy: Strategy::Aocs { j_max: 4 },
            algorithm: Algorithm::FedAvg {
                local_epochs: 1,
                eta_g: 1.0,
                eta_l: 0.125,
            },
            data: DataSpec::FemnistLike { pool: 350, variant: 1 },
            model: "femnist_mlp".into(),
            batch_size: 20,
            eval_every: 5,
            eval_examples: 1024,
            workers: 4,
            secure_updates: true,
            availability: 1.0,
            availability_trace: None,
            compressor: None,
            fault_plan: None,
        }
    }

    #[test]
    fn availability_trace_round_trips_and_validates() {
        use crate::fl::availability::{Churn, Diurnal, Outage, Trace};
        let mut c = sample();
        c.availability_trace = Some(Trace {
            seed: 3,
            base_q: 0.8,
            diurnal: Some(Diurnal { amplitude: 0.5, period: 24, zones: 4 }),
            churn: Some(Churn { session_len: 8, drop_prob: 0.1 }),
            outage: Some(Outage { prob: 0.02 }),
        });
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
        // a trace composes with availability = 1.0 only
        c.availability = 0.5;
        assert!(c.validate().is_err());
        c.availability = 1.0;
        c.availability_trace = Some(Trace::bernoulli(1, 0.0));
        assert!(c.validate().is_err());
        // absent field → no trace
        assert_eq!(
            ExperimentConfig::from_json(&sample().to_json())
                .unwrap()
                .availability_trace,
            None
        );
    }

    #[test]
    fn json_round_trip() {
        let c = sample();
        let v = c.to_json();
        let c2 = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(c, c2);
        // and through text
        let c3 =
            ExperimentConfig::from_json(&Json::parse(&v.to_pretty()).unwrap())
                .unwrap();
        assert_eq!(c, c3);
    }

    #[test]
    fn compressor_round_trips_and_defaults_off() {
        let mut c = sample();
        c.compressor = Some(Compressor::RandK { k: 128 });
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
        // absent field → no compression
        let v = sample().to_json();
        assert_eq!(
            ExperimentConfig::from_json(&v).unwrap().compressor,
            None
        );
    }

    #[test]
    fn fault_plan_round_trips_and_defaults_off() {
        use crate::faults::FaultPlan;
        let mut c = sample();
        c.fault_plan = Some(FaultPlan {
            crash_pre: 0.05,
            crash_post: 0.2,
            corrupt: 0.1,
            stall: 0.15,
            max_retries: 2,
            ..FaultPlan::new(11)
        });
        let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
        // absent field → no chaos
        assert_eq!(
            ExperimentConfig::from_json(&sample().to_json())
                .unwrap()
                .fault_plan,
            None
        );
        // validation rejects out-of-range rates
        c.fault_plan = Some(FaultPlan {
            crash_post: 1.5,
            ..FaultPlan::new(0)
        });
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_budget() {
        let mut c = sample();
        c.budget = 33;
        assert!(c.validate().is_err());
        c.budget = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn strategy_parse_accepts_every_spec_form() {
        // every accepted spec of the grammar, bare and parameterized
        assert_eq!(Strategy::parse("full").unwrap(), Strategy::Full);
        assert_eq!(Strategy::parse("uniform").unwrap(), Strategy::Uniform);
        assert_eq!(Strategy::parse("ocs").unwrap(), Strategy::Ocs);
        assert_eq!(
            Strategy::parse("aocs").unwrap(),
            Strategy::Aocs { j_max: DEFAULT_J_MAX }
        );
        assert_eq!(
            Strategy::parse("aocs7").unwrap(),
            Strategy::Aocs { j_max: 7 }
        );
        assert_eq!(
            Strategy::parse("caocs").unwrap(),
            Strategy::Caocs { j_max: DEFAULT_J_MAX }
        );
        assert_eq!(
            Strategy::parse("caocs2").unwrap(),
            Strategy::Caocs { j_max: 2 }
        );
        assert_eq!(
            Strategy::parse("clustered").unwrap(),
            Strategy::Clustered { k: DEFAULT_CLUSTERS }
        );
        assert_eq!(
            Strategy::parse("clustered3").unwrap(),
            Strategy::Clustered { k: 3 }
        );
        assert_eq!(
            Strategy::parse("cyclic").unwrap(),
            Strategy::Cyclic { g: DEFAULT_GROUPS }
        );
        assert_eq!(
            Strategy::parse("cyclic5").unwrap(),
            Strategy::Cyclic { g: 5 }
        );
        // whitespace is trimmed (the sweep arm list splits on commas)
        assert_eq!(Strategy::parse(" ocs ").unwrap(), Strategy::Ocs);
    }

    #[test]
    fn strategy_parse_rejections_name_the_token() {
        // unknown names — including suffixed unparameterized strategies
        for bad in ["magic", "ocs3", "full2", "uniform0.5", ""] {
            assert_eq!(
                Strategy::parse(bad).unwrap_err(),
                StrategySpecError::UnknownStrategy {
                    token: bad.trim().to_string()
                },
                "{bad:?}"
            );
        }
        // malformed parameter suffixes carry the whole offending token
        assert_eq!(
            Strategy::parse("aocsX").unwrap_err(),
            StrategySpecError::BadJMax { token: "aocsX".into() }
        );
        assert_eq!(
            Strategy::parse("caocs1.5").unwrap_err(),
            StrategySpecError::BadJMax { token: "caocs1.5".into() }
        );
        assert_eq!(
            Strategy::parse("clusteredX").unwrap_err(),
            StrategySpecError::BadClusterCount { token: "clusteredX".into() }
        );
        assert_eq!(
            Strategy::parse("clustered0").unwrap_err(),
            StrategySpecError::BadClusterCount { token: "clustered0".into() }
        );
        assert_eq!(
            Strategy::parse("cyclic0").unwrap_err(),
            StrategySpecError::BadGroupCount { token: "cyclic0".into() }
        );
        assert_eq!(
            Strategy::parse("cyclic-2").unwrap_err(),
            StrategySpecError::BadGroupCount { token: "cyclic-2".into() }
        );
        // the Display form names the token (the CLI surfaces this)
        let msg = Strategy::parse("clusteredX").unwrap_err().to_string();
        assert!(msg.contains("clusteredX"), "{msg}");
        let msg = Strategy::parse("gremlin").unwrap_err().to_string();
        assert!(msg.contains("gremlin"), "{msg}");
    }

    #[test]
    fn new_strategies_round_trip_through_json() {
        for s in [
            Strategy::Clustered { k: 3 },
            Strategy::Cyclic { g: 5 },
            Strategy::Caocs { j_max: 6 },
        ] {
            let mut c = sample();
            c.strategy = s.clone();
            let c2 = ExperimentConfig::from_json(&c.to_json()).unwrap();
            assert_eq!(c2.strategy, s);
            assert_eq!(c, c2);
        }
    }

    #[test]
    fn validation_rejects_degenerate_cluster_and_group_counts() {
        let mut c = sample();
        c.strategy = Strategy::Clustered { k: 0 };
        assert!(c.validate().is_err());
        c.strategy = Strategy::Cyclic { g: 0 };
        assert!(c.validate().is_err());
        c.strategy = Strategy::Cyclic { g: 1 };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn with_strategy_renames() {
        let c = sample().with_strategy(Strategy::Uniform);
        assert_eq!(c.strategy, Strategy::Uniform);
        assert!(c.name.ends_with("_uniform"));
    }
}
