//! Simulated secure aggregation (Bonawitz et al., 2017 style).
//!
//! The property AOCS depends on: the master learns *only the sum* of
//! client contributions, never an individual value. We implement the
//! classic pairwise-additive-masking protocol over a modular integer
//! ring:
//!
//! * values are encoded as fixed-point `i64 → u64` (wrapping ring Z_2^64),
//!   so masks cancel *exactly* — floating-point masks would leave
//!   cancellation residue;
//! * every ordered pair (i < j) of participants shares a seed (in a real
//!   deployment agreed via Diffie-Hellman; the simulation derives it from
//!   the round seed, which only the trusted test harness uses to verify
//!   properties);
//! * client i adds `PRG(s_ij)` for each j > i and subtracts it for each
//!   j < i; summing all masked vectors telescopes the masks away.
//!
//! Dropout recovery (Bonawitz §4.2, simplified): if a client drops after
//! masks were committed, the surviving mask residue is reconstructed from
//! the pairwise seeds and removed — see [`SecureAggregator::recover`].
//!
//! All masking rides the blocked ring kernels of `tensor::kernels`
//! (`fill_u64` block PRG draws + the fused
//! `scale_encode_mask_accumulate`); each pair stream is consumed in
//! element order, so the block walk is bit-identical to the per-element
//! scalar pipeline retained in `kernels::reference` (DESIGN.md §6).
//! The ring folds those kernels bottom out in follow the process-wide
//! backend selection of `tensor::dispatch` (AVX2 integer adds when
//! selected — exact ops, so the protocol is backend-invariant; the PRG
//! itself is serially state-dependent and always scalar, DESIGN.md §12).

use crate::tensor::kernels::{self, MaskStream};
use crate::util::rng::Rng;

// The fixed-point ring codec lives with the ring kernels that consume
// it (`tensor::kernels::{SCALE, encode, decode}` — 24 fractional bits,
// representable for |x| < 2^39, debug-guarded); re-exported here as the
// protocol-facing names.
pub use crate::tensor::kernels::{decode, encode};

/// Round-scoped aggregator context.
///
/// Holds the round seed from which pairwise mask streams derive. In a
/// deployment each client derives only its own pair seeds; here the
/// context also exposes [`SecureAggregator::recover`] for dropout repair
/// and the unit tests' mask-cancellation checks.
#[derive(Clone, Debug)]
pub struct SecureAggregator {
    round_seed: u64,
}

impl SecureAggregator {
    pub fn new(round_seed: u64) -> Self {
        SecureAggregator { round_seed }
    }

    fn pair_rng(&self, a: u64, b: u64) -> Rng {
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        Rng::new(
            self.round_seed
                ^ lo.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ hi.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        )
    }

    /// Derive `id`'s pairwise mask streams against the round roster into
    /// a reused buffer (one stream per other member, roster order; i<j
    /// adds, i>j subtracts). The streams feed the blocked ring kernels —
    /// each is consumed strictly in element order, so block draws
    /// reproduce the per-element scalar walk exactly.
    pub fn pair_streams_into(
        &self,
        id: u64,
        participants: &[u64],
        out: &mut Vec<MaskStream>,
    ) {
        assert!(participants.contains(&id), "client {id} not in roster");
        out.clear();
        for &other in participants {
            if other == id {
                continue;
            }
            out.push(MaskStream {
                rng: self.pair_rng(id, other),
                add: id < other,
            });
        }
    }

    /// Mask a client's contribution. `participants` must be the agreed
    /// round roster (sorted or not); `id` must appear in it. Rides the
    /// fused block kernel; bit-identical to the scalar pipeline retained
    /// in `kernels::reference::scale_encode_mask`.
    pub fn mask(&self, id: u64, participants: &[u64], values: &[f32]) -> Vec<u64> {
        let mut streams = Vec::new();
        self.pair_streams_into(id, participants, &mut streams);
        let mut out = vec![0u64; values.len()];
        let mut block = Vec::new();
        kernels::scale_encode_mask_accumulate(
            &mut out,
            values,
            1.0,
            &mut streams,
            &mut block,
        );
        out
    }

    /// Sum masked contributions (fused chunked wrapping sums — ring
    /// addition commutes, so any fold order is exact); masks telescope
    /// away when all roster members are present.
    pub fn sum(contributions: &[Vec<u64>]) -> Vec<u64> {
        assert!(!contributions.is_empty());
        let d = contributions[0].len();
        for c in contributions {
            assert_eq!(c.len(), d, "ragged contributions");
        }
        let mut acc = vec![0u64; d];
        let vecs: Vec<&[u64]> =
            contributions.iter().map(|c| c.as_slice()).collect();
        kernels::wrapping_accumulate(&mut acc, &vecs);
        acc
    }

    /// Remove the residue left by dropped clients: for each dropped d and
    /// surviving s, the mask PRG(s,d) did not cancel; reconstruct and
    /// subtract it (blocked stream fold — the survivor added the stream
    /// when s < d, so removal inverts the pair sign).
    pub fn recover(
        &self,
        sum: &mut [u64],
        survivors: &[u64],
        dropped: &[u64],
    ) {
        for &s in survivors {
            for &d in dropped {
                let mut prg = self.pair_rng(s, d);
                kernels::mask_stream_accumulate(sum, &mut prg, s > d);
            }
        }
    }

    /// Decode an aggregated ring vector back to floats.
    pub fn decode_sum(sum: &[u64]) -> Vec<f32> {
        sum.iter().map(|&v| decode(v)).collect()
    }

    /// Convenience: securely aggregate scalars (the AOCS negotiation
    /// path). One reused ring accumulator + stream buffer — no per-client
    /// masked vector materializes; the masks telescope inside the fold
    /// (ring addition commutes, so the fold order is immaterial).
    pub fn aggregate_scalars(
        &self,
        inputs: &[(u64, f32)],
    ) -> f32 {
        let roster: Vec<u64> = inputs.iter().map(|(id, _)| *id).collect();
        let mut acc = [0u64; 1];
        let mut streams = Vec::new();
        let mut block = Vec::new();
        for &(id, x) in inputs {
            self.pair_streams_into(id, &roster, &mut streams);
            kernels::scale_encode_mask_accumulate(
                &mut acc,
                &[x],
                1.0,
                &mut streams,
                &mut block,
            );
        }
        decode(acc[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;

    #[test]
    fn encode_decode_round_trip() {
        for x in [0.0f32, 1.0, -1.0, 3.14159, -1234.5678, 1e-6] {
            let y = decode(encode(x));
            assert!((x - y).abs() < 1e-6, "{x} -> {y}");
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "fixed-point overflow")]
    fn encode_overflow_is_detected() {
        // 1e12 > 2^39 ≈ 5.5e11: outside the representable range, the i64
        // cast would silently saturate — the debug guard must fire
        let _ = encode(1.0e12);
    }

    #[test]
    fn encode_round_trips_near_the_range_boundary() {
        // just inside |x| < 2^39: the encoding stays exact in the ring
        for x in [5.0e11f32, -5.0e11] {
            let y = decode(encode(x));
            assert!(
                ((x - y) / x).abs() < 1e-6,
                "boundary round trip {x} -> {y}"
            );
        }
    }

    #[test]
    fn kernelized_mask_matches_scalar_reference() {
        // mask rides the fused block kernel; the retained scalar pipeline
        // (scale copy → encode → per-pair full passes) must agree bitwise
        use crate::tensor::kernels::reference;
        let agg = SecureAggregator::new(31);
        let roster = [3u64, 9, 27, 81];
        let mut rng = Rng::new(5);
        let vals: Vec<f32> =
            (0..700).map(|_| rng.normal_f32(0.0, 3.0)).collect();
        for &id in &roster {
            let kernel = agg.mask(id, &roster, &vals);
            let mut streams = Vec::new();
            agg.pair_streams_into(id, &roster, &mut streams);
            let scalar = reference::scale_encode_mask(&vals, 1.0, &mut streams);
            assert_eq!(kernel, scalar, "client {id}");
        }
    }

    #[test]
    fn masks_cancel_exactly() {
        let agg = SecureAggregator::new(42);
        let roster = [10u64, 11, 12, 13];
        let data = [
            vec![1.5f32, -2.0, 0.25],
            vec![0.5, 0.5, 0.5],
            vec![-1.0, 1.0, -1.0],
            vec![10.0, 20.0, 30.0],
        ];
        let masked: Vec<Vec<u64>> = roster
            .iter()
            .zip(&data)
            .map(|(&id, v)| agg.mask(id, &roster, v))
            .collect();
        let sum = SecureAggregator::decode_sum(&SecureAggregator::sum(&masked));
        let want = [11.0f32, 19.5, 29.75];
        for (a, b) in sum.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{sum:?}");
        }
    }

    #[test]
    fn individual_contribution_is_hidden() {
        let agg = SecureAggregator::new(7);
        let roster = [1u64, 2];
        let masked = agg.mask(1, &roster, &[5.0, 5.0, 5.0, 5.0]);
        let plain = [encode(5.0); 4];
        // every lane must differ from the plain encoding (mask applied)
        assert!(masked.iter().zip(&plain).all(|(m, p)| m != p));
        // and lanes must differ from each other (stream, not constant pad)
        assert!(masked.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn single_participant_has_no_masks() {
        let agg = SecureAggregator::new(7);
        let masked = agg.mask(1, &[1], &[2.5]);
        assert_eq!(masked[0], encode(2.5));
    }

    #[test]
    fn dropout_recovery_restores_survivor_sum() {
        let agg = SecureAggregator::new(123);
        let roster = [0u64, 1, 2, 3, 4];
        let data: Vec<Vec<f32>> =
            (0..5).map(|i| vec![i as f32, -(i as f32)]).collect();
        let masked: Vec<Vec<u64>> = roster
            .iter()
            .zip(&data)
            .map(|(&id, v)| agg.mask(id, &roster, v))
            .collect();
        // clients 1 and 3 drop after committing masks
        let survivors = [0u64, 2, 4];
        let dropped = [1u64, 3];
        let mut sum = SecureAggregator::sum(&[
            masked[0].clone(),
            masked[2].clone(),
            masked[4].clone(),
        ]);
        agg.recover(&mut sum, &survivors, &dropped);
        let got = SecureAggregator::decode_sum(&sum);
        let want = [0.0f32 + 2.0 + 4.0, -(0.0 + 2.0 + 4.0)];
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{got:?}");
        }
    }

    #[test]
    fn scalar_aggregation_matches_plain_sum() {
        let agg = SecureAggregator::new(5);
        let inputs: Vec<(u64, f32)> =
            (0..16).map(|i| (i as u64, (i as f32) * 0.125)).collect();
        let want: f32 = inputs.iter().map(|(_, x)| x).sum();
        let got = agg.aggregate_scalars(&inputs);
        assert!((got - want).abs() < 1e-4, "{got} vs {want}");
    }

    #[test]
    fn prop_masked_sum_equals_plain_sum() {
        quick("secure-agg-sum", |rng, case| {
            let n = rng.range(1, 12);
            let d = rng.range(1, 40);
            let agg = SecureAggregator::new(case as u64);
            let roster: Vec<u64> = (0..n as u64).collect();
            let data: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 10.0)).collect())
                .collect();
            let masked: Vec<Vec<u64>> = roster
                .iter()
                .zip(&data)
                .map(|(&id, v)| agg.mask(id, &roster, v))
                .collect();
            let got =
                SecureAggregator::decode_sum(&SecureAggregator::sum(&masked));
            for lane in 0..d {
                let want: f32 = data.iter().map(|v| v[lane]).sum();
                if (got[lane] - want).abs() > 1e-3 {
                    return Err(format!(
                        "lane {lane}: {} vs {want}",
                        got[lane]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn different_rounds_produce_different_masks() {
        let a = SecureAggregator::new(1).mask(0, &[0, 1], &[1.0]);
        let b = SecureAggregator::new(2).mask(0, &[0, 1], &[1.0]);
        assert_ne!(a, b);
    }
}
