//! Update compression operators composed with OCS (the paper's §6
//! future-work direction: "combine our proposed optimal sampling approach
//! with communication compression methods").
//!
//! Two standard unbiased compressors:
//! * [`RandK`] — random-k sparsification (Stich et al., 2018): keep k
//!   coordinates chosen uniformly, scale by d/k.
//! * [`QsgdQuant`] — QSGD-style random dithering (Alistarh et al., 2017)
//!   with `levels` quantization levels.
//!
//! Both satisfy `E[C(x)] = x`, so the FL estimator stays unbiased when a
//! participating client compresses its scaled update.
//!
//! [`Compressor::compress`] produces a **native** [`Payload`] — sparse
//! index/value pairs for RandK, a bit-packed sign+level stream for QSGD
//! — never a dense decompressed-equivalent vector. The dense semantics
//! live in `Payload::densify`, and the fold kernels are bit-exact to
//! them (DESIGN.md §7). Bit accounting: [`Compressor::bits`] is the
//! textbook *estimate* of one compressed vector's uplink cost; the
//! actually-measured cost is `Payload::wire_bytes` (estimate and
//! measurement differ only by the documented framing overhead — see the
//! property test `prop_wire_bytes_track_the_bit_estimate`).
//!
//! ```
//! use fedsamp::compress::Compressor;
//! use fedsamp::util::rng::Rng;
//! let x = vec![1.0f32; 100];
//! let mut rng = Rng::new(7);
//! let c = Compressor::parse("randk10").unwrap();
//! let p = c.compress(&x, &mut rng); // native sparse payload
//! assert_eq!(p.carried(), 10);
//! assert!(p.wire_bytes() < 4 * x.len());
//! assert_eq!(p.densify(x.len()).len(), 100); // dense reference view
//! ```
//!
//! [`RandK`]: Compressor::RandK
//! [`QsgdQuant`]: Compressor::QsgdQuant

use crate::tensor::kernels;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::wire::Payload;

/// An unbiased compression operator.
#[derive(Clone, Debug, PartialEq)]
pub enum Compressor {
    /// No compression: the dense payload, d × 32 bits.
    None,
    /// Random-k sparsification: k × (32 value + 32 index) bits.
    RandK { k: usize },
    /// Random dithering with s levels: sign+level per coordinate plus
    /// one norm float; ⌈log2(s+2)⌉+1 bits per coordinate + 32 (the
    /// level field keeps headroom for the norm-rounding s+1 edge).
    /// `levels` should be ≥ 1 ([`Compressor::parse`] rejects `qsgd0`);
    /// a directly-constructed 0 behaves like 1 level but clamps the
    /// s+1 edge value.
    QsgdQuant { levels: u32 },
}

impl Compressor {
    pub fn name(&self) -> String {
        match self {
            Compressor::None => "none".into(),
            Compressor::RandK { k } => format!("randk{k}"),
            Compressor::QsgdQuant { levels } => format!("qsgd{levels}"),
        }
    }

    /// Parse a [`Compressor::name`]-style spec: `none`, `randk<K>`,
    /// `qsgd<S>` (the CLI `--compress` grammar and the config-file
    /// encoding).
    pub fn parse(spec: &str) -> Result<Compressor, String> {
        if spec == "none" {
            return Ok(Compressor::None);
        }
        if let Some(k) = spec.strip_prefix("randk") {
            if let Ok(k) = k.parse() {
                return Ok(Compressor::RandK { k });
            }
        }
        if let Some(levels) = spec.strip_prefix("qsgd") {
            if let Ok(levels) = levels.parse() {
                // levels = 0 is degenerate: s clamps to 1 but the code
                // width derives from the raw 0, so the norm-rounding
                // s+1 edge value would not be representable — reject it
                // here like the documented k clamp handles RandK
                if levels == 0 {
                    return Err(
                        "qsgd needs at least 1 level (qsgd0 is \
                         degenerate; use qsgd1)"
                            .into(),
                    );
                }
                return Ok(Compressor::QsgdQuant { levels });
            }
        }
        Err(format!(
            "unknown compressor '{spec}' (expected none|randk<K>|qsgd<S>)"
        ))
    }

    pub fn to_json(&self) -> Json {
        Json::str(self.name())
    }

    pub fn from_json(v: &Json) -> Result<Compressor, String> {
        Compressor::parse(
            v.as_str().ok_or("compressor must be a string spec")?,
        )
    }

    /// The number of coordinates one compressed upload of dimension `d`
    /// actually carries — for RandK the single clamp site of the
    /// `k.min(d).max(1)` rule (previously duplicated across the apply
    /// and bit-accounting paths, where it could silently drift).
    pub fn effective_k(&self, d: usize) -> usize {
        match self {
            Compressor::None | Compressor::QsgdQuant { .. } => d,
            Compressor::RandK { k } => (*k).min(d).max(1),
        }
    }

    /// Compress one update into its native wire payload (unbiased:
    /// `E[densify(compress(x))] = x`). Consumes the round RNG exactly as
    /// the historical dense-materializing operator did — `choose_k` for
    /// RandK, one Bernoulli per coordinate for QSGD (none when the norm
    /// is zero) — so trajectories are preserved through the refactor.
    pub fn compress(&self, x: &[f32], rng: &mut Rng) -> Payload {
        match self {
            Compressor::None => Payload::Dense(x.to_vec()),
            Compressor::RandK { .. } => {
                let d = x.len();
                let k = self.effective_k(d);
                let scale = d as f32 / k as f32;
                let mut idx = rng.choose_k(d, k);
                idx.sort_unstable();
                Payload::SparseK {
                    indices: idx.iter().map(|&i| i as u32).collect(),
                    values: idx.iter().map(|&i| x[i] * scale).collect(),
                }
            }
            Compressor::QsgdQuant { levels } => {
                // native bit-packed payload: no dense materialization,
                // no early-return d-length zero vector — a zero norm
                // packs as all-zero code words (level 0, positive sign),
                // which densify to the +0.0s the scalar operator emitted
                let s = (*levels).max(1) as f32;
                let norm = crate::tensor::norm(x) as f32;
                let bits = kernels::qsgd_bits_per_coord(*levels);
                let mut packed =
                    vec![0u64; kernels::qsgd_packed_words(x.len(), *levels)];
                if norm != 0.0 {
                    // the code word has headroom past s: the f32-rounded
                    // norm can land a hair below max|v|, pushing a past
                    // s, and the historical operator then emitted level
                    // s+1 — which always fits (levels+1 < 2^level_bits).
                    // The clamp to the representable max only binds for
                    // non-finite inputs and the degenerate
                    // directly-constructed levels = 0 (rejected by
                    // `parse`; there s = 1 outruns the 1-bit level
                    // field, so the s+1 edge clamps), keeping the
                    // packing safe everywhere `parse` admits without
                    // altering any value the dense operator produced
                    let max_level = (1u64 << (bits - 1)) - 1;
                    for (j, &v) in x.iter().enumerate() {
                        let a = v.abs() / norm * s;
                        let low = a.floor();
                        let p = a - low;
                        let level = (low as u64
                            + u64::from(rng.bernoulli(p as f64)))
                        .min(max_level);
                        let word =
                            (level << 1) | u64::from(v.is_sign_negative());
                        kernels::pack_bits(&mut packed, j, bits, word);
                    }
                }
                Payload::Quantized {
                    dim: x.len() as u32,
                    norm,
                    levels: *levels,
                    packed,
                }
            }
        }
    }

    /// Estimated uplink bits for one compressed vector of dimension d
    /// (the textbook formula). The measured quantity is
    /// `compress(x).wire_bytes()`; the two differ only by the framing
    /// overhead documented in the wire module (≤ 5 bytes for dense and
    /// sparse frames, ≤ 18 bytes for quantized frames, which round the
    /// bit stream up to whole u64 words).
    pub fn bits(&self, d: usize) -> u64 {
        match self {
            Compressor::None => 32 * d as u64,
            Compressor::RandK { .. } => {
                self.effective_k(d) as u64 * (32 + 32)
            }
            Compressor::QsgdQuant { levels } => {
                let bits_per = u64::from(kernels::qsgd_bits_per_coord(*levels));
                32 + bits_per * d as u64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;

    /// Dense view of a compressed payload (the operator's decompressed-
    /// equivalent semantics, shared with the fold kernels).
    fn densify(c: &Compressor, x: &[f32], rng: &mut Rng) -> Vec<f32> {
        c.compress(x, rng).densify(x.len())
    }

    #[test]
    fn none_is_identity() {
        let x = [1.0f32, -2.0, 3.0];
        let mut rng = Rng::new(0);
        let p = Compressor::None.compress(&x, &mut rng);
        assert_eq!(p, Payload::Dense(x.to_vec()));
        assert_eq!(Compressor::None.bits(3), 96);
        assert_eq!(p.wire_bytes(), 5 + 12);
    }

    #[test]
    fn randk_keeps_k_coords_scaled() {
        let x: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        let mut rng = Rng::new(1);
        let p = Compressor::RandK { k: 3 }.compress(&x, &mut rng);
        let Payload::SparseK { indices, values } = &p else {
            panic!("randk must produce a sparse payload")
        };
        assert_eq!(indices.len(), 3);
        assert!(indices.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
        for (&i, &v) in indices.iter().zip(values) {
            assert!((v - x[i as usize] * 10.0 / 3.0).abs() < 1e-5);
        }
        let y = p.densify(10);
        assert_eq!(y.iter().filter(|&&v| v != 0.0).count(), 3);
    }

    #[test]
    fn effective_k_clamps_once_for_both_paths() {
        let c = Compressor::RandK { k: 100 };
        assert_eq!(c.effective_k(10), 10);
        assert_eq!(c.bits(10), 10 * 64);
        let c0 = Compressor::RandK { k: 0 };
        assert_eq!(c0.effective_k(5), 1);
        assert_eq!(c0.bits(5), 64);
        let mut rng = Rng::new(2);
        let p = c0.compress(&[1.0, 2.0, 3.0, 4.0, 5.0], &mut rng);
        assert_eq!(p.carried(), 1);
        assert_eq!(Compressor::QsgdQuant { levels: 4 }.effective_k(7), 7);
        assert_eq!(Compressor::None.effective_k(7), 7);
    }

    #[test]
    fn randk_unbiased() {
        let x: Vec<f32> = (0..16).map(|i| (i as f32) - 8.0).collect();
        let mut rng = Rng::new(2);
        let c = Compressor::RandK { k: 4 };
        let trials = 20_000;
        let mut mean = vec![0.0f64; x.len()];
        for _ in 0..trials {
            for (m, v) in mean.iter_mut().zip(densify(&c, &x, &mut rng)) {
                *m += v as f64;
            }
        }
        for (m, &v) in mean.iter().zip(&x) {
            let avg = m / trials as f64;
            assert!((avg - v as f64).abs() < 0.2, "{avg} vs {v}");
        }
    }

    #[test]
    fn qsgd_unbiased_and_bounded() {
        let x = [0.3f32, -0.7, 1.2, 0.0];
        let c = Compressor::QsgdQuant { levels: 4 };
        let mut rng = Rng::new(3);
        let trials = 40_000;
        let mut mean = vec![0.0f64; 4];
        for _ in 0..trials {
            let y = densify(&c, &x, &mut rng);
            for (m, v) in mean.iter_mut().zip(y) {
                *m += v as f64;
            }
        }
        for (m, &v) in mean.iter().zip(&x) {
            let avg = m / trials as f64;
            assert!((avg - v as f64).abs() < 0.02, "{avg} vs {v}");
        }
    }

    #[test]
    fn qsgd_zero_vector() {
        let mut rng = Rng::new(4);
        let c = Compressor::QsgdQuant { levels: 4 };
        let p = c.compress(&[0.0; 5], &mut rng);
        assert_eq!(densify(&c, &[0.0; 5], &mut rng), vec![0.0; 5]);
        let Payload::Quantized { norm, packed, .. } = p else {
            panic!("qsgd must produce a quantized payload")
        };
        assert_eq!(norm, 0.0);
        assert!(packed.iter().all(|&w| w == 0));
    }

    #[test]
    fn bits_ordering() {
        // with aggressive settings both compressors beat dense f32, on
        // the estimate and on the measured wire
        let d = 10_000;
        let x = vec![1.0f32; d];
        let mut rng = Rng::new(7);
        let dense = Compressor::None;
        for c in [
            Compressor::RandK { k: 100 },
            Compressor::QsgdQuant { levels: 4 },
        ] {
            assert!(c.bits(d) < dense.bits(d), "{}", c.name());
            assert!(
                c.compress(&x, &mut rng).wire_bytes()
                    < dense.compress(&x, &mut rng).wire_bytes(),
                "{} measured",
                c.name()
            );
        }
    }

    #[test]
    fn prop_randk_preserves_support() {
        quick("randk-support", |rng, _| {
            let d = rng.range(1, 64);
            let k = rng.range(1, d + 1);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let y = densify(&Compressor::RandK { k }, &x, rng);
            if y.len() != d {
                return Err("length changed".into());
            }
            let nz = y.iter().filter(|&&v| v != 0.0).count();
            if nz > k {
                return Err(format!("{nz} > k={k}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_wire_bytes_track_the_bit_estimate() {
        // measured bytes ≈ estimated bits / 8: the frame adds a 5-byte
        // header to dense/sparse payloads and ≤ 18 bytes to quantized
        // ones (13-byte header minus the estimate's norm float, plus up
        // to 7 slack bytes rounding the bit stream to u64 words, plus
        // the estimate's own floor-division byte)
        quick("wire-vs-estimate", |rng, _| {
            let d = rng.range(1, 300);
            let x: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let c = match rng.below(3) {
                0 => Compressor::None,
                1 => Compressor::RandK { k: rng.range(1, d + 1) },
                _ => Compressor::QsgdQuant {
                    levels: rng.range(1, 40) as u32,
                },
            };
            let measured = c.compress(&x, rng).wire_bytes() as u64;
            let estimate = c.bits(d) / 8;
            let overhead = match &c {
                Compressor::QsgdQuant { .. } => 18,
                _ => 5,
            };
            if measured >= estimate && measured - estimate <= overhead {
                Ok(())
            } else {
                Err(format!(
                    "{}: measured {measured} vs estimate {estimate}",
                    c.name()
                ))
            }
        });
    }

    #[test]
    fn prop_compressed_payloads_round_trip_the_wire() {
        // real compressor outputs (not just synthetic payloads) survive
        // encode/decode byte-exactly
        quick("compress-wire-round-trip", |rng, _| {
            let d = rng.range(1, 200);
            let x: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            for c in [
                Compressor::None,
                Compressor::RandK { k: rng.range(1, d + 1) },
                Compressor::QsgdQuant { levels: rng.range(1, 16) as u32 },
            ] {
                let p = c.compress(&x, rng);
                let mut frame = Vec::new();
                p.encode_into(&mut frame);
                if frame.len() != p.wire_bytes() {
                    return Err(format!("{}: frame length", c.name()));
                }
                if Payload::decode(&frame)? != p {
                    return Err(format!("{}: round trip", c.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parse_round_trips_names() {
        for c in [
            Compressor::None,
            Compressor::RandK { k: 256 },
            Compressor::QsgdQuant { levels: 4 },
        ] {
            assert_eq!(Compressor::parse(&c.name()).unwrap(), c);
            assert_eq!(Compressor::from_json(&c.to_json()).unwrap(), c);
        }
        assert!(Compressor::parse("topk9").is_err());
        assert!(Compressor::parse("randkx").is_err());
        assert!(Compressor::parse("qsgd0").is_err(), "degenerate levels");
    }
}
