//! Update compression operators composed with OCS (the paper's §6
//! future-work direction: "combine our proposed optimal sampling approach
//! with communication compression methods").
//!
//! Two standard unbiased compressors:
//! * [`RandK`] — random-k sparsification (Stich et al., 2018): keep k
//!   coordinates chosen uniformly, scale by d/k.
//! * [`QsgdQuant`] — QSGD-style random dithering (Alistarh et al., 2017)
//!   with `levels` quantization levels.
//!
//! Both satisfy `E[C(x)] = x`, so the FL estimator stays unbiased when a
//! participating client compresses its scaled update. Bit accounting:
//! [`Compressor::bits`] reports the uplink cost of one compressed vector.

use crate::util::rng::Rng;

/// An unbiased compression operator.
#[derive(Clone, Debug, PartialEq)]
pub enum Compressor {
    /// No compression: d × 32 bits.
    None,
    /// Random-k sparsification: k × (32 value + 32 index) bits.
    RandK { k: usize },
    /// Random dithering with s levels: sign+level per coordinate plus one
    /// norm float; ⌈log2(s+1)⌉+1 bits per coordinate + 32.
    QsgdQuant { levels: u32 },
}

impl Compressor {
    pub fn name(&self) -> String {
        match self {
            Compressor::None => "none".into(),
            Compressor::RandK { k } => format!("randk{k}"),
            Compressor::QsgdQuant { levels } => format!("qsgd{levels}"),
        }
    }

    /// Apply the operator (unbiased): returns the decompressed-equivalent
    /// vector the master will add into the aggregate.
    pub fn apply(&self, x: &[f32], rng: &mut Rng) -> Vec<f32> {
        match self {
            Compressor::None => x.to_vec(),
            Compressor::RandK { k } => {
                let d = x.len();
                let k = (*k).min(d).max(1);
                let mut out = vec![0.0f32; d];
                let scale = d as f32 / k as f32;
                for idx in rng.choose_k(d, k) {
                    out[idx] = x[idx] * scale;
                }
                out
            }
            Compressor::QsgdQuant { levels } => {
                let s = (*levels).max(1) as f32;
                let norm = crate::tensor::norm(x) as f32;
                if norm == 0.0 {
                    return vec![0.0; x.len()];
                }
                x.iter()
                    .map(|&v| {
                        let a = v.abs() / norm * s;
                        let low = a.floor();
                        let p = a - low;
                        let level = low + (rng.bernoulli(p as f64) as u8 as f32);
                        v.signum() * norm * level / s
                    })
                    .collect()
            }
        }
    }

    /// Uplink bits for one compressed vector of dimension d.
    pub fn bits(&self, d: usize) -> u64 {
        match self {
            Compressor::None => 32 * d as u64,
            Compressor::RandK { k } => {
                let k = (*k).min(d).max(1) as u64;
                k * (32 + 32)
            }
            Compressor::QsgdQuant { levels } => {
                let bits_per = 64 - (u64::from(*levels) + 1).leading_zeros() as u64 + 1;
                32 + bits_per * d as u64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;

    #[test]
    fn none_is_identity() {
        let x = [1.0f32, -2.0, 3.0];
        let mut rng = Rng::new(0);
        assert_eq!(Compressor::None.apply(&x, &mut rng), x.to_vec());
        assert_eq!(Compressor::None.bits(3), 96);
    }

    #[test]
    fn randk_keeps_k_coords_scaled() {
        let x: Vec<f32> = (1..=10).map(|i| i as f32).collect();
        let mut rng = Rng::new(1);
        let y = Compressor::RandK { k: 3 }.apply(&x, &mut rng);
        let nz = y.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 3);
        for (i, &v) in y.iter().enumerate() {
            if v != 0.0 {
                assert!((v - x[i] * 10.0 / 3.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn randk_unbiased() {
        let x: Vec<f32> = (0..16).map(|i| (i as f32) - 8.0).collect();
        let mut rng = Rng::new(2);
        let c = Compressor::RandK { k: 4 };
        let trials = 20_000;
        let mut mean = vec![0.0f64; x.len()];
        for _ in 0..trials {
            for (m, v) in mean.iter_mut().zip(c.apply(&x, &mut rng)) {
                *m += v as f64;
            }
        }
        for (m, &v) in mean.iter().zip(&x) {
            let avg = m / trials as f64;
            assert!((avg - v as f64).abs() < 0.2, "{avg} vs {v}");
        }
    }

    #[test]
    fn qsgd_unbiased_and_bounded() {
        let x = [0.3f32, -0.7, 1.2, 0.0];
        let c = Compressor::QsgdQuant { levels: 4 };
        let mut rng = Rng::new(3);
        let trials = 40_000;
        let mut mean = vec![0.0f64; 4];
        for _ in 0..trials {
            let y = c.apply(&x, &mut rng);
            for (m, v) in mean.iter_mut().zip(y) {
                *m += v as f64;
            }
        }
        for (m, &v) in mean.iter().zip(&x) {
            let avg = m / trials as f64;
            assert!((avg - v as f64).abs() < 0.02, "{avg} vs {v}");
        }
    }

    #[test]
    fn qsgd_zero_vector() {
        let mut rng = Rng::new(4);
        let y = Compressor::QsgdQuant { levels: 4 }.apply(&[0.0; 5], &mut rng);
        assert_eq!(y, vec![0.0; 5]);
    }

    #[test]
    fn bits_ordering() {
        // with aggressive settings both compressors beat dense f32
        let d = 10_000;
        assert!(Compressor::RandK { k: 100 }.bits(d) < Compressor::None.bits(d));
        assert!(
            Compressor::QsgdQuant { levels: 4 }.bits(d)
                < Compressor::None.bits(d)
        );
    }

    #[test]
    fn prop_randk_preserves_support() {
        quick("randk-support", |rng, _| {
            let d = rng.range(1, 64);
            let k = rng.range(1, d + 1);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let y = Compressor::RandK { k }.apply(&x, rng);
            if y.len() != d {
                return Err("length changed".into());
            }
            let nz = y.iter().filter(|&&v| v != 0.0).count();
            if nz > k {
                return Err(format!("{nz} > k={k}"));
            }
            Ok(())
        });
    }
}
