//! Metrics recording: per-round records (loss, accuracy, bits, α/γ),
//! run-level series, CSV/JSON export — the data behind every figure.

use std::fmt::Write as _;

use crate::telemetry::TelemetrySummary;
use crate::util::json::Json;

/// One communication round's observables.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// weighted mean local training loss across the cohort
    pub train_loss: f64,
    /// validation accuracy (NaN on non-eval rounds)
    pub val_accuracy: f64,
    /// cumulative client→master uplink bits after this round — kept for
    /// CSV/JSON compatibility; since the estimated→measured switch this
    /// is exactly `uplink_bytes × 8`
    pub uplink_bits: u64,
    /// cumulative client→master uplink bytes after this round, measured
    /// from the encoded length of every wire payload (plus negotiation
    /// scalars at 4 bytes per float)
    pub uplink_bytes: u64,
    /// clients that actually transmitted updates this round
    pub transmitted: usize,
    /// expected budget Σ p_i
    pub expected_budget: f64,
    /// improvement factor α^k (Definition 11)
    pub alpha: f64,
    /// relative improvement factor γ^k (Eq. 16)
    pub gamma: f64,
}

/// A full experiment trajectory.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub name: String,
    pub strategy: String,
    pub rounds: Vec<RoundRecord>,
    /// Telemetry rollup when the run recorded with telemetry enabled
    /// (`None` otherwise — the common case).
    pub telemetry: Option<TelemetrySummary>,
}

impl RunResult {
    pub fn new(name: &str, strategy: &str) -> Self {
        RunResult {
            name: name.into(),
            strategy: strategy.into(),
            rounds: vec![],
            telemetry: None,
        }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.rounds.push(rec);
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find(|r| !r.val_accuracy.is_nan())
            .map(|r| r.val_accuracy)
            .unwrap_or(f64::NAN)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .filter(|r| !r.val_accuracy.is_nan())
            .map(|r| r.val_accuracy)
            .fold(f64::NAN, f64::max)
    }

    pub fn final_train_loss(&self) -> f64 {
        self.rounds.last().map(|r| r.train_loss).unwrap_or(f64::NAN)
    }

    pub fn total_uplink_bits(&self) -> u64 {
        self.rounds.last().map(|r| r.uplink_bits).unwrap_or(0)
    }

    /// Measured cumulative uplink bytes at the end of the run.
    pub fn total_uplink_bytes(&self) -> u64 {
        self.rounds.last().map(|r| r.uplink_bytes).unwrap_or(0)
    }

    /// First round reaching `target` validation accuracy (None if never).
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        self.rounds
            .iter()
            .find(|r| r.val_accuracy >= target)
            .map(|r| r.round)
    }

    /// Uplink bits spent when `target` accuracy was first reached.
    pub fn bits_to_accuracy(&self, target: f64) -> Option<u64> {
        self.rounds
            .iter()
            .find(|r| r.val_accuracy >= target)
            .map(|r| r.uplink_bits)
    }

    /// Measured uplink bytes spent when `target` accuracy was first
    /// reached.
    pub fn bytes_to_accuracy(&self, target: f64) -> Option<u64> {
        self.rounds
            .iter()
            .find(|r| r.val_accuracy >= target)
            .map(|r| r.uplink_bytes)
    }

    /// Mean α over rounds where it was defined.
    pub fn mean_alpha(&self) -> f64 {
        let xs: Vec<f64> = self
            .rounds
            .iter()
            .map(|r| r.alpha)
            .filter(|a| !a.is_nan())
            .collect();
        if xs.is_empty() {
            f64::NAN
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    /// "Current best" accuracy series (Figures 8–12).
    pub fn best_so_far_series(&self) -> Vec<(usize, f64)> {
        let mut best = f64::NAN;
        let mut out = Vec::new();
        for r in &self.rounds {
            if !r.val_accuracy.is_nan() {
                best = if best.is_nan() {
                    r.val_accuracy
                } else {
                    best.max(r.val_accuracy)
                };
                out.push((r.round, best));
            }
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,train_loss,val_accuracy,uplink_bits,uplink_bytes,\
             transmitted,expected_budget,alpha,gamma\n",
        );
        for r in &self.rounds {
            let _ = writeln!(
                s,
                "{},{},{},{},{},{},{},{},{}",
                r.round,
                r.train_loss,
                r.val_accuracy,
                r.uplink_bits,
                r.uplink_bytes,
                r.transmitted,
                r.expected_budget,
                r.alpha,
                r.gamma
            );
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("strategy", Json::str(self.strategy.clone())),
        ];
        if let Some(t) = &self.telemetry {
            pairs.push(("telemetry", t.to_json()));
        }
        pairs.push((
            "rounds",
            Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", Json::num(r.round as f64)),
                                ("train_loss", Json::num(r.train_loss)),
                                ("val_accuracy", Json::num(r.val_accuracy)),
                                ("uplink_bits", Json::num(r.uplink_bits as f64)),
                                (
                                    "uplink_bytes",
                                    Json::num(r.uplink_bytes as f64),
                                ),
                                ("transmitted", Json::num(r.transmitted as f64)),
                                ("expected_budget", Json::num(r.expected_budget)),
                                ("alpha", Json::num(r.alpha)),
                                ("gamma", Json::num(r.gamma)),
                            ])
                        })
                        .collect(),
            ),
        ));
        Json::obj(pairs)
    }

    pub fn save(&self, dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.json", self.name);
        // crash-safe: a kill mid-write must never leave a truncated
        // artifact at the final path (checkpoint::write_atomic)
        crate::checkpoint::write_atomic(&path, self.to_json().to_pretty().as_bytes())
            .map_err(|e| std::io::Error::other(e.to_string()))?;
        Ok(path)
    }
}

/// Average several seeds' runs pointwise (mean over matching rounds) —
/// the paper reports mean ± std over 5 seeds.
pub fn average_runs(runs: &[RunResult]) -> RunResult {
    assert!(!runs.is_empty());
    let n = runs[0].rounds.len();
    assert!(
        runs.iter().all(|r| r.rounds.len() == n),
        "seed runs must align"
    );
    let mut out = RunResult::new(&runs[0].name, &runs[0].strategy);
    // telemetry isn't averaged across seeds (latency distributions don't
    // combine meaningfully pointwise); keep the first seed's rollup
    out.telemetry = runs[0].telemetry.clone();
    for i in 0..n {
        let k = runs.len() as f64;
        let get = |f: &dyn Fn(&RoundRecord) -> f64| -> f64 {
            let vals: Vec<f64> =
                runs.iter().map(|r| f(&r.rounds[i])).filter(|v| !v.is_nan()).collect();
            if vals.is_empty() {
                f64::NAN
            } else {
                vals.iter().sum::<f64>() / vals.len() as f64
            }
        };
        out.push(RoundRecord {
            round: runs[0].rounds[i].round,
            train_loss: get(&|r| r.train_loss),
            val_accuracy: get(&|r| r.val_accuracy),
            // bits derive from the averaged bytes (×8) rather than being
            // averaged independently: integer truncation would otherwise
            // let an averaged record violate uplink_bits == uplink_bytes·8
            uplink_bits: (runs
                .iter()
                .map(|r| r.rounds[i].uplink_bytes)
                .sum::<u64>() as f64
                / k) as u64
                * 8,
            uplink_bytes: (runs
                .iter()
                .map(|r| r.rounds[i].uplink_bytes)
                .sum::<u64>() as f64
                / k) as u64,
            // round to nearest, not floor: seeds transmitting {1, 2}
            // average to 2, matching how the mean reads off a plot
            transmitted: (runs.iter().map(|r| r.rounds[i].transmitted).sum::<usize>()
                as f64
                / k)
                .round() as usize,
            expected_budget: get(&|r| r.expected_budget),
            alpha: get(&|r| r.alpha),
            gamma: get(&|r| r.gamma),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, loss: f64, acc: f64, bits: u64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: loss,
            val_accuracy: acc,
            uplink_bits: bits,
            uplink_bytes: bits / 8,
            transmitted: 3,
            expected_budget: 3.0,
            alpha: 0.5,
            gamma: 0.6,
        }
    }

    #[test]
    fn accuracy_queries() {
        let mut r = RunResult::new("t", "ocs");
        r.push(rec(0, 2.0, f64::NAN, 100));
        r.push(rec(1, 1.5, 0.3, 200));
        r.push(rec(2, 1.0, 0.6, 300));
        r.push(rec(3, 0.9, 0.5, 400));
        assert_eq!(r.final_accuracy(), 0.5);
        assert_eq!(r.best_accuracy(), 0.6);
        assert_eq!(r.rounds_to_accuracy(0.55), Some(2));
        assert_eq!(r.bits_to_accuracy(0.55), Some(300));
        assert_eq!(r.rounds_to_accuracy(0.9), None);
        assert_eq!(r.total_uplink_bits(), 400);
    }

    #[test]
    fn best_so_far_is_monotone() {
        let mut r = RunResult::new("t", "ocs");
        for (i, acc) in [0.2, 0.5, 0.4, 0.7, 0.6].iter().enumerate() {
            r.push(rec(i, 1.0, *acc, 0));
        }
        let series = r.best_so_far_series();
        assert_eq!(series.len(), 5);
        for w in series.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(series.last().unwrap().1, 0.7);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut r = RunResult::new("t", "ocs");
        r.push(rec(0, 2.0, 0.1, 10));
        let csv = r.to_csv();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn json_round_trips_name() {
        let mut r = RunResult::new("myrun", "aocs");
        r.push(rec(0, 2.0, 0.1, 10));
        let j = r.to_json();
        assert_eq!(j.get("name").as_str(), Some("myrun"));
        assert_eq!(j.get("rounds").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn averaging_aligned_runs() {
        let mk = |acc: f64| {
            let mut r = RunResult::new("t", "ocs");
            r.push(rec(0, 1.0, acc, 96));
            r
        };
        let avg = average_runs(&[mk(0.4), mk(0.6)]);
        assert!((avg.rounds[0].val_accuracy - 0.5).abs() < 1e-12);
        assert_eq!(avg.rounds[0].uplink_bytes, 12);
        assert_eq!(avg.rounds[0].uplink_bits, 96);
    }

    #[test]
    fn averaging_keeps_bits_consistent_with_bytes() {
        // odd byte counts across seeds: the averaged record must still
        // satisfy uplink_bits == uplink_bytes × 8 (bits derive from the
        // averaged bytes; independent averaging would truncate apart)
        let mk = |bytes: u64| {
            let mut r = RunResult::new("t", "ocs");
            r.push(RoundRecord {
                round: 0,
                train_loss: 1.0,
                val_accuracy: 0.5,
                uplink_bits: bytes * 8,
                uplink_bytes: bytes,
                transmitted: 1,
                expected_budget: 1.0,
                alpha: 0.5,
                gamma: 0.6,
            });
            r
        };
        let avg = average_runs(&[mk(9), mk(10)]);
        assert_eq!(
            avg.rounds[0].uplink_bits,
            avg.rounds[0].uplink_bytes * 8
        );
        assert_eq!(avg.rounds[0].uplink_bytes, 9); // floor(19/2)
    }

    #[test]
    fn averaging_rounds_transmitted_to_nearest() {
        // regression: floor-division used to turn seeds transmitting
        // {1, 2} into an average of 1; round-to-nearest reports 2
        let mk = |transmitted: usize| {
            let mut r = RunResult::new("t", "ocs");
            r.push(RoundRecord {
                round: 0,
                train_loss: 1.0,
                val_accuracy: 0.5,
                uplink_bits: 80,
                uplink_bytes: 10,
                transmitted,
                expected_budget: 1.5,
                alpha: 0.5,
                gamma: 0.6,
            });
            r
        };
        let avg = average_runs(&[mk(1), mk(2)]);
        assert_eq!(avg.rounds[0].transmitted, 2, "1.5 rounds to 2");
        let avg = average_runs(&[mk(1), mk(1), mk(2)]);
        assert_eq!(avg.rounds[0].transmitted, 1, "4/3 rounds to 1");
        let avg = average_runs(&[mk(3), mk(3)]);
        assert_eq!(avg.rounds[0].transmitted, 3, "exact mean unchanged");
    }

    #[test]
    fn json_carries_telemetry_only_when_present() {
        let mut r = RunResult::new("t", "ocs");
        r.push(rec(0, 2.0, 0.1, 80));
        assert_eq!(r.to_json().get("telemetry"), &Json::Null);
        r.telemetry = Some(TelemetrySummary {
            rounds: 1,
            phases: vec![],
            job_exec: vec![],
            job_queue: vec![],
            job_items: vec![],
            payload_bytes: crate::util::stats::LogSummary::empty(),
            counters: vec![("clients_transmitted", 7)],
        });
        let j = r.to_json();
        assert_eq!(
            j.get("telemetry").get("rounds").as_usize(),
            Some(1)
        );
        assert_eq!(
            j.get("telemetry")
                .get("counters")
                .get("clients_transmitted")
                .as_f64(),
            Some(7.0)
        );
    }

    #[test]
    fn measured_bytes_drive_identical_bit_trajectories() {
        // the estimated→measured regression gate: the meter now writes
        // uplink_bits as uplink_bytes × 8, so every bit-axis query must
        // be exactly the byte-axis query × 8 — the switch cannot change
        // any reported trajectory shape
        let mut r = RunResult::new("t", "ocs");
        for (i, (acc, bytes)) in
            [(f64::NAN, 50u64), (0.3, 120), (0.6, 300), (0.5, 410)]
                .into_iter()
                .enumerate()
        {
            r.push(RoundRecord {
                round: i,
                train_loss: 1.0,
                val_accuracy: acc,
                uplink_bits: bytes * 8, // what BitMeter::total_bits emits
                uplink_bytes: bytes,
                transmitted: 2,
                expected_budget: 2.0,
                alpha: 0.5,
                gamma: 0.6,
            });
        }
        assert_eq!(r.total_uplink_bits(), r.total_uplink_bytes() * 8);
        for target in [0.2, 0.55, 0.9] {
            assert_eq!(
                r.bits_to_accuracy(target),
                r.bytes_to_accuracy(target).map(|b| b * 8),
                "target {target}"
            );
        }
        assert_eq!(r.bits_to_accuracy(0.55), Some(300 * 8));
        assert_eq!(r.rounds_to_accuracy(0.55), Some(2));
    }

    #[test]
    fn csv_and_json_carry_measured_bytes() {
        let mut r = RunResult::new("t", "ocs");
        r.push(rec(0, 2.0, 0.1, 80));
        let csv = r.to_csv();
        assert!(csv.starts_with("round,"));
        assert!(csv.contains("uplink_bits"), "legacy column kept");
        assert!(csv.contains("uplink_bytes"), "measured column added");
        let j = r.to_json();
        let row = &j.get("rounds").as_arr().unwrap()[0];
        assert_eq!(row.get("uplink_bits").as_f64(), Some(80.0));
        assert_eq!(row.get("uplink_bytes").as_f64(), Some(10.0));
    }

    #[test]
    fn empty_run_queries_are_nan() {
        let r = RunResult::new("t", "ocs");
        assert!(r.final_accuracy().is_nan());
        assert!(r.final_train_loss().is_nan());
        assert_eq!(r.total_uplink_bits(), 0);
    }
}
