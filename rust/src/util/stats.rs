//! Statistics substrate: summary stats, Welford online accumulation,
//! histograms, and simple timing aggregation for the bench harness.

/// Summary of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
}

/// Compute a [`Summary`] of `xs` (empty input yields NaN fields, n=0).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
            median: f64::NAN,
            p10: f64::NAN,
            p90: f64::NAN,
        };
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = if xs.len() > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (xs.len() - 1) as f64
    } else {
        0.0
    };
    Summary {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: *sorted.last().unwrap(),
        median: percentile_sorted(&sorted, 0.5),
        p10: percentile_sorted(&sorted, 0.1),
        p90: percentile_sorted(&sorted, 0.9),
    }
}

/// Linear-interpolated percentile of pre-sorted data; q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-width histogram over [lo, hi); out-of-range values clamp to the
/// edge buckets. Used for Figure 2 (client-size distributions).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<usize>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Histogram { lo, hi, counts: vec![0; buckets] }
    }

    pub fn push(&mut self, x: f64) {
        let b = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * b as f64) as isize;
        let idx = t.clamp(0, b as isize - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Render as a fixed-width ASCII bar chart (for bench/figure output).
    pub fn ascii(&self, width: usize) -> String {
        let maxc = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bw = (self.hi - self.lo) / self.counts.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat(c * width / maxc);
            out.push_str(&format!(
                "{:>8.1}-{:<8.1} |{:<width$}| {}\n",
                self.lo + i as f64 * bw,
                self.lo + (i + 1) as f64 * bw,
                bar,
                c,
                width = width
            ));
        }
        out
    }
}

/// Quantile summary of a [`LogHistogram`] (durations in ns, sizes in
/// bytes — whatever unit was recorded).
#[derive(Clone, Debug, PartialEq)]
pub struct LogSummary {
    pub n: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: u64,
}

impl LogSummary {
    pub fn empty() -> LogSummary {
        LogSummary { n: 0, mean: 0.0, p50: 0.0, p90: 0.0, p99: 0.0, max: 0 }
    }
}

/// Log2-bucketed histogram over `u64` magnitudes. Bucket `b` holds
/// values in `[2^(b-1), 2^b)` (bucket 0 holds exactly 0), so recording
/// is a `leading_zeros` plus one array increment — no allocation, fixed
/// 65-slot footprint — which is what lets `ShardPool` workers record
/// per-job latencies on the hot path. Quantiles interpolate linearly
/// within a bucket and are clamped by the exact tracked max, keeping
/// relative error below ~2x in the worst case and far tighter near the
/// top of the distribution.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; 65],
    n: u64,
    sum: u128,
    max: u64,
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram { counts: [0; 65], n: 0, sum: 0, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        let b = (64 - v.leading_zeros()) as usize;
        self.counts[b] += 1;
        self.n += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn total(&self) -> u128 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Interpolated quantile, q in [0,1]; 0.0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.n as f64;
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = cum as f64;
            cum += c;
            if cum as f64 >= target {
                let lo = if b <= 1 { 0.0 } else { (1u128 << (b - 1)) as f64 };
                let hi = if b >= 64 {
                    u64::MAX as f64
                } else {
                    (1u128 << b) as f64
                };
                let hi = hi.min(self.max as f64).max(lo);
                let frac = ((target - before) / c as f64).clamp(0.0, 1.0);
                return lo + frac * (hi - lo);
            }
        }
        self.max as f64
    }

    pub fn summary(&self) -> LogSummary {
        LogSummary {
            n: self.n,
            mean: self.mean(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            max: self.max,
        }
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.5), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let s = summarize(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-12);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        for x in [0.5, 1.5, 9.9, -4.0, 42.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.counts[0], 3); // 0.5, 1.5, -4.0(clamped)
        assert_eq!(h.counts[4], 2); // 9.9, 42.0(clamped)
    }

    #[test]
    fn log_histogram_empty() {
        let h = LogHistogram::new();
        assert_eq!(h.n(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        let s = h.summary();
        assert_eq!(s, LogSummary::empty());
    }

    #[test]
    fn log_histogram_exact_stats() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 2, 3, 1000, 1_000_000] {
            h.record(v);
        }
        assert_eq!(h.n(), 6);
        assert_eq!(h.max(), 1_000_000);
        assert_eq!(h.total(), 1_001_006);
        assert!((h.mean() - 1_001_006.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn log_histogram_quantiles_ordered_and_bounded() {
        let mut h = LogHistogram::new();
        for i in 1..=1000u64 {
            h.record(i);
        }
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        assert!(p99 <= h.max() as f64);
        // log2 bucketing: quantiles within ~2x of the true value.
        assert!(p50 >= 250.0 && p50 <= 1000.0, "p50={p50}");
        assert!(p99 >= 500.0, "p99={p99}");
    }

    #[test]
    fn log_histogram_single_value_collapses() {
        let mut h = LogHistogram::new();
        for _ in 0..10 {
            h.record(7);
        }
        // Every quantile stays inside the value's bucket [4, 7].
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!((4.0..=7.0).contains(&v), "q={q} v={v}");
        }
        assert_eq!(h.max(), 7);
    }

    #[test]
    fn log_histogram_merge_matches_combined() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut c = LogHistogram::new();
        for v in [1u64, 5, 9, 100] {
            a.record(v);
            c.record(v);
        }
        for v in [2u64, 800, 4096] {
            b.record(v);
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.n(), c.n());
        assert_eq!(a.total(), c.total());
        assert_eq!(a.max(), c.max());
        assert_eq!(a.quantile(0.5), c.quantile(0.5));
    }
}
