//! Statistics substrate: summary stats, Welford online accumulation,
//! histograms, and simple timing aggregation for the bench harness.

/// Summary of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
}

/// Compute a [`Summary`] of `xs` (empty input yields NaN fields, n=0).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
            median: f64::NAN,
            p10: f64::NAN,
            p90: f64::NAN,
        };
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = if xs.len() > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (xs.len() - 1) as f64
    } else {
        0.0
    };
    Summary {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: *sorted.last().unwrap(),
        median: percentile_sorted(&sorted, 0.5),
        p10: percentile_sorted(&sorted, 0.1),
        p90: percentile_sorted(&sorted, 0.9),
    }
}

/// Linear-interpolated percentile of pre-sorted data; q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-width histogram over [lo, hi); out-of-range values clamp to the
/// edge buckets. Used for Figure 2 (client-size distributions).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<usize>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Histogram { lo, hi, counts: vec![0; buckets] }
    }

    pub fn push(&mut self, x: f64) {
        let b = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * b as f64) as isize;
        let idx = t.clamp(0, b as isize - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Render as a fixed-width ASCII bar chart (for bench/figure output).
    pub fn ascii(&self, width: usize) -> String {
        let maxc = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bw = (self.hi - self.lo) / self.counts.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat(c * width / maxc);
            out.push_str(&format!(
                "{:>8.1}-{:<8.1} |{:<width$}| {}\n",
                self.lo + i as f64 * bw,
                self.lo + (i + 1) as f64 * bw,
                bar,
                c,
                width = width
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.5), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let s = summarize(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-12);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        for x in [0.5, 1.5, 9.9, -4.0, 42.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.counts[0], 3); // 0.5, 1.5, -4.0(clamped)
        assert_eq!(h.counts[4], 2); // 9.9, 42.0(clamped)
    }
}
