//! Minimal JSON substrate (no serde available offline).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null) with a recursive-descent parser and a writer with
//! compact + pretty modes. Used for `artifacts/manifest.json`, experiment
//! configs, and metrics output.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so output
/// is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------ accessors -----------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Builders.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ------------------------------- parsing ------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ------------------------------- writing ------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; emit null (documented lossy behaviour)
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hx = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Bool(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let s = "line\nquote\"back\\slash\ttab\u{1F600}";
        let v = Json::Str(s.to_string());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn unicode_escape_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn round_trip_pretty_and_compact() {
        let text = r#"{"models":{"m":{"params":[{"name":"w0","shape":[784,256]}],"n":241854}},"v":1.5}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "\"unterminated", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
    }

    #[test]
    fn parses_real_manifest() {
        let text = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"),
        );
        if let Ok(text) = text {
            let v = Json::parse(&text).expect("manifest parses");
            assert!(v.get("models").as_obj().is_some());
        }
    }
}
