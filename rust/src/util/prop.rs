//! Mini property-based testing substrate (no proptest available offline).
//!
//! `check` runs a property over many seeded random cases and, on failure,
//! reports the failing case index + seed so the case can be replayed
//! deterministically. Generators are just closures over [`Rng`].

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 200, seed: 0xFED5_A310 }
    }
}

/// Run `property(case_rng, case_index)`; panic with replay info on failure.
///
/// The property should itself `assert!`/`panic!` on violation; returning
/// `Err(msg)` is also supported for nicer messages.
pub fn check<F>(name: &str, cfg: Config, mut property: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut rng = root.fork(case as u64);
        if let Err(msg) = property(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed {:#x}): {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience: run with default config.
pub fn quick<F>(name: &str, property: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    check(name, Config::default(), property)
}

/// Generator helpers -------------------------------------------------------

/// Random vector of length in [1, max_len] with values from `gen`.
pub fn vec_f64(
    rng: &mut Rng,
    max_len: usize,
    gen: impl Fn(&mut Rng) -> f64,
) -> Vec<f64> {
    let n = rng.range(1, max_len + 1);
    (0..n).map(|_| gen(rng)).collect()
}

/// Non-negative "update norm"-like values: mixture of zeros, small and
/// heavy-tailed entries — the shapes OCS cares about.
pub fn norm_profile(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n)
        .map(|_| match rng.below(10) {
            0..=1 => 0.0,
            2..=6 => rng.f64(),
            _ => rng.exponential(0.2),
        })
        .collect()
}

/// Simplex weights (w_i >= 0, sum = 1).
pub fn simplex(rng: &mut Rng, n: usize) -> Vec<f64> {
    rng.dirichlet(1.0, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        quick("sum-commutes", |rng, _| {
            let xs = vec_f64(rng, 20, |r| r.f64());
            let a: f64 = xs.iter().sum();
            let b: f64 = xs.iter().rev().sum();
            if (a - b).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("{a} vs {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failure_with_case() {
        check("always-fails", Config { cases: 3, seed: 1 }, |_, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn norm_profile_non_negative() {
        quick("norm-profile", |rng, _| {
            let p = norm_profile(rng, 50);
            if p.iter().all(|&x| x >= 0.0) {
                Ok(())
            } else {
                Err("negative norm".into())
            }
        });
    }

    #[test]
    fn simplex_sums_to_one() {
        quick("simplex", |rng, _| {
            let w = simplex(rng, 12);
            if (w.iter().sum::<f64>() - 1.0).abs() < 1e-9 {
                Ok(())
            } else {
                Err("not a simplex".into())
            }
        });
    }
}
