//! Substrate utilities built in-tree (the offline environment provides no
//! rand/serde/clap/criterion — see DESIGN.md §2).

pub mod args;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
