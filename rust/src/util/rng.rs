//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! `Rng` is xoshiro256++ seeded through splitmix64 — the standard pairing:
//! splitmix64 decorrelates arbitrary user seeds, xoshiro256++ provides the
//! stream. All federated experiments derive per-client / per-round
//! sub-streams via [`Rng::fork`] so runs are reproducible regardless of
//! thread scheduling.

/// splitmix64 step — used for seeding and stream splitting.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with gaussian / categorical / subset helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached spare gaussian from the polar Box-Muller transform
    spare: Option<f64>,
}

impl Rng {
    /// Construct from an arbitrary seed (splitmix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child stream (for per-client / per-round use).
    /// Mixing a label keeps forks order-independent.
    pub fn fork(&self, label: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ label.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Fill `out` with the exact [`Rng::next_u64`] sequence, unrolled in
    /// 8-draw chunks — the block form the secure-aggregation mask
    /// kernels consume. The generator is serially state-dependent, so
    /// this is not SIMD; the win is keeping the state register-resident
    /// across a block and decoupling draw production from the masked
    /// vector walk. Stream-identical to `out.len()` scalar calls: after
    /// the fill, the generator state equals the scalar walk's, so blocks
    /// of any size can be mixed freely with scalar draws.
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            for v in c.iter_mut() {
                *v = self.next_u64();
            }
        }
        for v in chunks.into_remainder() {
            *v = self.next_u64();
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via polar Box-Muller with spare caching.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// N(mu, sigma^2) as f32.
    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.gaussian() as f32
    }

    /// Exponential with rate lambda.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Sample from Gamma(shape, 1) — Marsaglia-Tsang; used for Dirichlet.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost trick
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gaussian();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over k categories.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s > 0.0 {
            for x in &mut g {
                *x /= s;
            }
        }
        g
    }

    /// Categorical draw from (unnormalized, non-negative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero mass");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k k>n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn prop_fill_u64_is_stream_identical_for_arbitrary_splits() {
        use crate::util::prop::quick;
        quick("rng-fill-u64", |rng, _| {
            let n = rng.range(0, 200);
            let seed = rng.next_u64();
            let mut blocked = Rng::new(seed);
            let mut scalar = Rng::new(seed);
            // fill in arbitrary-sized blocks (split points chosen by the
            // case rng), compare against the per-call scalar stream
            let mut got = vec![0u64; n];
            let mut i = 0;
            while i < n {
                let step = rng.range(1, n - i + 1);
                blocked.fill_u64(&mut got[i..i + step]);
                i += step;
            }
            for (j, g) in got.iter().enumerate() {
                if *g != scalar.next_u64() {
                    return Err(format!("lane {j} diverged"));
                }
            }
            // and the states must stay aligned after the fills
            if blocked.next_u64() != scalar.next_u64() {
                return Err("post-fill state diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn forks_are_independent_and_stable() {
        let root = Rng::new(1);
        let mut c0 = root.fork(0);
        let mut c1 = root.fork(1);
        let mut c0b = root.fork(0);
        assert_eq!(c0.next_u64(), c0b.next_u64());
        assert_ne!(c0.next_u64(), c1.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(9);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 8);
            assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        let n = 40_000;
        let m: f64 = (0..n).map(|_| r.gamma(3.0)).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "gamma mean {m}");
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = Rng::new(17);
        let ks = r.choose_k(100, 10);
        assert_eq!(ks.len(), 10);
        let mut sorted = ks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        assert!(ks.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(23);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "{counts:?}");
    }
}
