//! CLI argument substrate (no clap available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positionals,
//! and subcommands, with typed getters and generated usage text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option for usage text + validation.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI parser.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
}

/// Parse result: option map + positionals.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli { program: program.into(), about: about.into(), opts: Vec::new() }
    }

    /// Declare a value option with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: default.map(String::from),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nOptions:");
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <value>", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "{head:<32} {}{def}", o.help);
        }
        s
    }

    /// Parse an argv slice (excluding the program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut out = Parsed::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    out.flags.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    out.values.insert(name, v);
                }
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn str(&self, name: &str) -> String {
        self.values
            .get(name)
            .cloned()
            .unwrap_or_else(|| panic!("missing option --{name}"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an unsigned int"))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a u64"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a float"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list of usizes, e.g. "3,6,12".
    pub fn usize_list(&self, name: &str) -> Vec<usize> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name}: bad int '{s}'"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("test", "t")
            .opt("rounds", Some("10"), "rounds")
            .opt("name", None, "name")
            .flag("verbose", "talk")
    }

    #[test]
    fn defaults_apply() {
        let p = cli().parse(&argv(&[])).unwrap();
        assert_eq!(p.usize("rounds"), 10);
        assert!(!p.flag("verbose"));
        assert_eq!(p.get("name"), None);
    }

    #[test]
    fn space_and_equals_forms() {
        let p = cli().parse(&argv(&["--rounds", "5", "--name=x"])).unwrap();
        assert_eq!(p.usize("rounds"), 5);
        assert_eq!(p.get("name"), Some("x"));
    }

    #[test]
    fn flags_and_positionals() {
        let p = cli().parse(&argv(&["run", "--verbose", "extra"])).unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.positionals, vec!["run", "extra"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse(&argv(&["--rounds"])).is_err());
    }

    #[test]
    fn usize_list_parses() {
        let c = Cli::new("t", "t").opt("ms", Some("3,6,12"), "");
        let p = c.parse(&argv(&[])).unwrap();
        assert_eq!(p.usize_list("ms"), vec![3, 6, 12]);
    }
}
