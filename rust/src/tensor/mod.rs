//! Flat f32 vector math used on the coordinator hot path (update norms,
//! weighted aggregation, parameter updates).
//!
//! Everything here operates on `&[f32]` so the same code path serves the
//! rust-native sim models and the PJRT-backed parameter vectors. The hot
//! functions delegate to the blocked/unrolled [`kernels`] layer (scalar
//! references and measured speedups: EXPERIMENTS.md §Perf); this module
//! keeps the small assorted helpers and the stable call-site names.
//! [`dispatch`] selects the kernel backend (blocked scalar vs AVX2) once
//! per process — DESIGN.md §12.

pub mod dispatch;
pub mod kernels;

/// Squared L2 norm. f64 accumulators: client updates can have ~1e6
/// entries and the norm drives sampling probabilities, so precision
/// matters. 8-lane unrolled ([`kernels::norm_sq`]).
pub fn norm_sq(x: &[f32]) -> f64 {
    kernels::norm_sq(x)
}

/// L2 norm.
pub fn norm(x: &[f32]) -> f64 {
    norm_sq(x).sqrt()
}

/// y += a * x (the aggregation primitive: `Δx += (w_i/p_i)·Δ_i`).
/// Unrolled; bit-identical to the scalar loop ([`kernels::axpy`]).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    kernels::axpy(y, a, x);
}

/// y = a * y.
pub fn scale(y: &mut [f32], a: f32) {
    for yi in y.iter_mut() {
        *yi *= a;
    }
}

/// out = a - b (elementwise); used for Δ_i = x^k − y_i.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len(), "sub length mismatch");
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Allocation-free [`sub`]: out = a - b into a caller-owned buffer (the
/// FedAvg delta computation writes into its outcome buffer directly).
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    kernels::sub_into(out, a, b);
}

/// In-place a -= b.
pub fn sub_assign(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "sub_assign length mismatch");
    for (x, y) in a.iter_mut().zip(b) {
        *x -= y;
    }
}

/// Dot product with f64 accumulators, 8-lane unrolled
/// ([`kernels::dot`]).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    kernels::dot(a, b)
}

/// Squared distance ‖a − b‖².
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "dist_sq length mismatch");
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc
}

/// True iff every entry is finite (NaN/Inf guard after aggregation).
pub fn all_finite(x: &[f32]) -> bool {
    x.iter().all(|v| v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{quick, vec_f64};

    #[test]
    fn norms_and_dot() {
        let x = [3.0f32, 4.0];
        assert!((norm(&x) - 5.0).abs() < 1e-9);
        assert!((norm_sq(&x) - 25.0).abs() < 1e-9);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_and_scale() {
        let mut y = vec![1.0f32, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![10.5, 21.0]);
    }

    #[test]
    fn sub_ops() {
        let a = [5.0f32, 7.0];
        let b = [1.0f32, 2.0];
        assert_eq!(sub(&a, &b), vec![4.0, 5.0]);
        let mut out = [0.0f32; 2];
        sub_into(&mut out, &a, &b);
        assert_eq!(out.to_vec(), sub(&a, &b));
        let mut c = a;
        sub_assign(&mut c, &b);
        assert_eq!(c.to_vec(), vec![4.0, 5.0]);
        assert!((dist_sq(&a, &b) - 41.0).abs() < 1e-9);
    }

    #[test]
    fn finite_guard() {
        assert!(all_finite(&[1.0, -2.0]));
        assert!(!all_finite(&[1.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }

    #[test]
    #[should_panic(expected = "axpy length mismatch")]
    fn axpy_length_checked() {
        axpy(&mut [0.0], 1.0, &[1.0, 2.0]);
    }

    #[test]
    fn prop_triangle_inequality() {
        quick("norm-triangle", |rng, _| {
            let xs: Vec<f32> =
                vec_f64(rng, 64, |r| r.gaussian()).iter().map(|&v| v as f32).collect();
            let ys: Vec<f32> =
                (0..xs.len()).map(|_| rng.gaussian() as f32).collect();
            let sum: Vec<f32> = xs.iter().zip(&ys).map(|(a, b)| a + b).collect();
            if norm(&sum) <= norm(&xs) + norm(&ys) + 1e-6 {
                Ok(())
            } else {
                Err("triangle violated".into())
            }
        });
    }
}
