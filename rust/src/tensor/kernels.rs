//! Blocked, unrolled f32 compute kernels for every arithmetic hot loop
//! in the crate (see EXPERIMENTS.md §Perf for the measured speedups and
//! DESIGN.md §5 for the exactness contracts).
//!
//! Two contracts coexist here:
//!
//! * **Bit-exactness** where the secure-aggregation ring or the seed
//!   trajectory demands it: every kernel accumulates each output element
//!   in exactly the order the scalar reference does (ascending index,
//!   member order), so [`axpy`], [`accumulate`], [`weighted_accumulate`],
//!   [`wrapping_accumulate`], [`gemm_block`] and [`rank1_accumulate`]
//!   are drop-in bit-identical replacements — blocking reorders *loops*,
//!   never the per-element addition sequence.
//! * **Tolerance (≤ 1e-6 relative)** where reductions may re-associate
//!   for speed: [`norm_sq`], [`dot`] and [`axpy_norm_sq`] run 8 partial
//!   f64 accumulators, which changes the summation tree (and improves
//!   accuracy) relative to the sequential fold.
//!
//! The scalar references live in [`reference`] and stay the baseline arm
//! of `benches/micro_kernels.rs` / `fedsamp bench kernels`.
//!
//! **Backend dispatch.** Every kernel below first consults
//! [`super::dispatch`]: when the SIMD backend is active (AVX2 detected
//! and selected — see `--kernel-backend` and DESIGN.md §12) the hot
//! loops run the explicit-intrinsics implementations in
//! `dispatch::avx2`, which are constructed to be bit-identical to the
//! blocked scalar bodies here (same per-element op order, same lane
//! accumulator layout, same [`fold`] tree, no FMA). The scalar bodies
//! remain the default and the pinned reference.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use super::dispatch;
use crate::util::rng::Rng;

/// Route a kernel call to the AVX2 backend when it is active. Expands
/// to nothing on builds without the `simd` feature or off x86_64, so
/// the scalar body below is the whole function there.
macro_rules! simd_dispatch {
    ($($call:tt)*) => {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            if dispatch::simd_on() {
                // SAFETY: simd_on() is true only after runtime AVX2
                // detection (dispatch::select / init_from_env).
                return unsafe { dispatch::avx2::$($call)* };
            }
        }
    };
}

/// Elements per unrolled lane group. Eight f32 lanes fill a 256-bit
/// vector register; LLVM maps the fixed-size chunk bodies to packed ops.
const LANES: usize = 8;

/// Chunk length (elements) for member-inner accumulation: small enough
/// that one chunk of the accumulator plus one chunk per member stays in
/// L1 while every member is folded in, large enough to amortize the
/// outer loop.
const CHUNK: usize = 1024;

/// k-block length for the GEMM kernels: a block of `b` rows
/// (`KC × n` floats) is reused across every output row before moving on.
const KC: usize = 64;

// ---------------------------------------------------------------------------
// reductions (tolerance contract: 8 partial f64 accumulators)
// ---------------------------------------------------------------------------

/// Squared L2 norm, 8-lane unrolled with f64 partial accumulators.
pub fn norm_sq(x: &[f32]) -> f64 {
    simd_dispatch!(norm_sq(x));
    let mut acc = [0.0f64; LANES];
    let mut chunks = x.chunks_exact(LANES);
    for c in &mut chunks {
        for (a, &v) in acc.iter_mut().zip(c) {
            *a += (v as f64) * v as f64;
        }
    }
    let mut tail = 0.0f64;
    for &v in chunks.remainder() {
        tail += (v as f64) * v as f64;
    }
    fold(&acc) + tail
}

/// Dot product, 8-lane unrolled with f64 partial accumulators.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    simd_dispatch!(dot(a, b));
    let mut acc = [0.0f64; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (xs, ys) in (&mut ac).zip(&mut bc) {
        for ((s, &x), &y) in acc.iter_mut().zip(xs).zip(ys) {
            *s += (x as f64) * y as f64;
        }
    }
    let mut tail = 0.0f64;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += (x as f64) * y as f64;
    }
    fold(&acc) + tail
}

/// Pairwise fold of the lane accumulators (fixed tree, deterministic).
/// Shared with `dispatch::avx2` so both backends reduce their 8 lane
/// sums through the identical tree — the keystone of the reductions'
/// bit-exactness across backends.
#[inline]
pub(crate) fn fold(acc: &[f64; LANES]) -> f64 {
    ((acc[0] + acc[4]) + (acc[2] + acc[6]))
        + ((acc[1] + acc[5]) + (acc[3] + acc[7]))
}

// ---------------------------------------------------------------------------
// elementwise updates (bit-exact contract)
// ---------------------------------------------------------------------------

/// y += a * x, 8-lane unrolled. Per-element ops identical to the scalar
/// loop (ascending index, one fused expression per element).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "axpy length mismatch");
    simd_dispatch!(axpy(y, a, x));
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for (yi, &xi) in yb.iter_mut().zip(xb) {
            *yi += a * xi;
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += a * xi;
    }
}

/// y += x (the unit-weight accumulation step), 8-lane unrolled.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len(), "add_assign length mismatch");
    simd_dispatch!(add_assign(y, x));
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for (yi, &xi) in yb.iter_mut().zip(xb) {
            *yi += xi;
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += xi;
    }
}

/// out = a − b, 8-lane unrolled (the `Δ_i = x^k − y_i` kernel).
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len(), "sub_into length mismatch");
    assert_eq!(a.len(), b.len(), "sub_into length mismatch");
    simd_dispatch!(sub_into(out, a, b));
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((ob, ab), bb) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for ((o, &x), &y) in ob.iter_mut().zip(ab).zip(bb) {
            *o = x - y;
        }
    }
    for ((o, &x), &y) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o = x - y;
    }
}

/// Fused `y += a·x` + squared norm of the *updated* y, one pass.
///
/// The master-update kernel: commit applies the aggregate and needs a
/// finiteness verdict on the result; the returned Σ y'² is finite iff
/// every updated entry is (any NaN/Inf poisons the f64 sum, and finite
/// f32 squares cannot overflow f64).
pub fn axpy_norm_sq(y: &mut [f32], a: f32, x: &[f32]) -> f64 {
    assert_eq!(y.len(), x.len(), "axpy_norm_sq length mismatch");
    simd_dispatch!(axpy_norm_sq(y, a, x));
    let mut acc = [0.0f64; LANES];
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for ((yi, &xi), s) in yb.iter_mut().zip(xb).zip(acc.iter_mut()) {
            *yi += a * xi;
            *s += (*yi as f64) * *yi as f64;
        }
    }
    let mut tail = 0.0f64;
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += a * xi;
        tail += (*yi as f64) * *yi as f64;
    }
    fold(&acc) + tail
}

/// out = a ⊙ (x − c) (diagonal-curvature gradient), fused elementwise.
pub fn scaled_diff(out: &mut [f32], a: &[f32], x: &[f32], c: &[f32]) {
    assert_eq!(out.len(), a.len(), "scaled_diff length mismatch");
    assert_eq!(a.len(), x.len(), "scaled_diff length mismatch");
    assert_eq!(x.len(), c.len(), "scaled_diff length mismatch");
    for (((o, &ai), &xi), &ci) in
        out.iter_mut().zip(a).zip(x).zip(c)
    {
        *o = ai * (xi - ci);
    }
}

// ---------------------------------------------------------------------------
// chunked accumulation (bit-exact contract)
// ---------------------------------------------------------------------------

/// acc += Σ_v vecs[v], chunked member-inner: one `CHUNK`-long window of
/// the accumulator is folded over *every* member before moving on, so
/// the window stays cache-hot across members. Per element, members are
/// added in slice order — bit-identical to folding each member with
/// [`add_assign`] sequentially.
pub fn accumulate(acc: &mut [f32], vecs: &[&[f32]]) {
    for v in vecs {
        assert_eq!(v.len(), acc.len(), "accumulate length mismatch");
    }
    let n = acc.len();
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + CHUNK).min(n);
        for v in vecs {
            add_assign(&mut acc[j0..j1], &v[j0..j1]);
        }
        j0 = j1;
    }
}

/// acc += Σ_v w[v] · vecs[v], chunked member-inner (same windowing and
/// the same bit-exactness argument as [`accumulate`], with one fused
/// multiply per element).
pub fn weighted_accumulate(acc: &mut [f32], vecs: &[&[f32]], weights: &[f32]) {
    assert_eq!(vecs.len(), weights.len(), "weighted_accumulate arity");
    for v in vecs {
        assert_eq!(v.len(), acc.len(), "weighted_accumulate length mismatch");
    }
    let n = acc.len();
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + CHUNK).min(n);
        for (v, &w) in vecs.iter().zip(weights) {
            axpy(&mut acc[j0..j1], w, &v[j0..j1]);
        }
        j0 = j1;
    }
}

/// acc = acc ⊞ Σ_v vecs[v] over the Z_2^64 secure-aggregation ring,
/// chunked member-inner. Wrapping addition commutes, so this is exact
/// for any chunking; the windowing only buys cache locality.
pub fn wrapping_accumulate(acc: &mut [u64], vecs: &[&[u64]]) {
    for v in vecs {
        assert_eq!(v.len(), acc.len(), "wrapping_accumulate length mismatch");
    }
    let n = acc.len();
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + CHUNK).min(n);
        for v in vecs {
            ring_add(&mut acc[j0..j1], &v[j0..j1]);
        }
        j0 = j1;
    }
}

/// acc ⊞= m over Z_2^64 (elementwise wrapping add) — the shared inner
/// loop of every ring fold below. Integer arithmetic, so both backends
/// are exact and identical by construction.
#[inline]
fn ring_add(acc: &mut [u64], m: &[u64]) {
    debug_assert_eq!(acc.len(), m.len());
    simd_dispatch!(ring_add(acc, m));
    for (a, &b) in acc.iter_mut().zip(m) {
        *a = a.wrapping_add(b);
    }
}

/// acc ⊟= m over Z_2^64 (elementwise wrapping sub); see [`ring_add`].
#[inline]
fn ring_sub(acc: &mut [u64], m: &[u64]) {
    debug_assert_eq!(acc.len(), m.len());
    simd_dispatch!(ring_sub(acc, m));
    for (a, &b) in acc.iter_mut().zip(m) {
        *a = a.wrapping_sub(b);
    }
}

// ---------------------------------------------------------------------------
// secure-aggregation ring kernels (bit-exact contract)
// ---------------------------------------------------------------------------

/// Window length (ring elements) for the blocked mask kernels: the
/// encode block + PRG block (2 KB each) plus the accumulator and value
/// windows stay in L1 while every pair stream is folded in.
pub const RING_BLOCK: usize = 256;

/// Fixed-point scale of the Z_2^64 ring encoding: 24 fractional bits.
/// The representable range is |x| < 2^63 / SCALE = 2^39 ≈ 5.5e11 — far
/// beyond gradient ranges. Outside it the `f64 → i64` cast in
/// [`encode`] saturates silently and the ring sum is wrong without any
/// error, so `encode` guards the range with a debug assertion.
pub const SCALE: f64 = (1u64 << 24) as f64;

/// Encode an f32 into the ring (re-exported as `secure_agg::encode`,
/// the protocol-facing name). Debug builds reject values outside the
/// representable range (|x| ≥ 2^39, where the i64 cast would silently
/// saturate — see [`SCALE`]).
#[inline]
pub fn encode(x: f32) -> u64 {
    let scaled = x as f64 * SCALE;
    debug_assert!(
        scaled.abs() < i64::MAX as f64,
        "fixed-point overflow: |{x}| ≥ 2^39 is outside the ring's \
         representable range"
    );
    (scaled.round() as i64) as u64
}

/// Decode a ring element (interpreting as signed) back to f32
/// (re-exported as `secure_agg::decode`).
#[inline]
pub fn decode(v: u64) -> f32 {
    ((v as i64) as f64 / SCALE) as f32
}

/// One pairwise mask stream: the pair PRG and its sign in the telescoping
/// sum (`add` for the lower-id side of the pair, subtract for the higher).
/// Streams are consumed strictly in element order, so block fills of any
/// size reproduce the per-element scalar walk exactly.
#[derive(Clone, Debug)]
pub struct MaskStream {
    pub rng: Rng,
    pub add: bool,
}

/// acc = acc ⊞/⊟ PRG-stream over the Z_2^64 ring, blocked: `prg` is drawn
/// in [`RING_BLOCK`]-element blocks via [`Rng::fill_u64`] (stream-identical
/// to per-element `next_u64` calls) and folded into the accumulator
/// window while it is cache-hot. The dropout-recovery kernel.
pub fn mask_stream_accumulate(acc: &mut [u64], prg: &mut Rng, add: bool) {
    let mut block = [0u64; RING_BLOCK];
    for w in acc.chunks_mut(RING_BLOCK) {
        let n = w.len();
        prg.fill_u64(&mut block[..n]);
        if add {
            ring_add(w, &block[..n]);
        } else {
            ring_sub(w, &block[..n]);
        }
    }
}

/// The fused masking kernel: acc ⊞= mask(encode(factor · values)), one
/// chunked pass. Per [`RING_BLOCK`] window it (1) scales and fixed-point
/// encodes the values (the same per-element `f32` multiply + encode the
/// scalar pipeline performs), (2) folds every pair stream's block into
/// the window (block PRG draws, element order preserved per stream), and
/// (3) wrapping-adds the masked window into the ring accumulator — so no
/// scaled `Vec<f32>`, no per-member mask `Vec<u64>`, and no separate
/// partial fold ever materialize. Ring addition commutes, so the result
/// is bit-identical to the scalar scale → encode → mask → fold pipeline
/// retained in [`reference::scale_encode_mask`].
///
/// `block` is caller-owned scratch (the arena's ring buffer), grown to
/// 2·[`RING_BLOCK`] on first use and reused across members and rounds.
pub fn scale_encode_mask_accumulate(
    acc: &mut [u64],
    values: &[f32],
    factor: f32,
    streams: &mut [MaskStream],
    block: &mut Vec<u64>,
) {
    assert_eq!(
        acc.len(),
        values.len(),
        "scale_encode_mask_accumulate length mismatch"
    );
    Scratch::ensure_u64(block, 2 * RING_BLOCK);
    let (enc, prg) = block.split_at_mut(RING_BLOCK);
    let d = acc.len();
    let mut j0 = 0;
    while j0 < d {
        let j1 = (j0 + RING_BLOCK).min(d);
        let n = j1 - j0;
        // fused scale → fixed-point encode of this window
        for (e, &v) in enc[..n].iter_mut().zip(&values[j0..j1]) {
            *e = encode(v * factor);
        }
        // net pairwise mask: each stream contributes draws j0..j1
        for s in streams.iter_mut() {
            s.rng.fill_u64(&mut prg[..n]);
            if s.add {
                ring_add(&mut enc[..n], &prg[..n]);
            } else {
                ring_sub(&mut enc[..n], &prg[..n]);
            }
        }
        // fold the masked window into the shard partial
        ring_add(&mut acc[j0..j1], &enc[..n]);
        j0 = j1;
    }
}

// ---------------------------------------------------------------------------
// wire-payload scatter kernels + QSGD bit codec (bit-exact contract)
// ---------------------------------------------------------------------------

/// Bits per coordinate of the packed QSGD code word:
/// bit_length(levels+1) = ⌈log2(levels+2)⌉ level bits (the levels+1
/// ordinary values 0..=levels, plus headroom for the norm-rounding
/// s+1 edge level) plus one sign bit — the same width
/// `Compressor::bits` estimates.
#[inline]
pub fn qsgd_bits_per_coord(levels: u32) -> u32 {
    64 - (u64::from(levels) + 1).leading_zeros() + 1
}

/// u64 words needed to hold `d` packed QSGD coordinates.
#[inline]
pub fn qsgd_packed_words(d: usize, levels: u32) -> usize {
    (d * qsgd_bits_per_coord(levels) as usize).div_ceil(64)
}

/// Write `word` (low `bits` bits) into coordinate slot `j` of the
/// little-endian packed bit stream. Slots are `bits` wide and may
/// straddle a word boundary; the target bits must be zero (fresh
/// buffer), as in any append-only bit writer.
#[inline]
pub fn pack_bits(packed: &mut [u64], j: usize, bits: u32, word: u64) {
    debug_assert!((1..64).contains(&bits), "pack_bits width {bits}");
    debug_assert!(word >> bits == 0, "pack_bits word overflows {bits} bits");
    let off = j * bits as usize;
    let idx = off / 64;
    let sh = (off % 64) as u32;
    packed[idx] |= word << sh;
    if sh + bits > 64 {
        packed[idx + 1] |= word >> (64 - sh);
    }
}

/// Read the `bits`-wide code word at coordinate slot `j`.
#[inline]
pub fn unpack_bits(packed: &[u64], j: usize, bits: u32) -> u64 {
    debug_assert!((1..64).contains(&bits), "unpack_bits width {bits}");
    let mask = (1u64 << bits) - 1;
    let off = j * bits as usize;
    let idx = off / 64;
    let sh = (off % 64) as u32;
    let mut w = packed[idx] >> sh;
    if sh + bits > 64 {
        w |= packed[idx + 1] << (64 - sh);
    }
    w & mask
}

/// Reconstruct one QSGD coordinate from its sign and integer level:
/// `±1 · norm · level / s`, with exactly the scalar dequantizer's
/// left-associated float-op order — the bit-exactness anchor for
/// [`quantized_accumulate`] and `wire::Payload::densify_into`.
#[inline]
pub fn qsgd_value(negative: bool, level: u32, norm: f32, s: f32) -> f32 {
    let sign = if negative { -1.0f32 } else { 1.0f32 };
    sign * norm * level as f32 / s
}

/// acc[indices[t]] += w · values[t] — the sparse-upload fold. Each
/// retained coordinate receives the identical fused multiply-add the
/// densified fold would apply; the skipped coordinates would have
/// received `acc += w·(±0.0)`, which is the f32 identity here (a
/// nonzero sum cancels to +0.0 under round-to-nearest and ±0.0
/// contributions keep +0.0, so the accumulator is never −0.0) — hence
/// bit-exact to [`reference::sparse_densify`] + [`axpy`], pinned by
/// property tests.
pub fn sparse_weighted_accumulate(
    acc: &mut [f32],
    indices: &[u32],
    values: &[f32],
    w: f32,
) {
    assert_eq!(
        indices.len(),
        values.len(),
        "sparse_weighted_accumulate arity"
    );
    let d = acc.len();
    for (&i, &v) in indices.iter().zip(values) {
        let i = i as usize;
        assert!(i < d, "sparse index {i} out of dim {d}");
        acc[i] += w * v;
    }
}

/// acc[j] += w · q_j for every coordinate of a packed QSGD upload —
/// fused unpack + fold, no dense intermediate. Per element this is the
/// identical reconstruct-then-multiply-add of the densified fold
/// ([`qsgd_value`] is shared), so the result is bit-exact to
/// [`reference::quantized_densify`] + [`axpy`].
pub fn quantized_accumulate(
    acc: &mut [f32],
    packed: &[u64],
    norm: f32,
    levels: u32,
    w: f32,
) {
    assert_eq!(
        packed.len(),
        qsgd_packed_words(acc.len(), levels),
        "quantized_accumulate packed length"
    );
    let bits = qsgd_bits_per_coord(levels);
    let s = levels.max(1) as f32;
    for (j, a) in acc.iter_mut().enumerate() {
        let word = unpack_bits(packed, j, bits);
        *a += w * qsgd_value(word & 1 == 1, (word >> 1) as u32, norm, s);
    }
}

// ---------------------------------------------------------------------------
// GEMM kernels (bit-exact contract)
// ---------------------------------------------------------------------------

/// out (m×n) = a (m×k, row-major) · b (k×n, row-major), rows initialized
/// to `bias` (broadcast) or zero. Blocked over k in [`KC`]-row windows of
/// `b`; within a window every output row accumulates in ascending-k
/// order, so each out element sees the exact per-element op sequence of
/// the naive row walk. Zero `a` entries are skipped (sparse one-hot rows
/// are common), matching the scalar reference bit-for-bit.
pub fn gemm_block(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "gemm_block a shape");
    let rows: Vec<usize> = (0..m).collect();
    gemm_gather_block(a, &rows, k, b, n, bias, out);
}

/// [`gemm_block`] over a gathered row set: row `i` of the output reads
/// row `rows[i]` of `a` (the batch-indexing form the models need —
/// mini-batches are index sets, not contiguous slices).
pub fn gemm_gather_block(
    a: &[f32],
    rows: &[usize],
    k: usize,
    b: &[f32],
    n: usize,
    bias: Option<&[f32]>,
    out: &mut [f32],
) {
    assert_eq!(b.len(), k * n, "gemm_gather_block b shape");
    assert_eq!(out.len(), rows.len() * n, "gemm_gather_block out shape");
    match bias {
        Some(bias) => {
            assert_eq!(bias.len(), n, "gemm_gather_block bias shape");
            for r in out.chunks_exact_mut(n) {
                r.copy_from_slice(bias);
            }
        }
        None => out.fill(0.0),
    }
    let mut l0 = 0;
    while l0 < k {
        let l1 = (l0 + KC).min(k);
        let bblock = &b[l0 * n..l1 * n];
        for (i, &row) in rows.iter().enumerate() {
            let arow = &a[row * k + l0..row * k + l1];
            let orow = &mut out[i * n..(i + 1) * n];
            for (l, &al) in arow.iter().enumerate() {
                if al == 0.0 {
                    continue;
                }
                axpy(orow, al, &bblock[l * n..(l + 1) * n]);
            }
        }
        l0 = l1;
    }
}

/// grad (k×n, row-major) += x ⊗ d (rank-1 outer-product accumulation):
/// `grad[l·n + j] += x[l] · d[j]`. The inner j-loop is contiguous and
/// unrolled — the scalar reference walks j-outer/l-inner, which writes
/// with stride n and is the single worst access pattern in the seed
/// `loss_grad`. Per element the contribution is the same single fused
/// multiply-add, so swapping the nesting is bit-exact. Zero `x` entries
/// skipped, as in the scalar reference.
pub fn rank1_accumulate(grad: &mut [f32], x: &[f32], d: &[f32]) {
    let n = d.len();
    assert_eq!(grad.len(), x.len() * n, "rank1_accumulate shape");
    for (l, &xl) in x.iter().enumerate() {
        if xl == 0.0 {
            continue;
        }
        axpy(&mut grad[l * n..(l + 1) * n], xl, d);
    }
}

/// Positional one-hot expansion: token rows (rows × seq, row-major) →
/// dense rows × (seq·vocab) with a single 1.0 per position. The blocked
/// row-major fill keeps the (sparse) writes sequential per row.
pub fn one_hot_expand(tokens: &[i32], seq: usize, vocab: usize, out: &mut [f32]) {
    assert!(seq > 0, "one_hot_expand empty rows");
    assert_eq!(tokens.len() % seq, 0, "one_hot_expand ragged tokens");
    let dim = seq * vocab;
    assert_eq!(out.len(), (tokens.len() / seq) * dim, "one_hot_expand out");
    out.fill(0.0);
    for (row, orow) in tokens.chunks_exact(seq).zip(out.chunks_exact_mut(dim)) {
        for (pos, &t) in row.iter().enumerate() {
            let t = t as usize;
            assert!(t < vocab, "token {t} out of vocab {vocab}");
            orow[pos * vocab + t] = 1.0;
        }
    }
}

// ---------------------------------------------------------------------------
// scratch arena
// ---------------------------------------------------------------------------

/// Per-worker scratch arena: every buffer the sim hot path needs,
/// allocated once per shard worker (or per legacy-engine round) instead
/// of per `local_pass` call. Fields are public so callers can borrow
/// them disjointly; [`Scratch::ensure`] grows a buffer without
/// reallocating once the high-water mark is reached.
#[derive(Debug, Default)]
pub struct Scratch {
    /// gradient accumulator (model dim)
    pub grad: Vec<f32>,
    /// local parameter vector for FedAvg inner loops (model dim)
    pub y: Vec<f32>,
    /// model workspace (batch × classes logits, etc.)
    pub work: Vec<f32>,
    /// epoch index order (shuffled once per epoch, reused across epochs)
    pub idx: Vec<usize>,
    /// wrap-around tail batch
    pub tail: Vec<usize>,
    /// ring-block staging for the fused mask kernels (encode + PRG
    /// windows of [`scale_encode_mask_accumulate`])
    pub ring: Vec<u64>,
    /// densify staging for compressed secure-path uploads: sparse and
    /// quantized payloads reconstruct here at the shard boundary before
    /// the dense-only mask fold (DESIGN.md §7)
    pub dense: Vec<f32>,
    /// per-member pairwise mask streams (secure aggregation fan-out)
    pub streams: Vec<MaskStream>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Resize `buf` to `n` elements. Contents are unspecified — stale
    /// values are retained when the length already matches, so callers
    /// must fully overwrite before reading. A no-op (not even a fill)
    /// on the steady-state hot path where the size is stable.
    pub fn ensure(buf: &mut Vec<f32>, n: usize) {
        if buf.len() != n {
            buf.clear();
            buf.resize(n, 0.0);
        }
    }

    /// [`Scratch::ensure`] for ring (u64) buffers — same contract:
    /// contents unspecified, no reallocation once the high-water mark is
    /// reached.
    pub fn ensure_u64(buf: &mut Vec<u64>, n: usize) {
        if buf.len() != n {
            buf.clear();
            buf.resize(n, 0);
        }
    }
}

// ---------------------------------------------------------------------------
// scalar references
// ---------------------------------------------------------------------------

/// The pre-kernel scalar loops: the correctness oracle for the property
/// tests and the baseline arm of the `bench kernels` / `bench secure`
/// suites.
pub mod reference {
    use super::{encode, MaskStream};
    use crate::util::rng::Rng;

    /// Per-element PRG mask walk (the pre-kernel `SecureAggregator::mask`
    /// / `recover` inner loop): one `next_u64` call per ring element.
    pub fn mask_stream(out: &mut [u64], prg: &mut Rng, add: bool) {
        if add {
            for v in out.iter_mut() {
                *v = v.wrapping_add(prg.next_u64());
            }
        } else {
            for v in out.iter_mut() {
                *v = v.wrapping_sub(prg.next_u64());
            }
        }
    }

    /// The scalar masking pipeline the fused kernel replaces: materialize
    /// the scaled copy, fixed-point encode it, then one full-vector pass
    /// per pair stream. Returns the masked ring vector (the caller folds
    /// it, as `masked_partial` did member by member).
    pub fn scale_encode_mask(
        values: &[f32],
        factor: f32,
        streams: &mut [MaskStream],
    ) -> Vec<u64> {
        let mut scaled = values.to_vec();
        for v in &mut scaled {
            *v *= factor;
        }
        let mut out: Vec<u64> = scaled.iter().map(|&x| encode(x)).collect();
        for s in streams.iter_mut() {
            mask_stream(&mut out, &mut s.rng, s.add);
        }
        out
    }

    /// Densify a sparse-k upload: the dense decompressed-equivalent
    /// vector the pre-wire path materialized (zeros everywhere, the
    /// retained scaled values at their indices). With [`axpy`] this is
    /// the densify-then-accumulate reference the scatter kernel
    /// `sparse_weighted_accumulate` is bit-exact to.
    pub fn sparse_densify(
        dim: usize,
        indices: &[u32],
        values: &[f32],
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        for (&i, &v) in indices.iter().zip(values) {
            out[i as usize] = v;
        }
        out
    }

    /// Densify a packed QSGD upload: reconstruct every coordinate via
    /// the shared [`super::qsgd_value`] codec. With [`axpy`] this is the
    /// densify-then-accumulate reference `quantized_accumulate` is
    /// bit-exact to.
    pub fn quantized_densify(
        dim: usize,
        packed: &[u64],
        norm: f32,
        levels: u32,
    ) -> Vec<f32> {
        let bits = super::qsgd_bits_per_coord(levels);
        let s = levels.max(1) as f32;
        (0..dim)
            .map(|j| {
                let w = super::unpack_bits(packed, j, bits);
                super::qsgd_value(w & 1 == 1, (w >> 1) as u32, norm, s)
            })
            .collect()
    }

    /// Sequential-fold squared norm (the seed `tensor::norm_sq`).
    pub fn norm_sq(x: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for &v in x {
            acc += (v as f64) * (v as f64);
        }
        acc
    }

    /// Sequential-fold dot product (the seed `tensor::dot`).
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len(), "dot length mismatch");
        let mut acc = 0.0f64;
        for (x, y) in a.iter().zip(b) {
            acc += (*x as f64) * (*y as f64);
        }
        acc
    }

    /// Simple-loop axpy (the seed `tensor::axpy`).
    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        assert_eq!(y.len(), x.len(), "axpy length mismatch");
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    /// Naive gathered mat-mul, row walk with zero-skip (the seed
    /// `Logistic::logits` shape, generalized).
    pub fn gemm_gather(
        a: &[f32],
        rows: &[usize],
        k: usize,
        b: &[f32],
        n: usize,
        bias: Option<&[f32]>,
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), rows.len() * n, "gemm_gather out shape");
        for (i, &row) in rows.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            match bias {
                Some(bias) => orow.copy_from_slice(bias),
                None => orow.fill(0.0),
            }
            for (l, &al) in a[row * k..(row + 1) * k].iter().enumerate() {
                if al == 0.0 {
                    continue;
                }
                for (o, &w) in orow.iter_mut().zip(&b[l * n..(l + 1) * n]) {
                    *o += al * w;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;
    use crate::util::rng::Rng;

    fn vecf(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect()
    }

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn prop_norm_sq_matches_reference() {
        quick("kernel-norm-sq", |rng, _| {
            let n = rng.range(0, 300);
            let x = vecf(rng, n);
            let k = norm_sq(&x);
            let r = reference::norm_sq(&x);
            if rel_close(k, r, 1e-6) {
                Ok(())
            } else {
                Err(format!("{k} vs {r}"))
            }
        });
    }

    #[test]
    fn prop_dot_matches_reference() {
        quick("kernel-dot", |rng, _| {
            let n = rng.range(0, 300);
            let a = vecf(rng, n);
            let b = vecf(rng, n);
            let k = dot(&a, &b);
            let r = reference::dot(&a, &b);
            if rel_close(k, r, 1e-6) {
                Ok(())
            } else {
                Err(format!("{k} vs {r}"))
            }
        });
    }

    #[test]
    fn prop_axpy_bit_identical_to_reference() {
        quick("kernel-axpy", |rng, _| {
            let n = rng.range(0, 100);
            let a = rng.normal_f32(0.0, 1.0);
            let x = vecf(rng, n);
            let mut y1 = vecf(rng, n);
            let mut y2 = y1.clone();
            axpy(&mut y1, a, &x);
            reference::axpy(&mut y2, a, &x);
            if y1 == y2 {
                Ok(())
            } else {
                Err("axpy diverged from reference".into())
            }
        });
    }

    #[test]
    fn prop_gemm_block_matches_reference() {
        quick("kernel-gemm", |rng, case| {
            let m = rng.range(1, 9);
            let k = rng.range(1, 200);
            let n = rng.range(1, 24);
            let a: Vec<f32> = (0..m * k)
                .map(|_| {
                    // mix in exact zeros: the skip path must agree too
                    if rng.bernoulli(0.3) {
                        0.0
                    } else {
                        rng.normal_f32(0.0, 1.0)
                    }
                })
                .collect();
            let b = vecf(rng, k * n);
            let bias = vecf(rng, n);
            let with_bias = case % 2 == 0;
            let bias_opt = if with_bias { Some(&bias[..]) } else { None };
            let mut out_k = vec![0.0f32; m * n];
            let mut out_r = vec![0.0f32; m * n];
            gemm_block(m, k, n, &a, &b, bias_opt, &mut out_k);
            let rows: Vec<usize> = (0..m).collect();
            reference::gemm_gather(&a, &rows, k, &b, n, bias_opt, &mut out_r);
            for (x, y) in out_k.iter().zip(&out_r) {
                if !rel_close(*x as f64, *y as f64, 1e-6) {
                    return Err(format!("{x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_gather_reads_the_right_rows() {
        // a has 3 rows; gather rows [2, 0] with identity-ish b
        let k = 2;
        let n = 2;
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [1.0f32, 0.0, 0.0, 1.0]; // identity
        let mut out = vec![0.0f32; 4];
        gemm_gather_block(&a, &[2, 0], k, &b, n, None, &mut out);
        assert_eq!(out, vec![5.0, 6.0, 1.0, 2.0]);
    }

    #[test]
    fn prop_weighted_accumulate_bit_exact_to_sequential_axpy() {
        quick("kernel-weighted-accumulate", |rng, _| {
            let d = rng.range(1, 2500); // spans multiple CHUNK windows
            let members = rng.range(1, 6);
            let vecs: Vec<Vec<f32>> =
                (0..members).map(|_| vecf(rng, d)).collect();
            let weights: Vec<f32> =
                (0..members).map(|_| rng.normal_f32(1.0, 0.5)).collect();
            let refs: Vec<&[f32]> = vecs.iter().map(|v| v.as_slice()).collect();
            let mut acc_k = vec![0.0f32; d];
            weighted_accumulate(&mut acc_k, &refs, &weights);
            // the secure-aggregation ordering: fold members sequentially
            let mut acc_r = vec![0.0f32; d];
            for (v, &w) in vecs.iter().zip(&weights) {
                reference::axpy(&mut acc_r, w, v);
            }
            if acc_k == acc_r {
                Ok(())
            } else {
                Err("weighted_accumulate reordered the fold".into())
            }
        });
    }

    #[test]
    fn prop_accumulate_bit_exact_to_sequential_fold() {
        quick("kernel-accumulate", |rng, _| {
            let d = rng.range(1, 2500);
            let members = rng.range(1, 6);
            let vecs: Vec<Vec<f32>> =
                (0..members).map(|_| vecf(rng, d)).collect();
            let refs: Vec<&[f32]> = vecs.iter().map(|v| v.as_slice()).collect();
            let mut acc_k = vec![0.0f32; d];
            accumulate(&mut acc_k, &refs);
            let mut acc_r = vec![0.0f32; d];
            for v in &vecs {
                reference::axpy(&mut acc_r, 1.0, v);
            }
            if acc_k == acc_r {
                Ok(())
            } else {
                Err("accumulate reordered the fold".into())
            }
        });
    }

    #[test]
    fn wrapping_accumulate_matches_flat_wrapping_sum() {
        let mut rng = Rng::new(11);
        let d = 3000;
        let vecs: Vec<Vec<u64>> = (0..5)
            .map(|_| (0..d).map(|_| rng.next_u64()).collect())
            .collect();
        let refs: Vec<&[u64]> = vecs.iter().map(|v| v.as_slice()).collect();
        let mut acc = vec![0u64; d];
        wrapping_accumulate(&mut acc, &refs);
        for j in 0..d {
            let want = vecs
                .iter()
                .fold(0u64, |s, v| s.wrapping_add(v[j]));
            assert_eq!(acc[j], want, "lane {j}");
        }
    }

    fn streams_from(specs: &[(u64, bool)]) -> Vec<MaskStream> {
        specs
            .iter()
            .map(|&(seed, add)| MaskStream { rng: Rng::new(seed), add })
            .collect()
    }

    #[test]
    fn prop_mask_stream_accumulate_matches_per_element_walk() {
        quick("kernel-mask-stream", |rng, _| {
            let n = rng.range(0, 700); // spans several RING_BLOCK windows
            let seed = rng.next_u64();
            let add = rng.bernoulli(0.5);
            let mut acc_k: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let mut acc_r = acc_k.clone();
            mask_stream_accumulate(&mut acc_k, &mut Rng::new(seed), add);
            reference::mask_stream(&mut acc_r, &mut Rng::new(seed), add);
            if acc_k == acc_r {
                Ok(())
            } else {
                Err("blocked mask stream diverged from scalar walk".into())
            }
        });
    }

    #[test]
    fn prop_fused_mask_fold_bit_exact_to_scalar_pipeline() {
        // the secure-path contract: fused scale → encode → mask → fold
        // equals the retained scalar mask + member-by-member ring fold,
        // bitwise, for any dim / member count / stream signs
        quick("kernel-scale-encode-mask", |rng, _| {
            let d = rng.range(1, 700);
            let members = rng.range(1, 5);
            let specs: Vec<Vec<(u64, bool)>> = (0..members)
                .map(|_| {
                    let pairs = rng.range(0, 6);
                    (0..pairs)
                        .map(|_| (rng.next_u64(), rng.bernoulli(0.5)))
                        .collect()
                })
                .collect();
            let vals: Vec<Vec<f32>> =
                (0..members).map(|_| vecf(rng, d)).collect();
            let factors: Vec<f32> =
                (0..members).map(|_| rng.normal_f32(1.0, 0.5)).collect();

            let mut acc_k = vec![0u64; d];
            let mut block = Vec::new();
            for ((spec, v), &f) in specs.iter().zip(&vals).zip(&factors) {
                let mut streams = streams_from(spec);
                scale_encode_mask_accumulate(
                    &mut acc_k, v, f, &mut streams, &mut block,
                );
            }

            let mut acc_r = vec![0u64; d];
            for ((spec, v), &f) in specs.iter().zip(&vals).zip(&factors) {
                let mut streams = streams_from(spec);
                let masked = reference::scale_encode_mask(v, f, &mut streams);
                for (a, &m) in acc_r.iter_mut().zip(&masked) {
                    *a = a.wrapping_add(m);
                }
            }

            if acc_k == acc_r {
                Ok(())
            } else {
                Err("fused mask fold diverged from scalar pipeline".into())
            }
        });
    }

    #[test]
    fn scratch_ensure_u64_reuses_capacity() {
        let mut s = Scratch::new();
        Scratch::ensure_u64(&mut s.ring, 512);
        assert_eq!(s.ring.len(), 512);
        let cap = s.ring.capacity();
        Scratch::ensure_u64(&mut s.ring, 256);
        Scratch::ensure_u64(&mut s.ring, 512);
        assert_eq!(s.ring.capacity(), cap, "ensure_u64 must not reallocate");
    }

    #[test]
    fn rank1_accumulate_is_the_outer_product() {
        let x = [2.0f32, 0.0, -1.0];
        let d = [1.0f32, 3.0];
        let mut grad = vec![0.5f32; 6];
        rank1_accumulate(&mut grad, &x, &d);
        assert_eq!(grad, vec![2.5, 6.5, 0.5, 0.5, -0.5, -2.5]);
    }

    #[test]
    fn axpy_norm_sq_fuses_update_and_norm() {
        let mut y = vec![1.0f32, 2.0, 3.0];
        let x = [1.0f32, 1.0, 1.0];
        let ns = axpy_norm_sq(&mut y, 2.0, &x);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        assert!((ns - 50.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_norm_sq_flags_non_finite() {
        let mut y = vec![0.0f32; 9];
        let mut x = vec![0.0f32; 9];
        x[8] = f32::INFINITY; // in the unrolled tail
        assert!(!axpy_norm_sq(&mut y, 1.0, &x).is_finite());
        let mut y = vec![f32::NAN; 3];
        assert!(!axpy_norm_sq(&mut y, 1.0, &[0.0; 3]).is_finite());
    }

    #[test]
    fn one_hot_expand_places_ones() {
        let tokens = [1i32, 0, 2, 2];
        let mut out = vec![0.0f32; 2 * 2 * 3];
        one_hot_expand(&tokens, 2, 3, &mut out);
        assert_eq!(
            out,
            vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0]
        );
    }

    #[test]
    fn scaled_diff_componentwise() {
        let mut out = vec![0.0f32; 3];
        scaled_diff(&mut out, &[2.0, 3.0, 4.0], &[1.0, 1.0, 1.0], &[0.0, 1.0, 2.0]);
        assert_eq!(out, vec![2.0, 0.0, -4.0]);
    }

    #[test]
    fn scratch_ensure_reuses_capacity() {
        let mut s = Scratch::new();
        Scratch::ensure(&mut s.grad, 100);
        assert_eq!(s.grad.len(), 100);
        let cap = s.grad.capacity();
        Scratch::ensure(&mut s.grad, 50);
        Scratch::ensure(&mut s.grad, 100);
        assert_eq!(s.grad.capacity(), cap, "ensure must not reallocate");
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn accumulate_length_checked() {
        accumulate(&mut [0.0; 2], &[&[1.0, 2.0, 3.0]]);
    }

    #[test]
    fn prop_pack_unpack_round_trips() {
        quick("kernel-pack-bits", |rng, _| {
            let bits = rng.range(1, 35) as u32;
            let n = rng.range(1, 120);
            let words: Vec<u64> = (0..n)
                .map(|_| rng.next_u64() & ((1u64 << bits) - 1))
                .collect();
            let mut packed =
                vec![0u64; (n * bits as usize).div_ceil(64)];
            for (j, &w) in words.iter().enumerate() {
                pack_bits(&mut packed, j, bits, w);
            }
            for (j, &w) in words.iter().enumerate() {
                if unpack_bits(&packed, j, bits) != w {
                    return Err(format!("slot {j} (width {bits}) diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sparse_scatter_bit_exact_to_densified_fold() {
        // the sparse fold contract: scatter-adding only the retained
        // coordinates equals densifying and folding the whole vector,
        // bitwise, for any accumulator state and member count
        quick("kernel-sparse-scatter", |rng, _| {
            let d = rng.range(1, 400);
            let members = rng.range(1, 5);
            let mut acc_k = vec![0.0f32; d];
            let mut acc_r = vec![0.0f32; d];
            for _ in 0..members {
                let k = rng.range(1, d + 1);
                let idx: Vec<u32> =
                    rng.choose_k(d, k).iter().map(|&i| i as u32).collect();
                let vals = vecf(rng, k);
                let w = rng.normal_f32(1.0, 0.5);
                sparse_weighted_accumulate(&mut acc_k, &idx, &vals, w);
                let dense = reference::sparse_densify(d, &idx, &vals);
                reference::axpy(&mut acc_r, w, &dense);
            }
            let same = acc_k
                .iter()
                .zip(&acc_r)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            if same {
                Ok(())
            } else {
                Err("sparse scatter diverged from densified fold".into())
            }
        });
    }

    #[test]
    fn prop_quantized_fold_bit_exact_to_densified_fold() {
        quick("kernel-quantized-fold", |rng, _| {
            let d = rng.range(1, 300);
            let levels = rng.range(1, 40) as u32;
            let bits = qsgd_bits_per_coord(levels);
            let mut packed = vec![0u64; qsgd_packed_words(d, levels)];
            for j in 0..d {
                let level = rng.below(u64::from(levels) + 1);
                pack_bits(&mut packed, j, bits, (level << 1) | rng.below(2));
            }
            let norm = rng.normal_f32(1.0, 0.5).abs();
            let w = rng.normal_f32(1.0, 0.5);
            let mut acc_k = vecf(rng, d);
            let mut acc_r = acc_k.clone();
            quantized_accumulate(&mut acc_k, &packed, norm, levels, w);
            let dense = reference::quantized_densify(d, &packed, norm, levels);
            reference::axpy(&mut acc_r, w, &dense);
            let same = acc_k
                .iter()
                .zip(&acc_r)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            if same {
                Ok(())
            } else {
                Err("quantized fold diverged from densified fold".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "out of dim")]
    fn sparse_scatter_bounds_checked() {
        sparse_weighted_accumulate(&mut [0.0; 2], &[2], &[1.0], 1.0);
    }

    /// Backend-equivalence pins: every AVX2 kernel must be *bitwise*
    /// identical to its blocked scalar counterpart (the stronger
    /// achieved contract of DESIGN.md §12), across odd lengths,
    /// remainder tails and non-finite inputs — and the reductions must
    /// additionally satisfy the published ≤ 1e-6 relative tolerance
    /// against the sequential [`reference`] fold.
    ///
    /// Each test is a no-op on hosts without AVX2. Non-finite probes
    /// use only the std `NAN`/`INFINITY` constants: both backends
    /// propagate those canonical payloads identically, whereas exotic
    /// NaN payloads are outside every contract here.
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    mod simd_backend {
        use super::*;
        use crate::tensor::dispatch;

        /// Mostly finite values with occasional canonical non-finites
        /// mixed in, so lanes and tails both see NaN/±Inf.
        fn vecf_nf(rng: &mut Rng, n: usize) -> Vec<f32> {
            (0..n)
                .map(|_| match rng.below(16) {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    2 => f32::NEG_INFINITY,
                    _ => rng.normal_f32(0.0, 2.0),
                })
                .collect()
        }

        fn bits32(v: &[f32]) -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        }

        #[test]
        fn prop_avx2_reductions_bit_identical_to_scalar() {
            if !dispatch::simd_available() {
                return;
            }
            quick("avx2-reductions", |rng, case| {
                // 0, sub-lane, exact-lane and multi-chunk-with-tail dims
                let n = rng.range(0, 300);
                let x = if case % 3 == 0 {
                    vecf_nf(rng, n)
                } else {
                    vecf(rng, n)
                };
                let y = vecf(rng, n);
                // SAFETY: AVX2 presence checked above.
                let (ns, dt) =
                    unsafe { (dispatch::avx2::norm_sq(&x), dispatch::avx2::dot(&x, &y)) };
                if ns.to_bits() != norm_sq(&x).to_bits() {
                    return Err(format!("norm_sq diverged at n={n}"));
                }
                if dt.to_bits() != dot(&x, &y).to_bits() {
                    return Err(format!("dot diverged at n={n}"));
                }
                // published tolerance contract vs the sequential fold
                if x.iter().all(|v| v.is_finite())
                    && !rel_close(ns, reference::norm_sq(&x), 1e-6)
                {
                    return Err("norm_sq outside tolerance contract".into());
                }
                if x.iter().all(|v| v.is_finite())
                    && !rel_close(dt, reference::dot(&x, &y), 1e-6)
                {
                    return Err("dot outside tolerance contract".into());
                }
                Ok(())
            });
        }

        #[test]
        fn prop_avx2_elementwise_bit_identical_to_scalar() {
            if !dispatch::simd_available() {
                return;
            }
            quick("avx2-elementwise", |rng, case| {
                let n = rng.range(0, 120);
                let a = if case % 5 == 0 {
                    f32::NAN
                } else {
                    rng.normal_f32(0.0, 1.0)
                };
                let x = if case % 3 == 0 {
                    vecf_nf(rng, n)
                } else {
                    vecf(rng, n)
                };
                let b = vecf(rng, n);
                let y0 = vecf(rng, n);

                let mut y_simd = y0.clone();
                let mut y_scal = y0.clone();
                // SAFETY: AVX2 presence checked above.
                unsafe { dispatch::avx2::axpy(&mut y_simd, a, &x) };
                axpy(&mut y_scal, a, &x);
                if bits32(&y_simd) != bits32(&y_scal) {
                    return Err(format!("axpy diverged at n={n}"));
                }

                let mut y_simd = y0.clone();
                let mut y_scal = y0.clone();
                // SAFETY: AVX2 presence checked above.
                unsafe { dispatch::avx2::add_assign(&mut y_simd, &x) };
                add_assign(&mut y_scal, &x);
                if bits32(&y_simd) != bits32(&y_scal) {
                    return Err(format!("add_assign diverged at n={n}"));
                }

                let mut o_simd = vec![0.0f32; n];
                let mut o_scal = vec![0.0f32; n];
                // SAFETY: AVX2 presence checked above.
                unsafe { dispatch::avx2::sub_into(&mut o_simd, &x, &b) };
                sub_into(&mut o_scal, &x, &b);
                if bits32(&o_simd) != bits32(&o_scal) {
                    return Err(format!("sub_into diverged at n={n}"));
                }
                Ok(())
            });
        }

        #[test]
        fn prop_avx2_axpy_norm_sq_bit_identical_to_scalar() {
            if !dispatch::simd_available() {
                return;
            }
            quick("avx2-axpy-norm-sq", |rng, case| {
                let n = rng.range(0, 200);
                let a = rng.normal_f32(0.0, 1.0);
                let x = if case % 3 == 0 {
                    vecf_nf(rng, n)
                } else {
                    vecf(rng, n)
                };
                let y0 = vecf(rng, n);
                let mut y_simd = y0.clone();
                let mut y_scal = y0;
                // SAFETY: AVX2 presence checked above.
                let ns_simd =
                    unsafe { dispatch::avx2::axpy_norm_sq(&mut y_simd, a, &x) };
                let ns_scal = axpy_norm_sq(&mut y_scal, a, &x);
                if bits32(&y_simd) != bits32(&y_scal) {
                    return Err(format!("updated y diverged at n={n}"));
                }
                if ns_simd.to_bits() != ns_scal.to_bits() {
                    return Err(format!("norm diverged at n={n}"));
                }
                Ok(())
            });
        }

        #[test]
        fn prop_avx2_ring_ops_exact() {
            if !dispatch::simd_available() {
                return;
            }
            quick("avx2-ring", |rng, _| {
                let n = rng.range(0, 40);
                let m: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
                let acc0: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();

                let mut a_simd = acc0.clone();
                // SAFETY: AVX2 presence checked above.
                unsafe { dispatch::avx2::ring_add(&mut a_simd, &m) };
                let mut a_scal = acc0.clone();
                for (a, &b) in a_scal.iter_mut().zip(&m) {
                    *a = a.wrapping_add(b);
                }
                if a_simd != a_scal {
                    return Err(format!("ring_add diverged at n={n}"));
                }

                let mut s_simd = acc0.clone();
                // SAFETY: AVX2 presence checked above.
                unsafe { dispatch::avx2::ring_sub(&mut s_simd, &m) };
                let mut s_scal = acc0;
                for (a, &b) in s_scal.iter_mut().zip(&m) {
                    *a = a.wrapping_sub(b);
                }
                if s_simd != s_scal {
                    return Err(format!("ring_sub diverged at n={n}"));
                }
                Ok(())
            });
        }
    }
}
