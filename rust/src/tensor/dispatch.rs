//! Runtime kernel-backend dispatch: one process-wide choice between the
//! blocked scalar kernels and the explicit-SIMD (AVX2) implementations
//! in [`avx2`], selected once at startup and read with a single relaxed
//! atomic load on every kernel entry (DESIGN.md §12).
//!
//! **Selection rules.**
//!
//! * The library default is [`Backend::Scalar`]: a process that never
//!   calls [`select`] (tests, library embedders) runs the exact blocked
//!   kernels the seed trajectories were pinned on.
//! * The CLI surfaces `--kernel-backend auto|scalar|simd` (default
//!   `auto`) on every subcommand with a hot path and calls [`select`]
//!   before any kernel runs. `auto` resolves to SIMD when the host has
//!   AVX2 and the `simd` cargo feature is on; forcing `simd` on a host
//!   without AVX2 is an error (exit 2), never a silent fallback.
//! * The `FEDSAMP_KERNEL_BACKEND` environment variable supplies the
//!   default for processes with no CLI surface (`cargo test`, the bench
//!   binaries) — this is how CI runs the full tier-1 suite under both
//!   backends. An explicit [`select`] (the CLI) always wins; a bogus
//!   env value warns and falls back to scalar.
//!
//! **Exactness.** Every AVX2 kernel here is constructed to be *bitwise
//! identical* to its blocked scalar counterpart in
//! [`crate::tensor::kernels`] — see each function's comment and
//! DESIGN.md §12 for the argument (no FMA, lane-mapped f64 partial
//! accumulators sharing the scalar fold tree, exact integer ring ops).
//! The published contract the rest of the crate relies on is weaker
//! (reductions: ≤ 1e-6 relative vs the sequential reference), so a
//! future port to a width where the lane mapping cannot be preserved
//! stays within contract; the bitwise property tests pin what this
//! implementation actually achieves.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel implementation set executes the hot loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The blocked/unrolled scalar kernels (the pinned reference path).
    Scalar,
    /// The AVX2 implementations in [`avx2`].
    Simd,
}

impl Backend {
    /// Stable lowercase name (CLI values, BENCH_*.json records).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Simd => "simd",
        }
    }
}

/// A parsed `--kernel-backend` request; `Auto` resolves in [`select`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    Auto,
    Scalar,
    Simd,
}

/// Parse a `--kernel-backend` / env value.
pub fn parse_backend(s: &str) -> Result<BackendChoice, String> {
    match s {
        "auto" => Ok(BackendChoice::Auto),
        "scalar" => Ok(BackendChoice::Scalar),
        "simd" => Ok(BackendChoice::Simd),
        other => Err(format!(
            "unknown kernel backend '{other}' (expected auto, scalar or \
             simd)"
        )),
    }
}

const UNINIT: u8 = 0;
const SCALAR: u8 = 1;
const SIMD: u8 = 2;

/// The process-wide active backend. `UNINIT` until the first kernel
/// call or [`select`], whichever comes first.
static ACTIVE: AtomicU8 = AtomicU8::new(UNINIT);

/// True iff the SIMD implementations can run on this build + host:
/// the `simd` cargo feature is enabled, the target is x86_64, and the
/// CPU reports AVX2 at runtime.
pub fn simd_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// Resolve `choice` and install it as the process-wide backend.
/// Forcing `Simd` where [`simd_available`] is false is an error;
/// `Auto` picks SIMD when available, scalar otherwise.
pub fn select(choice: BackendChoice) -> Result<Backend, String> {
    let backend = match choice {
        BackendChoice::Scalar => Backend::Scalar,
        BackendChoice::Simd => {
            if !simd_available() {
                return Err(
                    "--kernel-backend simd: AVX2 unavailable (host CPU \
                     without AVX2, non-x86_64 target, or the `simd` \
                     cargo feature is disabled); use auto or scalar"
                        .into(),
                );
            }
            Backend::Simd
        }
        BackendChoice::Auto => {
            if simd_available() {
                Backend::Simd
            } else {
                Backend::Scalar
            }
        }
    };
    let code = match backend {
        Backend::Scalar => SCALAR,
        Backend::Simd => SIMD,
    };
    ACTIVE.store(code, Ordering::Relaxed);
    Ok(backend)
}

/// The currently active backend (initializing from the environment on
/// first use).
pub fn active() -> Backend {
    if simd_on() {
        Backend::Simd
    } else {
        Backend::Scalar
    }
}

/// Hot-path predicate: is the SIMD backend active? One relaxed atomic
/// load on the steady state; the first call per process takes the cold
/// env-init path.
#[inline]
pub fn simd_on() -> bool {
    match ACTIVE.load(Ordering::Relaxed) {
        SIMD => true,
        SCALAR => false,
        _ => init_from_env() == SIMD,
    }
}

/// First-use initialization from `FEDSAMP_KERNEL_BACKEND`. The first
/// writer wins (compare-exchange), so a race between threads cannot
/// flip the backend mid-run.
#[cold]
#[inline(never)]
fn init_from_env() -> u8 {
    let var = std::env::var("FEDSAMP_KERNEL_BACKEND").ok();
    let code = match var.as_deref() {
        None | Some("") | Some("scalar") => SCALAR,
        Some("auto") => {
            if simd_available() {
                SIMD
            } else {
                SCALAR
            }
        }
        Some("simd") => {
            if simd_available() {
                SIMD
            } else {
                eprintln!(
                    "FEDSAMP_KERNEL_BACKEND=simd: AVX2 unavailable on \
                     this build/host, falling back to scalar"
                );
                SCALAR
            }
        }
        Some(other) => {
            eprintln!(
                "FEDSAMP_KERNEL_BACKEND: unknown backend '{other}' \
                 (expected auto, scalar or simd), using scalar"
            );
            SCALAR
        }
    };
    match ACTIVE.compare_exchange(
        UNINIT,
        code,
        Ordering::Relaxed,
        Ordering::Relaxed,
    ) {
        Ok(_) => code,
        Err(prev) => prev,
    }
}

/// AVX2 implementations of the hot kernels. Every function is
/// `#[target_feature(enable = "avx2")]` and therefore `unsafe`: the
/// caller must guarantee the CPU supports AVX2 (the dispatch layer
/// only routes here after [`simd_available`] runtime detection).
///
/// Bit-exactness construction, per kernel class:
///
/// * **f32 elementwise** ([`avx2::axpy`], [`avx2::add_assign`],
///   [`avx2::sub_into`]): packed single-precision multiply and add are
///   IEEE-754 correctly rounded per lane, exactly like the scalar ops —
///   no FMA is ever used, so each element sees the identical two
///   roundings in the identical order.
/// * **f64-accumulated reductions** ([`avx2::norm_sq`], [`avx2::dot`],
///   [`avx2::axpy_norm_sq`]): the blocked scalar kernels keep 8 f64
///   partial accumulators where lane `i` sums elements `8k + i`. Here
///   two 4-wide f64 vectors hold lanes 0–3 (low f32 half, widened via
///   `cvtps_pd`) and 4–7 (high half); f32→f64 widening is exact, and
///   the per-lane multiply/add sequence is the scalar one. The eight
///   lane sums are then spilled in lane order and folded through the
///   *same* fixed pairwise tree ([`crate::tensor::kernels`]'s `fold`),
///   so the result is bit-identical, tails included.
/// * **Z_2^64 ring ops** ([`avx2::ring_add`], [`avx2::ring_sub`]):
///   packed 64-bit wrapping add/sub are exact integer arithmetic.
///
/// What deliberately stays scalar: fixed-point `encode` (Rust's
/// round-half-away-from-zero f64→i64 with saturation has no AVX2
/// equivalent) and the xoshiro256++ PRG (serially state-dependent);
/// see DESIGN.md §12.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    use crate::tensor::kernels::fold;

    /// Lanes per f32 vector op.
    const F32_LANES: usize = 8;
    /// Lanes per u64 vector op.
    const U64_LANES: usize = 4;

    /// Widen the 8 f32 lanes of `v` to two 4-wide f64 vectors
    /// `(lanes 0–3, lanes 4–7)` — exact, like the scalar `as f64`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen(v: __m256) -> (__m256d, __m256d) {
        let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
        let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
        (lo, hi)
    }

    /// Spill the two 4-wide accumulators into the scalar kernels' 8-lane
    /// layout (`acc[i]` sums elements `8k + i`) and apply the shared
    /// fixed fold tree.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn fold_acc(acc_lo: __m256d, acc_hi: __m256d) -> f64 {
        let mut lanes = [0.0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
        fold(&lanes)
    }

    /// Squared L2 norm; bit-identical to `kernels::norm_sq`.
    ///
    /// # Safety
    /// The CPU must support AVX2 (runtime-detected by the dispatch
    /// layer before routing here).
    #[target_feature(enable = "avx2")]
    pub unsafe fn norm_sq(x: &[f32]) -> f64 {
        let n = x.len();
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut j = 0;
        while j + F32_LANES <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(j));
            let (lo, hi) = widen(v);
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(lo, lo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(hi, hi));
            j += F32_LANES;
        }
        let mut tail = 0.0f64;
        for &v in &x[j..] {
            tail += (v as f64) * v as f64;
        }
        fold_acc(acc_lo, acc_hi) + tail
    }

    /// Dot product; bit-identical to `kernels::dot`.
    ///
    /// # Safety
    /// The CPU must support AVX2; `a.len() == b.len()` (asserted by the
    /// dispatching wrapper).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut j = 0;
        while j + F32_LANES <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(j));
            let vb = _mm256_loadu_ps(b.as_ptr().add(j));
            let (alo, ahi) = widen(va);
            let (blo, bhi) = widen(vb);
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(alo, blo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(ahi, bhi));
            j += F32_LANES;
        }
        let mut tail = 0.0f64;
        for (&x, &y) in a[j..].iter().zip(&b[j..]) {
            tail += (x as f64) * y as f64;
        }
        fold_acc(acc_lo, acc_hi) + tail
    }

    /// y += a·x; bit-identical to `kernels::axpy` (multiply then add,
    /// two IEEE roundings per element, no FMA).
    ///
    /// # Safety
    /// The CPU must support AVX2; `y.len() == x.len()` (asserted by the
    /// dispatching wrapper).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let va = _mm256_set1_ps(a);
        let mut j = 0;
        while j + F32_LANES <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(j));
            let vx = _mm256_loadu_ps(x.as_ptr().add(j));
            let r = _mm256_add_ps(vy, _mm256_mul_ps(va, vx));
            _mm256_storeu_ps(y.as_mut_ptr().add(j), r);
            j += F32_LANES;
        }
        for (yi, &xi) in y[j..].iter_mut().zip(&x[j..]) {
            *yi += a * xi;
        }
    }

    /// y += x; bit-identical to `kernels::add_assign`.
    ///
    /// # Safety
    /// The CPU must support AVX2; `y.len() == x.len()` (asserted by the
    /// dispatching wrapper).
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(y: &mut [f32], x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let mut j = 0;
        while j + F32_LANES <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(j));
            let vx = _mm256_loadu_ps(x.as_ptr().add(j));
            _mm256_storeu_ps(y.as_mut_ptr().add(j), _mm256_add_ps(vy, vx));
            j += F32_LANES;
        }
        for (yi, &xi) in y[j..].iter_mut().zip(&x[j..]) {
            *yi += xi;
        }
    }

    /// out = a − b; bit-identical to `kernels::sub_into`.
    ///
    /// # Safety
    /// The CPU must support AVX2; all three slices must have equal
    /// lengths (asserted by the dispatching wrapper).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert_eq!(out.len(), a.len());
        debug_assert_eq!(a.len(), b.len());
        let n = out.len();
        let mut j = 0;
        while j + F32_LANES <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(j));
            let vb = _mm256_loadu_ps(b.as_ptr().add(j));
            _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_sub_ps(va, vb));
            j += F32_LANES;
        }
        for ((o, &x), &y) in out[j..].iter_mut().zip(&a[j..]).zip(&b[j..]) {
            *o = x - y;
        }
    }

    /// Fused y += a·x and Σ y'²; bit-identical to
    /// `kernels::axpy_norm_sq` (per element: update with mul-then-add,
    /// then square-accumulate the updated value into its f64 lane).
    ///
    /// # Safety
    /// The CPU must support AVX2; `y.len() == x.len()` (asserted by the
    /// dispatching wrapper).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_norm_sq(y: &mut [f32], a: f32, x: &[f32]) -> f64 {
        debug_assert_eq!(y.len(), x.len());
        let n = y.len();
        let va = _mm256_set1_ps(a);
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut j = 0;
        while j + F32_LANES <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(j));
            let vx = _mm256_loadu_ps(x.as_ptr().add(j));
            let upd = _mm256_add_ps(vy, _mm256_mul_ps(va, vx));
            _mm256_storeu_ps(y.as_mut_ptr().add(j), upd);
            let (lo, hi) = widen(upd);
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(lo, lo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(hi, hi));
            j += F32_LANES;
        }
        let mut tail = 0.0f64;
        for (yi, &xi) in y[j..].iter_mut().zip(&x[j..]) {
            *yi += a * xi;
            tail += (*yi as f64) * *yi as f64;
        }
        fold_acc(acc_lo, acc_hi) + tail
    }

    /// acc ⊞= m over Z_2^64 (packed wrapping add — exact).
    ///
    /// # Safety
    /// The CPU must support AVX2; `acc.len() == m.len()` (guaranteed by
    /// the dispatching wrapper's window slicing).
    #[target_feature(enable = "avx2")]
    pub unsafe fn ring_add(acc: &mut [u64], m: &[u64]) {
        debug_assert_eq!(acc.len(), m.len());
        let n = acc.len();
        let mut j = 0;
        while j + U64_LANES <= n {
            let a =
                _mm256_loadu_si256(acc.as_ptr().add(j) as *const __m256i);
            let b = _mm256_loadu_si256(m.as_ptr().add(j) as *const __m256i);
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(j) as *mut __m256i,
                _mm256_add_epi64(a, b),
            );
            j += U64_LANES;
        }
        for (a, &b) in acc[j..].iter_mut().zip(&m[j..]) {
            *a = a.wrapping_add(b);
        }
    }

    /// acc ⊟= m over Z_2^64 (packed wrapping sub — exact).
    ///
    /// # Safety
    /// The CPU must support AVX2; `acc.len() == m.len()` (guaranteed by
    /// the dispatching wrapper's window slicing).
    #[target_feature(enable = "avx2")]
    pub unsafe fn ring_sub(acc: &mut [u64], m: &[u64]) {
        debug_assert_eq!(acc.len(), m.len());
        let n = acc.len();
        let mut j = 0;
        while j + U64_LANES <= n {
            let a =
                _mm256_loadu_si256(acc.as_ptr().add(j) as *const __m256i);
            let b = _mm256_loadu_si256(m.as_ptr().add(j) as *const __m256i);
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(j) as *mut __m256i,
                _mm256_sub_epi64(a, b),
            );
            j += U64_LANES;
        }
        for (a, &b) in acc[j..].iter_mut().zip(&m[j..]) {
            *a = a.wrapping_sub(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_three_values() {
        assert_eq!(parse_backend("auto").unwrap(), BackendChoice::Auto);
        assert_eq!(parse_backend("scalar").unwrap(), BackendChoice::Scalar);
        assert_eq!(parse_backend("simd").unwrap(), BackendChoice::Simd);
        assert!(parse_backend("avx512").is_err());
        assert!(parse_backend("").is_err());
    }

    #[test]
    fn backend_names_round_trip() {
        assert_eq!(Backend::Scalar.name(), "scalar");
        assert_eq!(Backend::Simd.name(), "simd");
    }

    #[test]
    fn select_respects_availability() {
        // Transiently flipping the global is safe: both backends are
        // bit-identical (the property tests in tensor::kernels pin it),
        // so concurrent tests cannot observe a result difference.
        let before = active();
        assert_eq!(select(BackendChoice::Scalar).unwrap(), Backend::Scalar);
        assert_eq!(active(), Backend::Scalar);
        if simd_available() {
            assert_eq!(select(BackendChoice::Simd).unwrap(), Backend::Simd);
            assert_eq!(active(), Backend::Simd);
            assert_eq!(
                select(BackendChoice::Auto).unwrap(),
                Backend::Simd,
                "auto resolves to simd when available"
            );
        } else {
            assert!(select(BackendChoice::Simd).is_err());
            assert_eq!(
                select(BackendChoice::Auto).unwrap(),
                Backend::Scalar,
                "auto falls back to scalar when simd is unavailable"
            );
        }
        let restore = match before {
            Backend::Scalar => BackendChoice::Scalar,
            Backend::Simd => BackendChoice::Simd,
        };
        select(restore).unwrap();
    }
}
