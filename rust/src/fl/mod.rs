//! Federated-learning orchestration: the master/client round protocol of
//! Algorithm 3 (FedAvg) and Eq. (2) (DSGD) with pluggable client
//! sampling.
//!
//! The driver is generic over a [`ClientEngine`] — the sim path plugs in
//! rust-native exact-gradient models ([`crate::sim`]), the XLA path plugs
//! in PJRT-executed AOT artifacts ([`crate::runtime`]). Everything else
//! (cohort selection, norm collection, sampling negotiation, secure
//! aggregation, master update, bit accounting, metrics) is shared — and
//! is precisely the paper's system contribution.
//!
//! The protocol itself lives in [`crate::coordinator`] as an explicit
//! round state machine over a sharded client registry; [`train`] is the
//! thin single-shard adapter that preserves the historical entry point
//! (and its exact trajectories) for any [`ClientEngine`].

pub mod availability;
pub mod comm;

use crate::checkpoint::CheckpointOptions;
use crate::compress::Compressor;
use crate::config::ExperimentConfig;
use crate::coordinator::{Coordinator, CoordinatorOptions, EngineRunner};
use crate::metrics::RunResult;
use crate::telemetry::TelemetryConfig;

/// Result of one client's local work in a round.
#[derive(Clone, Debug)]
pub struct LocalOutcome {
    /// The update U_i^k: local gradient (DSGD) or model delta
    /// Δy_i = x^k − y_{i,R} (FedAvg).
    pub delta: Vec<f32>,
    /// Mean local training loss observed during the local pass.
    pub train_loss: f64,
    /// Number of local examples (drives the FedAvg weight w_i).
    pub examples: usize,
}

/// Validation metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOutcome {
    pub loss: f64,
    pub accuracy: f64,
}

/// Per-client compute backend (sim or XLA).
pub trait ClientEngine {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;
    /// Total pool size.
    fn num_clients(&self) -> usize;
    /// Examples held by client `id`.
    fn client_examples(&self, id: usize) -> usize;
    /// Initial global parameters.
    fn init_params(&self, seed: u64) -> Vec<f32>;
    /// Run the local computation for every cohort member.
    fn run_local(
        &mut self,
        round: usize,
        global: &[f32],
        cohort: &[usize],
    ) -> Vec<LocalOutcome>;
    /// Evaluate global parameters on the validation split.
    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome;
}

/// Options beyond [`ExperimentConfig`] (compression ablation hook, §6).
#[derive(Clone, Debug, Default)]
pub struct TrainOptions {
    /// Update compressor for participant uploads; `None` falls back to
    /// the config's `compressor` field (this is the ablation override).
    /// To force an *uncompressed* arm even when the config sets a
    /// compressor, pass `Some(Compressor::None)` — only a `None` option
    /// inherits.
    pub compressor: Option<Compressor>,
    /// Print a progress line every `verbose_every` rounds (0 = silent).
    pub verbose_every: usize,
    /// Route plain-path shard folds through the retained
    /// densify-then-accumulate reference instead of the payload-native
    /// scatter kernels. Bit-identical by contract (the end-to-end
    /// exactness tests pin it); the baseline arm of `fedsamp bench comm`.
    pub densify_folds: bool,
    /// Observability configuration (see [`crate::telemetry`]). Default
    /// off: no clocks read, no events recorded, trajectories bit-
    /// identical to a build without the subsystem in the call path.
    pub telemetry: TelemetryConfig,
    /// Durable-snapshot configuration (see [`crate::checkpoint`]).
    /// Default fully off: no cadence branch taken, no file written, no
    /// restore attempted — bitwise inert by the same contract as
    /// telemetry.
    pub checkpoint: CheckpointOptions,
}

/// Run a full federated training experiment.
///
/// Thin adapter over the [`crate::coordinator`] subsystem: a single-shard
/// [`Coordinator`] over an [`EngineRunner`], which reproduces the seed
/// sequential protocol bit-for-bit (same RNG streams, same float-op
/// order) for any [`ClientEngine`].
pub fn train(
    cfg: &ExperimentConfig,
    engine: &mut dyn ClientEngine,
    opts: &TrainOptions,
) -> Result<RunResult, String> {
    let mut runner = EngineRunner::new(engine);
    let mut coordinator =
        Coordinator::new(CoordinatorOptions::single_shard());
    coordinator.run(cfg, &mut runner, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, DataSpec, Strategy};
    use crate::tensor;
    use crate::util::rng::Rng;

    /// Deterministic toy engine: "clients" pull the parameter toward
    /// client-specific targets; loss is the distance.
    struct ToyEngine {
        targets: Vec<Vec<f32>>,
        sizes: Vec<usize>,
    }

    impl ToyEngine {
        fn new(n: usize, dim: usize) -> ToyEngine {
            let mut rng = Rng::new(7);
            ToyEngine {
                targets: (0..n)
                    .map(|_| {
                        (0..dim).map(|_| rng.normal_f32(1.0, 0.2)).collect()
                    })
                    .collect(),
                sizes: (0..n).map(|i| 10 + (i % 7) * 30).collect(),
            }
        }
    }

    impl ClientEngine for ToyEngine {
        fn dim(&self) -> usize {
            self.targets[0].len()
        }
        fn num_clients(&self) -> usize {
            self.targets.len()
        }
        fn client_examples(&self, id: usize) -> usize {
            self.sizes[id]
        }
        fn init_params(&self, _seed: u64) -> Vec<f32> {
            vec![0.0; self.dim()]
        }
        fn run_local(
            &mut self,
            _round: usize,
            global: &[f32],
            cohort: &[usize],
        ) -> Vec<LocalOutcome> {
            cohort
                .iter()
                .map(|&id| {
                    // gradient of ½‖x − t‖²: delta = x − t (DSGD-like)
                    let mut delta = vec![0.0f32; global.len()];
                    tensor::sub_into(&mut delta, global, &self.targets[id]);
                    LocalOutcome {
                        train_loss: tensor::norm(&delta),
                        delta,
                        examples: self.sizes[id],
                    }
                })
                .collect()
        }
        fn evaluate(&mut self, global: &[f32]) -> EvalOutcome {
            // distance to mean target
            let d = self.dim();
            let mut mean = vec![0.0f32; d];
            for t in &self.targets {
                tensor::axpy(&mut mean, 1.0 / self.targets.len() as f32, t);
            }
            let dist = tensor::dist_sq(global, &mean).sqrt();
            EvalOutcome { loss: dist, accuracy: (-dist).exp() }
        }
    }

    fn toy_cfg(strategy: Strategy) -> ExperimentConfig {
        ExperimentConfig {
            name: format!("toy_{}", strategy.name()),
            seed: 3,
            rounds: 60,
            cohort: 16,
            budget: 4,
            strategy,
            algorithm: Algorithm::Dsgd { eta: 0.3 },
            data: DataSpec::FemnistLike { pool: 0, variant: 0 },
            model: "native:toy".into(),
            batch_size: 1,
            eval_every: 5,
            eval_examples: 1,
            workers: 1,
            secure_updates: true,
            availability: 1.0,
            availability_trace: None,
            compressor: None,
            fault_plan: None,
        }
    }

    #[test]
    fn converges_toward_mean_target() {
        let mut engine = ToyEngine::new(24, 8);
        let run = train(
            &toy_cfg(Strategy::Ocs),
            &mut engine,
            &TrainOptions::default(),
        )
        .unwrap();
        assert_eq!(run.rounds.len(), 60);
        let first = run.rounds[0].train_loss;
        let last = run.final_train_loss();
        assert!(last < first * 0.2, "{first} -> {last}");
        assert!(run.final_accuracy() > 0.5);
    }

    #[test]
    fn budget_respected_in_expectation() {
        let mut engine = ToyEngine::new(24, 8);
        let run = train(
            &toy_cfg(Strategy::Aocs { j_max: 4 }),
            &mut engine,
            &TrainOptions::default(),
        )
        .unwrap();
        for r in &run.rounds {
            assert!(r.expected_budget <= 4.0 + 1e-6, "{}", r.expected_budget);
        }
        let mean_sent: f64 = run
            .rounds
            .iter()
            .map(|r| r.transmitted as f64)
            .sum::<f64>()
            / run.rounds.len() as f64;
        assert!(mean_sent <= 4.6, "mean transmitted {mean_sent}");
    }

    #[test]
    fn full_transmits_everyone_uniform_budget() {
        let mut engine = ToyEngine::new(24, 8);
        let run = train(
            &toy_cfg(Strategy::Full),
            &mut engine,
            &TrainOptions::default(),
        )
        .unwrap();
        assert!(run.rounds.iter().all(|r| r.transmitted == 16));
        // full pays 16 updates/round; OCS pays ~4 → ~4x fewer bits
        let mut engine2 = ToyEngine::new(24, 8);
        let ocs = train(
            &toy_cfg(Strategy::Ocs),
            &mut engine2,
            &TrainOptions::default(),
        )
        .unwrap();
        assert!(ocs.total_uplink_bits() < run.total_uplink_bits() / 2);
    }

    #[test]
    fn secure_and_plain_aggregation_agree() {
        let mk = |secure: bool| {
            let mut engine = ToyEngine::new(24, 8);
            let mut cfg = toy_cfg(Strategy::Ocs);
            cfg.secure_updates = secure;
            train(&cfg, &mut engine, &TrainOptions::default()).unwrap()
        };
        let a = mk(true);
        let b = mk(false);
        // same seeds → same trajectories up to fixed-point quantization
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert!(
                (ra.train_loss - rb.train_loss).abs() < 1e-3,
                "round {}: {} vs {}",
                ra.round,
                ra.train_loss,
                rb.train_loss
            );
        }
    }

    #[test]
    fn compression_reduces_bits() {
        let mut e1 = ToyEngine::new(24, 32);
        let dense =
            train(&toy_cfg(Strategy::Ocs), &mut e1, &TrainOptions::default())
                .unwrap();
        let mut e2 = ToyEngine::new(24, 32);
        let sparse = train(
            &toy_cfg(Strategy::Ocs),
            &mut e2,
            &TrainOptions {
                compressor: Some(Compressor::RandK { k: 4 }),
                ..TrainOptions::default()
            },
        )
        .unwrap();
        assert!(sparse.total_uplink_bits() < dense.total_uplink_bits() / 2);
    }

    #[test]
    fn partial_availability_still_trains() {
        let mut engine = ToyEngine::new(40, 8);
        let mut cfg = toy_cfg(Strategy::Aocs { j_max: 4 });
        cfg.availability = 0.5;
        cfg.rounds = 80;
        let run =
            train(&cfg, &mut engine, &TrainOptions::default()).unwrap();
        assert!(run.final_train_loss() < run.rounds[0].train_loss * 0.3);
    }

    #[test]
    fn empty_pool_is_an_error() {
        let mut engine = ToyEngine::new(24, 8);
        engine.targets.clear();
        engine.sizes.clear();
        assert!(train(
            &toy_cfg(Strategy::Full),
            &mut engine,
            &TrainOptions::default()
        )
        .is_err());
    }

    #[test]
    fn divergence_detected() {
        let mut engine = ToyEngine::new(8, 4);
        let mut cfg = toy_cfg(Strategy::Full);
        cfg.algorithm = Algorithm::Dsgd { eta: 1e20 };
        cfg.rounds = 50;
        // plain aggregation: the fixed-point secure-agg encoding saturates
        // instead of producing the inf/NaN this test wants to observe
        cfg.secure_updates = false;
        let err = train(&cfg, &mut engine, &TrainOptions::default());
        assert!(err.is_err(), "expected divergence error");
        assert!(err.unwrap_err().contains("divergence"));
    }
}
