//! Communication accounting — the paper's x-axis in every "vs bits" plot.
//!
//! Only client→master (uplink) traffic is counted, per footnote 5: the
//! master→client broadcast is orders of magnitude cheaper in FL systems.

use crate::compress::Compressor;

pub const BITS_PER_FLOAT: u64 = 32;

/// Running uplink-bit meter for one experiment arm.
#[derive(Clone, Debug, Default)]
pub struct BitMeter {
    total: u64,
}

impl BitMeter {
    pub fn new() -> Self {
        BitMeter { total: 0 }
    }

    /// One full-precision update vector of dimension `d`.
    pub fn add_update(&mut self, d: usize) {
        self.total += BITS_PER_FLOAT * d as u64;
    }

    /// One compressed update vector.
    pub fn add_compressed_update(&mut self, d: usize, c: &Compressor) {
        self.total += c.bits(d);
    }

    /// Sampling-negotiation extras (Remark 3): `floats` per client across
    /// `clients` cohort members.
    pub fn add_negotiation(&mut self, clients: usize, floats_per_client: usize) {
        self.total += BITS_PER_FLOAT * (clients * floats_per_client) as u64;
    }

    pub fn total_bits(&self) -> u64 {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_updates_and_negotiation() {
        let mut m = BitMeter::new();
        m.add_update(100); // 3200
        m.add_negotiation(32, 9); // 32*9*32 = 9216
        assert_eq!(m.total_bits(), 3200 + 9216);
    }

    #[test]
    fn compressed_updates_cost_less() {
        let mut dense = BitMeter::new();
        dense.add_update(10_000);
        let mut sparse = BitMeter::new();
        sparse.add_compressed_update(10_000, &Compressor::RandK { k: 100 });
        assert!(sparse.total_bits() < dense.total_bits());
    }

    #[test]
    fn zero_cost_paths() {
        let mut m = BitMeter::new();
        m.add_negotiation(0, 5);
        m.add_negotiation(5, 0);
        assert_eq!(m.total_bits(), 0);
    }
}
