//! Communication accounting — the paper's x-axis in every "vs bits" plot.
//!
//! Only client→master (uplink) traffic is counted, per footnote 5: the
//! master→client broadcast is orders of magnitude cheaper in FL systems.
//!
//! Accounting is **measured, not estimated**: every participant upload
//! is a typed [`Payload`] and the meter counts its exact encoded frame
//! length. `Payload::wire_bytes` is property-pinned equal to
//! `encode_into`'s output for every payload (wire module), so the
//! accessor *is* the measurement; debug builds additionally re-encode
//! each metered payload and assert the two agree, keeping the contract
//! enforced on every test run without an O(d) serialization on the
//! release hot path. The legacy bit view ([`BitMeter::total_bits`]) is
//! kept for CSV/JSON compatibility and is exactly `total_bytes() × 8`,
//! so every bits-axis query is an affine view of the measured bytes.
//! Negotiation scalars (Remark 3) are not payloads; they are metered at
//! four bytes per f32, the same rate the historical estimate charged.

use crate::wire::Payload;

pub const BYTES_PER_FLOAT: u64 = 4;

/// Running uplink meter for one experiment arm (cumulative bytes).
#[derive(Clone, Debug, Default)]
pub struct BitMeter {
    bytes: u64,
}

impl BitMeter {
    pub fn new() -> Self {
        BitMeter::default()
    }

    /// A meter resumed at `bytes` cumulative uplink bytes — used by
    /// [`crate::checkpoint`] restore so post-resume uplink metrics
    /// continue the interrupted tally bit-exactly.
    pub fn with_bytes(bytes: u64) -> Self {
        BitMeter { bytes }
    }

    /// One participant upload: count the bytes its wire frame occupies
    /// (debug builds encode the frame and verify the count against it).
    pub fn add_payload(&mut self, p: &Payload) {
        let bytes = p.wire_bytes();
        #[cfg(debug_assertions)]
        {
            let mut frame = Vec::new();
            p.encode_into(&mut frame);
            assert_eq!(
                frame.len(),
                bytes,
                "wire_bytes out of sync with encode_into"
            );
        }
        self.bytes += bytes as u64;
    }

    /// Sampling-negotiation extras (Remark 3): `floats` per client across
    /// `clients` cohort members.
    pub fn add_negotiation(&mut self, clients: usize, floats_per_client: usize) {
        self.bytes += BYTES_PER_FLOAT * (clients * floats_per_client) as u64;
    }

    /// Measured cumulative uplink bytes.
    pub fn total_bytes(&self) -> u64 {
        self.bytes
    }

    /// Legacy bit view: measured bytes × 8 (CSV/JSON compatibility).
    pub fn total_bits(&self) -> u64 {
        self.bytes * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Compressor;
    use crate::util::rng::Rng;

    #[test]
    fn counts_payloads_and_negotiation() {
        let mut m = BitMeter::new();
        m.add_payload(&Payload::Dense(vec![0.0; 100])); // 5 + 400 bytes
        m.add_negotiation(32, 9); // 32·9·4 = 1152 bytes
        assert_eq!(m.total_bytes(), 405 + 1152);
        assert_eq!(m.total_bits(), m.total_bytes() * 8);
    }

    #[test]
    fn measured_bytes_equal_the_encoded_frame() {
        let x: Vec<f32> = (0..50).map(|i| i as f32).collect();
        let mut rng = Rng::new(3);
        let p = Compressor::RandK { k: 5 }.compress(&x, &mut rng);
        let mut frame = Vec::new();
        p.encode_into(&mut frame);
        let mut m = BitMeter::new();
        m.add_payload(&p);
        assert_eq!(m.total_bytes(), frame.len() as u64);
    }

    #[test]
    fn compressed_payloads_cost_less() {
        let x = vec![1.0f32; 10_000];
        let mut rng = Rng::new(1);
        let mut dense = BitMeter::new();
        dense.add_payload(&Compressor::None.compress(&x, &mut rng));
        let mut sparse = BitMeter::new();
        sparse.add_payload(
            &Compressor::RandK { k: 100 }.compress(&x, &mut rng),
        );
        assert!(sparse.total_bytes() < dense.total_bytes());
    }

    #[test]
    fn zero_cost_paths() {
        let mut m = BitMeter::new();
        m.add_negotiation(0, 5);
        m.add_negotiation(5, 0);
        assert_eq!(m.total_bytes(), 0);
        assert_eq!(m.total_bits(), 0);
    }
}
