//! Client availability (Appendix E) at million-client scale: which pool
//! clients can be reached in a given round, and **streaming** cohort
//! selection among them.
//!
//! The main-paper experiments sample the round cohort uniformly from an
//! always-available pool; Appendix E extends the analysis to a known
//! availability distribution Q with `q_i = Prob(i ∈ Q^k)`. Two model
//! families implement it here:
//!
//! * the **static** models ([`Availability::AlwaysOn`],
//!   [`Availability::Bernoulli`], [`Availability::PerClient`]) — iid
//!   across rounds, drawing from the round RNG exactly as the seed
//!   protocol did;
//! * the **time-varying traces** ([`Availability::Trace`]) — diurnal
//!   Bernoulli schedules, per-client session churn and correlated
//!   whole-shard outages. A trace is a *pure function* of
//!   `(client, round)` over dedicated seed streams: any shard (or any
//!   replay) can evaluate it independently, it costs no per-client
//!   state, and enabling one never perturbs the cohort/selection RNG
//!   (the same design as the coordinator's straggler stream).
//!
//! **Streaming selection.** [`sample_round_cohort`] draws a round cohort
//! with memory proportional to the *cohort*, never the pool: the partial
//! Fisher–Yates behind `Rng::choose_k` is simulated sparsely (a hash map
//! of displaced slots instead of an O(pool) index vector), and the
//! availability scan of the static models is counted and then replayed
//! from a cloned RNG instead of materializing the available set. The
//! draw is **bitwise identical** to the retained dense reference
//! ([`reference::sample_cohort_dense`]) — same RNG consumption, same
//! cohort, property-pinned — so every pre-existing seed trajectory is
//! unchanged. With a million-client pool and a 512-client cohort the
//! per-round allocation is a few tens of KiB instead of ~8 MiB
//! (pinned by `tests/streaming_cohort.rs` with a counting allocator).
//!
//! ```
//! use fedsamp::fl::availability::{Diurnal, Trace};
//! let t = Trace {
//!     seed: 7,
//!     base_q: 0.8,
//!     diurnal: Some(Diurnal { amplitude: 0.5, period: 24, zones: 4 }),
//!     churn: None,
//!     outage: None,
//! };
//! // a pure function of (client, round): replayable anywhere, no state
//! assert_eq!(t.is_available(42, 3), t.is_available(42, 3));
//! let q = t.q_at(42, 3);
//! assert!(q >= 0.8 * 0.5 && q <= 0.8);
//! ```

use crate::coordinator::registry::Registry;
use crate::util::json::Json;
use crate::util::rng::{splitmix64, Rng};

/// Seed-stream labels for the trace draws — dedicated streams, so traces
/// never consume (or perturb) the round RNG that drives selection.
const AVAIL_STREAM: u64 = 0x7C1E_A51B_0D1A_6E55;
const CHURN_STREAM: u64 = 0x00C4_E55E_5E55_10A1;
const CHURN_PHASE_STREAM: u64 = 0x0FA5_E0FF_5E7B_AC4E;
const OUTAGE_STREAM: u64 = 0x0D07_A6E5_0077_A6E5;

/// Diurnal Bernoulli schedule: availability oscillates over the round
/// clock, staggered across timezone groups.
#[derive(Clone, Debug, PartialEq)]
pub struct Diurnal {
    /// Peak-to-trough modulation depth in `[0, 1]`: at the trough the
    /// availability is `base_q · (1 − amplitude)`.
    pub amplitude: f64,
    /// Rounds per full day cycle (≥ 1).
    pub period: usize,
    /// Timezone groups (≥ 1): client `i` belongs to zone `i % zones`,
    /// which offsets its phase by `zone/zones` of a period.
    pub zones: usize,
}

/// Per-client session churn: a client is online or offline for whole
/// sessions at a time (correlated across the rounds of a session),
/// with session boundaries staggered per client.
#[derive(Clone, Debug, PartialEq)]
pub struct Churn {
    /// Rounds per connectivity session (≥ 1).
    pub session_len: usize,
    /// Probability a given session is spent entirely offline, in `[0, 1)`.
    pub drop_prob: f64,
}

/// Correlated shard outage: a whole registry shard (network segment,
/// region) drops out of a round together.
#[derive(Clone, Debug, PartialEq)]
pub struct Outage {
    /// Per-(round, shard) probability the shard is unreachable, in `[0, 1)`.
    pub prob: f64,
}

/// A time-varying availability trace (the scenario-engine model).
///
/// Availability of client `i` at round `k` composes three independent
/// gates, each a pure function of `(i, k)` over its own seed stream:
/// the client's shard is not in a correlated [`Outage`] this round, the
/// client is not in a churned-off [`Churn`] session, and a Bernoulli
/// draw with the diurnal-modulated probability [`Trace::q_at`] succeeds.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Seed for the trace's dedicated draw streams (independent of the
    /// experiment seed so scenario ablations can hold it fixed).
    pub seed: u64,
    /// Baseline availability probability q, in `(0, 1]`.
    pub base_q: f64,
    pub diurnal: Option<Diurnal>,
    pub churn: Option<Churn>,
    pub outage: Option<Outage>,
}

impl Trace {
    /// A plain Bernoulli trace (no diurnal/churn/outage structure).
    pub fn bernoulli(seed: u64, q: f64) -> Trace {
        Trace { seed, base_q: q, diurnal: None, churn: None, outage: None }
    }

    /// True when every client is deterministically reachable every round
    /// (q = 1, no modulation, no churn) — [`sample_round_cohort`] then
    /// degrades to the exact [`Availability::AlwaysOn`] draw.
    pub fn always_available(&self) -> bool {
        let flat_diurnal = match &self.diurnal {
            Some(d) => d.amplitude <= 0.0,
            None => true,
        };
        let no_churn = match &self.churn {
            Some(c) => c.drop_prob <= 0.0,
            None => true,
        };
        self.base_q >= 1.0 && flat_diurnal && no_churn
    }

    /// The diurnal-modulated Bernoulli probability of client `i` at
    /// round `k` (the schedule; churn and outages gate on top of it).
    pub fn q_at(&self, client: usize, round: usize) -> f64 {
        let mut q = self.base_q;
        if let Some(d) = &self.diurnal {
            let zones = d.zones.max(1);
            let phase = (client % zones) as f64 / zones as f64;
            let t = (round as f64 / d.period.max(1) as f64 + phase)
                * std::f64::consts::TAU;
            q *= 1.0 - d.amplitude * (0.5 + 0.5 * t.sin());
        }
        q.clamp(0.0, 1.0)
    }

    /// Whether `client` spends round `round` in a churned-off session.
    fn churned_off(&self, client: usize, round: usize) -> bool {
        let Some(c) = &self.churn else { return false };
        if c.drop_prob <= 0.0 {
            return false;
        }
        let len = c.session_len.max(1);
        // stagger session boundaries per client so the pool does not
        // flip connectivity in lockstep
        let mut sm = self.seed
            ^ CHURN_PHASE_STREAM
            ^ (client as u64).wrapping_mul(0xA24B_AED4_963E_E407);
        let offset = (splitmix64(&mut sm) % len as u64) as usize;
        let session = (round + offset) / len;
        Rng::new(self.seed ^ CHURN_STREAM)
            .fork(client as u64)
            .fork(session as u64)
            .bernoulli(c.drop_prob)
    }

    /// Client-level availability at `(client, round)` — churn gate plus
    /// the Bernoulli schedule draw. Pure and stateless: two evaluations
    /// always agree, and no call consumes shared RNG state. (The shard
    /// [`Outage`] gate composes at the registry level — see
    /// [`Trace::shard_down`].)
    pub fn is_available(&self, client: usize, round: usize) -> bool {
        if self.churned_off(client, round) {
            return false;
        }
        let q = self.q_at(client, round);
        if q >= 1.0 {
            return true;
        }
        if q <= 0.0 {
            return false;
        }
        Rng::new(self.seed ^ AVAIL_STREAM)
            .fork(round as u64)
            .fork(client as u64)
            .bernoulli(q)
    }

    /// Whether `shard` suffers a correlated outage at `round`.
    pub fn shard_down(&self, shard: usize, round: usize) -> bool {
        let Some(o) = &self.outage else { return false };
        if o.prob <= 0.0 {
            return false;
        }
        Rng::new(self.seed ^ OUTAGE_STREAM)
            .fork(round as u64)
            .fork(shard as u64)
            .bernoulli(o.prob)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0 < self.base_q && self.base_q <= 1.0) {
            return Err("trace.base_q must be in (0, 1]".into());
        }
        if let Some(d) = &self.diurnal {
            if !(0.0..=1.0).contains(&d.amplitude) {
                return Err("trace.diurnal.amplitude must be in [0, 1]".into());
            }
            if d.period == 0 || d.zones == 0 {
                return Err("trace.diurnal period/zones must be ≥ 1".into());
            }
        }
        if let Some(c) = &self.churn {
            if c.session_len == 0 {
                return Err("trace.churn.session_len must be ≥ 1".into());
            }
            if !(0.0..1.0).contains(&c.drop_prob) {
                return Err("trace.churn.drop_prob must be in [0, 1)".into());
            }
        }
        if let Some(o) = &self.outage {
            if !(0.0..1.0).contains(&o.prob) {
                return Err("trace.outage.prob must be in [0, 1)".into());
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seed", Json::num(self.seed as f64)),
            ("base_q", Json::num(self.base_q)),
        ];
        if let Some(d) = &self.diurnal {
            fields.push((
                "diurnal",
                Json::obj(vec![
                    ("amplitude", Json::num(d.amplitude)),
                    ("period", Json::num(d.period as f64)),
                    ("zones", Json::num(d.zones as f64)),
                ]),
            ));
        }
        if let Some(c) = &self.churn {
            fields.push((
                "churn",
                Json::obj(vec![
                    ("session_len", Json::num(c.session_len as f64)),
                    ("drop_prob", Json::num(c.drop_prob)),
                ]),
            ));
        }
        if let Some(o) = &self.outage {
            fields.push((
                "outage",
                Json::obj(vec![("prob", Json::num(o.prob))]),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Trace, String> {
        let base_q = v
            .get("base_q")
            .as_f64()
            .ok_or("availability_trace.base_q missing")?;
        let seed = v.get("seed").as_f64().unwrap_or(0.0) as u64;
        let diurnal = match v.get("diurnal") {
            Json::Null => None,
            d => Some(Diurnal {
                amplitude: d
                    .get("amplitude")
                    .as_f64()
                    .ok_or("diurnal.amplitude missing")?,
                period: d.get("period").as_usize().unwrap_or(24),
                zones: d.get("zones").as_usize().unwrap_or(4),
            }),
        };
        let churn = match v.get("churn") {
            Json::Null => None,
            c => Some(Churn {
                session_len: c.get("session_len").as_usize().unwrap_or(8),
                drop_prob: c
                    .get("drop_prob")
                    .as_f64()
                    .ok_or("churn.drop_prob missing")?,
            }),
        };
        let outage = match v.get("outage") {
            Json::Null => None,
            o => Some(Outage {
                prob: o.get("prob").as_f64().ok_or("outage.prob missing")?,
            }),
        };
        let t = Trace { seed, base_q, diurnal, churn, outage };
        t.validate()?;
        Ok(t)
    }
}

/// Availability model for the client pool.
#[derive(Clone, Debug, PartialEq)]
pub enum Availability {
    /// Every client reachable every round (main-paper setting).
    AlwaysOn,
    /// Client i is reachable with probability q (iid across rounds),
    /// drawn sequentially from the round RNG (the seed protocol's
    /// stream discipline).
    Bernoulli { q: f64 },
    /// Per-client probabilities q_i (heterogeneous devices), drawn
    /// sequentially from the round RNG.
    PerClient { q: Vec<f64> },
    /// Time-varying trace over dedicated seed streams (diurnal schedule,
    /// session churn, correlated shard outages).
    Trace(Trace),
}

impl Availability {
    pub fn from_probability(q: f64) -> Availability {
        if q >= 1.0 {
            Availability::AlwaysOn
        } else {
            Availability::Bernoulli { q }
        }
    }

    /// The subset Q^k of reachable clients at `round` — the **dense**
    /// materialization, O(pool) output; the selection path uses the
    /// streaming [`sample_round_cohort`] instead. Static models consume
    /// `rng` (one draw per client, the seed stream discipline); traces
    /// ignore it (pure per-(client, round) functions) and apply no
    /// shard-outage gate (that composes at the registry level).
    pub fn available(&self, pool: usize, round: usize, rng: &mut Rng) -> Vec<usize> {
        match self {
            Availability::AlwaysOn => (0..pool).collect(),
            Availability::Bernoulli { q } => {
                (0..pool).filter(|_| rng.bernoulli(*q)).collect()
            }
            Availability::PerClient { q } => {
                assert_eq!(q.len(), pool, "q length must match pool");
                (0..pool).filter(|&i| rng.bernoulli(q[i])).collect()
            }
            Availability::Trace(t) => {
                (0..pool).filter(|&i| t.is_available(i, round)).collect()
            }
        }
    }

    /// Marginal probability that client i is available (the baseline q
    /// for traces; diurnal modulation is exposed via [`Trace::q_at`]).
    pub fn probability(&self, i: usize) -> f64 {
        match self {
            Availability::AlwaysOn => 1.0,
            Availability::Bernoulli { q } => *q,
            Availability::PerClient { q } => q[i],
            Availability::Trace(t) => t.base_q,
        }
    }
}

/// One round's cohort draw.
#[derive(Clone, Debug)]
pub struct CohortDraw {
    /// Selected clients, in selection order (the protocol's cohort order).
    pub cohort: Vec<usize>,
    /// Shards removed wholesale by a correlated trace outage this round
    /// (0 for non-trace models).
    pub outaged_shards: usize,
}

/// Simulate `Rng::choose_k(n, k)` sparsely: the same partial
/// Fisher–Yates, with the O(n) identity index vector replaced by a hash
/// map of displaced slots — O(k) memory, and draw-for-draw identical to
/// the dense walk (property-pinned).
fn sparse_choose_k(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    use std::collections::HashMap;
    debug_assert!(k <= n, "choose_k k>n");
    let mut displaced: HashMap<usize, usize> = HashMap::new();
    let mut picks = Vec::with_capacity(k);
    for i in 0..k {
        let j = rng.range(i, n);
        let vi = *displaced.get(&i).unwrap_or(&i);
        let vj = *displaced.get(&j).unwrap_or(&j);
        displaced.insert(i, vj);
        displaced.insert(j, vi);
        picks.push(vj);
    }
    picks
}

/// Map pick positions (indices into the availability scan's ordered
/// available sequence) back to client ids by re-walking `avail_at`,
/// preserving pick order. O(picks) memory.
fn resolve_positions(
    pool: usize,
    picks: &[usize],
    mut avail_at: impl FnMut(usize) -> bool,
) -> Vec<usize> {
    let mut order: Vec<(usize, usize)> =
        picks.iter().copied().enumerate().map(|(s, p)| (p, s)).collect();
    order.sort_unstable();
    let mut out = vec![usize::MAX; picks.len()];
    let mut next = 0usize; // cursor into `order`
    let mut seen = 0usize; // available clients passed so far
    for i in 0..pool {
        if next == order.len() {
            break;
        }
        if avail_at(i) {
            while next < order.len() && order[next].0 == seen {
                out[order[next].1] = i;
                next += 1;
            }
            seen += 1;
        }
    }
    debug_assert!(out.iter().all(|&c| c != usize::MAX), "unresolved pick");
    out
}

/// AlwaysOn draw: the available set is the identity, so the sparse
/// Fisher–Yates picks *are* client ids. O(cohort) time and memory.
fn draw_always_on(pool: usize, n: usize, rng: &mut Rng) -> Vec<usize> {
    if pool <= n {
        return (0..pool).collect();
    }
    sparse_choose_k(pool, n, rng)
}

/// Streaming draw for the sequential-stream models (Bernoulli /
/// PerClient): count available clients with the live RNG (consuming the
/// exact per-client draws the dense scan consumed), then collect or
/// resolve from a pre-scan clone. O(cohort) memory, O(pool) time.
fn draw_replayed(
    pool: usize,
    n: usize,
    rng: &mut Rng,
    mut avail_at: impl FnMut(usize, &mut Rng) -> bool,
) -> Vec<usize> {
    let prescan = rng.clone();
    let mut count = 0usize;
    for i in 0..pool {
        if avail_at(i, rng) {
            count += 1;
        }
    }
    if count <= n {
        let mut replay = prescan;
        let mut out = Vec::with_capacity(count);
        for i in 0..pool {
            if avail_at(i, &mut replay) {
                out.push(i);
            }
        }
        return out;
    }
    let picks = sparse_choose_k(count, n, rng);
    let mut replay = prescan;
    resolve_positions(pool, &picks, |i| avail_at(i, &mut replay))
}

/// Streaming draw over a pure availability predicate (the trace models):
/// no replay clone needed — the predicate is simply evaluated twice.
fn draw_predicated(
    pool: usize,
    n: usize,
    rng: &mut Rng,
    mut pred: impl FnMut(usize) -> bool,
) -> Vec<usize> {
    let count = (0..pool).filter(|&i| pred(i)).count();
    if count <= n {
        return (0..pool).filter(|&i| pred(i)).collect();
    }
    let picks = sparse_choose_k(count, n, rng);
    resolve_positions(pool, &picks, pred)
}

/// Sample round `round`'s cohort of (at most) `n` clients uniformly from
/// the available pool (§5.2), with memory proportional to the cohort.
///
/// Bitwise identical to the dense reference draw
/// ([`reference::sample_cohort_dense`]) for every model: same round-RNG
/// consumption, same cohort, same order. Trace models additionally apply
/// the correlated shard-outage gate over `registry` (an O(shards) mask)
/// and report how many shards it removed.
pub fn sample_round_cohort(
    availability: &Availability,
    registry: &Registry,
    round: usize,
    n: usize,
    rng: &mut Rng,
) -> CohortDraw {
    let pool = registry.pool();
    match availability {
        Availability::AlwaysOn => CohortDraw {
            cohort: draw_always_on(pool, n, rng),
            outaged_shards: 0,
        },
        Availability::Bernoulli { q } => CohortDraw {
            cohort: draw_replayed(pool, n, rng, |_, r| r.bernoulli(*q)),
            outaged_shards: 0,
        },
        Availability::PerClient { q } => {
            assert_eq!(q.len(), pool, "q length must match pool");
            CohortDraw {
                cohort: draw_replayed(pool, n, rng, |i, r| r.bernoulli(q[i])),
                outaged_shards: 0,
            }
        }
        Availability::Trace(t) => {
            let down: Vec<bool> = (0..registry.shards())
                .map(|s| t.shard_down(s, round))
                .collect();
            let outaged_shards = down.iter().filter(|&&d| d).count();
            let cohort = if t.always_available() && outaged_shards == 0 {
                // q = 1 degradation: the exact AlwaysOn draw
                draw_always_on(pool, n, rng)
            } else {
                draw_predicated(pool, n, rng, |i| {
                    !down[registry.shard_of(i)] && t.is_available(i, round)
                })
            };
            CohortDraw { cohort, outaged_shards }
        }
    }
}

/// The slice of round `round`'s cohort owned by `shard`, derived without
/// the global cohort ever being materialized by the caller: the
/// deterministic streaming draw is replayed from a clone of `round_rng`
/// (which is not advanced) and filtered to the shard's members, cohort
/// order preserved. Consistent with [`sample_round_cohort`] +
/// [`Registry::split_cohort`] by construction (property-pinned), which
/// is what lets cohort selection run shard-locally at pool sizes where
/// shipping a central draw would dominate the round.
pub fn shard_cohort_slice(
    availability: &Availability,
    registry: &Registry,
    round: usize,
    n: usize,
    shard: usize,
    round_rng: &Rng,
) -> Vec<usize> {
    let mut rng = round_rng.clone();
    sample_round_cohort(availability, registry, round, n, &mut rng)
        .cohort
        .into_iter()
        .filter(|&c| registry.shard_of(c) == shard)
        .collect()
}

/// Legacy entry point: sample a cohort over a single-shard view of the
/// pool (trace outages, which are shard-scoped, see one shard covering
/// everything). Prefer [`sample_round_cohort`]; retained for callers
/// without a registry, with `round = 0` semantics for static models
/// (which ignore the round anyway).
pub fn sample_cohort(
    availability: &Availability,
    pool: usize,
    n: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    if pool == 0 {
        return Vec::new();
    }
    let registry = Registry::new(pool, 1);
    sample_round_cohort(availability, &registry, 0, n, rng).cohort
}

/// The retained dense draw — the seed semantics every streaming path is
/// property-pinned against.
pub mod reference {
    use super::*;

    /// Materialize the available set (O(pool)), then `Rng::choose_k`
    /// over it (another O(pool) index vector) — exactly the historical
    /// `sample_cohort`, with the trace shard-outage gate applied to the
    /// materialized set.
    pub fn sample_cohort_dense(
        availability: &Availability,
        registry: &Registry,
        round: usize,
        n: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let pool = registry.pool();
        let mut avail = availability.available(pool, round, rng);
        if let Availability::Trace(t) = availability {
            avail.retain(|&c| !t.shard_down(registry.shard_of(c), round));
        }
        if avail.len() <= n {
            return avail;
        }
        let picks = rng.choose_k(avail.len(), n);
        picks.into_iter().map(|i| avail[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;

    fn reg(pool: usize, shards: usize) -> Registry {
        Registry::new(pool, shards)
    }

    #[test]
    fn always_on_full_pool() {
        let mut rng = Rng::new(1);
        assert_eq!(
            Availability::AlwaysOn.available(5, 0, &mut rng).len(),
            5
        );
    }

    #[test]
    fn bernoulli_rate_respected() {
        let mut rng = Rng::new(2);
        let a = Availability::Bernoulli { q: 0.3 };
        let total: usize =
            (0..2000).map(|_| a.available(50, 0, &mut rng).len()).sum();
        let rate = total as f64 / (2000.0 * 50.0);
        assert!((rate - 0.3).abs() < 0.02, "{rate}");
    }

    #[test]
    fn per_client_rates() {
        let mut rng = Rng::new(3);
        let a = Availability::PerClient { q: vec![0.0, 1.0, 0.5] };
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            for i in a.available(3, 0, &mut rng) {
                counts[i] += 1;
            }
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], 4000);
        assert!((counts[2] as f64 / 4000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn cohort_size_and_distinctness() {
        let mut rng = Rng::new(4);
        let cohort = sample_cohort(&Availability::AlwaysOn, 100, 32, &mut rng);
        assert_eq!(cohort.len(), 32);
        let mut s = cohort.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 32);
    }

    #[test]
    fn cohort_shrinks_when_pool_scarce() {
        let mut rng = Rng::new(5);
        let cohort = sample_cohort(&Availability::AlwaysOn, 8, 32, &mut rng);
        assert_eq!(cohort.len(), 8);
        let a = Availability::Bernoulli { q: 0.1 };
        let c2 = sample_cohort(&a, 20, 32, &mut rng);
        assert!(c2.len() <= 20);
    }

    #[test]
    fn cohort_is_uniform_over_pool() {
        let mut rng = Rng::new(6);
        let mut counts = vec![0usize; 10];
        for _ in 0..5000 {
            for i in sample_cohort(&Availability::AlwaysOn, 10, 3, &mut rng) {
                counts[i] += 1;
            }
        }
        for &c in &counts {
            let f = c as f64 / 5000.0;
            assert!((f - 0.3).abs() < 0.03, "{counts:?}");
        }
    }

    #[test]
    fn prop_sparse_choose_k_matches_dense() {
        quick("sparse-choose-k", |rng, _| {
            let n = rng.range(1, 400);
            let k = rng.range(0, n + 1);
            let seed = rng.next_u64();
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let sparse = sparse_choose_k(n, k, &mut a);
            let dense = b.choose_k(n, k);
            if sparse != dense {
                return Err(format!("picks diverged (n={n} k={k})"));
            }
            // RNG state must stay aligned after the draw
            if a.next_u64() != b.next_u64() {
                return Err("post-draw RNG state diverged".into());
            }
            Ok(())
        });
    }

    fn random_availability(rng: &mut Rng, pool: usize) -> Availability {
        match rng.below(5) {
            0 => Availability::AlwaysOn,
            1 => Availability::Bernoulli { q: rng.f64() },
            2 => Availability::PerClient {
                q: (0..pool).map(|_| rng.f64()).collect(),
            },
            3 => Availability::Trace(Trace::bernoulli(
                rng.next_u64(),
                0.05 + 0.95 * rng.f64(),
            )),
            _ => Availability::Trace(Trace {
                seed: rng.next_u64(),
                base_q: 0.3 + 0.7 * rng.f64(),
                diurnal: Some(Diurnal {
                    amplitude: rng.f64(),
                    period: rng.range(1, 50),
                    zones: rng.range(1, 6),
                }),
                churn: Some(Churn {
                    session_len: rng.range(1, 10),
                    drop_prob: 0.5 * rng.f64(),
                }),
                outage: Some(Outage { prob: 0.3 * rng.f64() }),
            }),
        }
    }

    #[test]
    fn prop_streaming_draw_matches_the_dense_reference_bitwise() {
        // the trajectory pin: same RNG consumption, same cohort, same
        // order, for every availability model
        quick("streaming-vs-dense", |rng, _| {
            let pool = rng.range(1, 300);
            let shards = rng.range(1, 9);
            let n = rng.range(1, 64);
            let round = rng.range(0, 100);
            let avail = random_availability(rng, pool);
            let registry = reg(pool, shards);
            let seed = rng.next_u64();
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let streaming =
                sample_round_cohort(&avail, &registry, round, n, &mut a);
            let dense = reference::sample_cohort_dense(
                &avail, &registry, round, n, &mut b,
            );
            if streaming.cohort != dense {
                return Err(format!(
                    "cohorts diverged: {:?} vs {dense:?}",
                    streaming.cohort
                ));
            }
            if a.next_u64() != b.next_u64() {
                return Err("post-draw RNG state diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_shard_slices_reassemble_the_global_draw() {
        quick("shard-slices", |rng, _| {
            let pool = rng.range(2, 200);
            let shards = rng.range(1, 7);
            let n = rng.range(1, 40);
            let avail = random_availability(rng, pool);
            let registry = reg(pool, shards);
            let round_rng = Rng::new(rng.next_u64());
            let mut global_rng = round_rng.clone();
            let global = sample_round_cohort(
                &avail, &registry, 3, n, &mut global_rng,
            )
            .cohort;
            let mut seen = Vec::new();
            for s in 0..registry.shards() {
                let slice = shard_cohort_slice(
                    &avail, &registry, 3, n, s, &round_rng,
                );
                for &c in &slice {
                    if registry.shard_of(c) != s {
                        return Err(format!("client {c} not on shard {s}"));
                    }
                }
                seen.extend(slice);
            }
            let mut want = global.clone();
            want.sort_unstable();
            seen.sort_unstable();
            if seen != want {
                return Err("shard slices do not cover the global draw".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_trace_is_deterministic_per_seed() {
        quick("trace-deterministic", |rng, _| {
            let t = match random_availability(rng, 1) {
                Availability::Trace(t) => t,
                _ => Trace::bernoulli(rng.next_u64(), 0.5),
            };
            let client = rng.range(0, 10_000);
            let round = rng.range(0, 1000);
            let shard = rng.range(0, 64);
            if t.is_available(client, round) != t.is_available(client, round)
            {
                return Err("is_available not a pure function".into());
            }
            if t.shard_down(shard, round) != t.shard_down(shard, round) {
                return Err("shard_down not a pure function".into());
            }
            let u = t.clone();
            if u.is_available(client, round) != t.is_available(client, round)
            {
                return Err("clone diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn trace_respects_q() {
        // empirical frequency over many (client, round) pairs tracks q_at
        for q in [0.25, 0.6, 0.9] {
            let t = Trace::bernoulli(11, q);
            let mut hits = 0usize;
            let total = 20_000;
            for round in 0..200 {
                for client in 0..100 {
                    if t.is_available(client, round) {
                        hits += 1;
                    }
                }
            }
            let rate = hits as f64 / total as f64;
            assert!((rate - q).abs() < 0.02, "q={q}: rate {rate}");
        }
    }

    #[test]
    fn trace_q1_degrades_to_always_on_bitwise() {
        let t = Availability::Trace(Trace::bernoulli(99, 1.0));
        let registry = reg(500, 4);
        for case in 0..20u64 {
            let mut a = Rng::new(case);
            let mut b = Rng::new(case);
            let trace_draw =
                sample_round_cohort(&t, &registry, case as usize, 32, &mut a);
            let always = sample_round_cohort(
                &Availability::AlwaysOn,
                &registry,
                case as usize,
                32,
                &mut b,
            );
            assert_eq!(trace_draw.cohort, always.cohort, "case {case}");
            assert_eq!(a.next_u64(), b.next_u64(), "rng state, case {case}");
        }
    }

    #[test]
    fn diurnal_modulation_stays_in_band_and_staggers_zones() {
        let t = Trace {
            seed: 5,
            base_q: 0.8,
            diurnal: Some(Diurnal { amplitude: 0.5, period: 24, zones: 4 }),
            churn: None,
            outage: None,
        };
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for round in 0..48 {
            for client in 0..8 {
                let q = t.q_at(client, round);
                assert!(q >= 0.8 * 0.5 - 1e-12 && q <= 0.8 + 1e-12, "{q}");
                lo = lo.min(q);
                hi = hi.max(q);
            }
        }
        assert!(hi - lo > 0.2, "modulation too flat: [{lo}, {hi}]");
        // different timezone groups peak at different rounds
        assert_ne!(t.q_at(0, 3), t.q_at(1, 3));
    }

    #[test]
    fn churn_flips_only_at_session_boundaries() {
        let t = Trace {
            seed: 21,
            base_q: 1.0, // isolate the churn gate
            diurnal: None,
            churn: Some(Churn { session_len: 5, drop_prob: 0.5 }),
            outage: None,
        };
        let rounds = 50;
        let mut any_off = false;
        for client in 0..40 {
            let states: Vec<bool> =
                (0..rounds).map(|k| t.is_available(client, k)).collect();
            let flips =
                states.windows(2).filter(|w| w[0] != w[1]).count();
            assert!(flips <= rounds / 5 + 1, "client {client}: {flips} flips");
            any_off |= states.iter().any(|&s| !s);
        }
        assert!(any_off, "churn never took a client offline");
    }

    #[test]
    fn outage_downs_whole_shards() {
        let t = Trace {
            seed: 33,
            base_q: 1.0,
            diurnal: None,
            churn: None,
            outage: Some(Outage { prob: 0.5 }),
        };
        let registry = reg(60, 4);
        let avail = Availability::Trace(t.clone());
        let mut saw_outage = false;
        for round in 0..30 {
            let mut rng = Rng::new(round as u64);
            let draw =
                sample_round_cohort(&avail, &registry, round, 60, &mut rng);
            if draw.outaged_shards > 0 {
                saw_outage = true;
                for &c in &draw.cohort {
                    assert!(
                        !t.shard_down(registry.shard_of(c), round),
                        "round {round}: client {c} from a downed shard"
                    );
                }
            }
        }
        assert!(saw_outage, "outage model never fired at prob 0.5");
    }

    #[test]
    fn trace_validation_catches_bad_fields() {
        assert!(Trace::bernoulli(1, 0.0).validate().is_err());
        assert!(Trace::bernoulli(1, 1.5).validate().is_err());
        let mut t = Trace::bernoulli(1, 0.5);
        t.diurnal = Some(Diurnal { amplitude: 2.0, period: 24, zones: 4 });
        assert!(t.validate().is_err());
        t.diurnal = Some(Diurnal { amplitude: 0.5, period: 0, zones: 4 });
        assert!(t.validate().is_err());
        t.diurnal = None;
        t.churn = Some(Churn { session_len: 0, drop_prob: 0.1 });
        assert!(t.validate().is_err());
        t.churn = Some(Churn { session_len: 4, drop_prob: 1.0 });
        assert!(t.validate().is_err());
        t.churn = None;
        t.outage = Some(Outage { prob: 1.0 });
        assert!(t.validate().is_err());
        t.outage = Some(Outage { prob: 0.3 });
        assert!(t.validate().is_ok());
    }

    #[test]
    fn trace_json_round_trips() {
        let t = Trace {
            seed: 17,
            base_q: 0.7,
            diurnal: Some(Diurnal { amplitude: 0.4, period: 24, zones: 3 }),
            churn: Some(Churn { session_len: 6, drop_prob: 0.2 }),
            outage: Some(Outage { prob: 0.05 }),
        };
        let j = t.to_json();
        assert_eq!(Trace::from_json(&j).unwrap(), t);
        // sparse traces omit absent components
        let plain = Trace::bernoulli(3, 0.5);
        let j2 = plain.to_json();
        assert_eq!(j2.get("diurnal"), &Json::Null);
        assert_eq!(Trace::from_json(&j2).unwrap(), plain);
    }
}
