//! Client availability (Appendix E): which pool clients can be reached in
//! a given round, and cohort selection among them.
//!
//! The main-paper experiments sample the round cohort uniformly from an
//! always-available pool; Appendix E extends the analysis to a known
//! availability distribution Q with `q_i = Prob(i ∈ Q^k)` — modelled here
//! as independent Bernoulli availability.

use crate::util::rng::Rng;

/// Availability model for the client pool.
#[derive(Clone, Debug, PartialEq)]
pub enum Availability {
    /// Every client reachable every round (main-paper setting).
    AlwaysOn,
    /// Client i is reachable with probability q (iid across rounds).
    Bernoulli { q: f64 },
    /// Per-client probabilities q_i (heterogeneous devices).
    PerClient { q: Vec<f64> },
}

impl Availability {
    pub fn from_probability(q: f64) -> Availability {
        if q >= 1.0 {
            Availability::AlwaysOn
        } else {
            Availability::Bernoulli { q }
        }
    }

    /// The subset Q^k of reachable clients this round.
    pub fn available(&self, pool: usize, rng: &mut Rng) -> Vec<usize> {
        match self {
            Availability::AlwaysOn => (0..pool).collect(),
            Availability::Bernoulli { q } => (0..pool)
                .filter(|_| rng.bernoulli(*q))
                .collect(),
            Availability::PerClient { q } => {
                assert_eq!(q.len(), pool, "q length must match pool");
                (0..pool).filter(|&i| rng.bernoulli(q[i])).collect()
            }
        }
    }

    /// Probability q_i that client i is available.
    pub fn probability(&self, i: usize) -> f64 {
        match self {
            Availability::AlwaysOn => 1.0,
            Availability::Bernoulli { q } => *q,
            Availability::PerClient { q } => q[i],
        }
    }
}

/// Sample a round cohort of (at most) `n` clients uniformly from the
/// available set (paper §5.2: "n = 32 clients are sampled uniformly from
/// the client pool").
pub fn sample_cohort(
    availability: &Availability,
    pool: usize,
    n: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let avail = availability.available(pool, rng);
    if avail.len() <= n {
        return avail;
    }
    let picks = rng.choose_k(avail.len(), n);
    picks.into_iter().map(|i| avail[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_on_full_pool() {
        let mut rng = Rng::new(1);
        assert_eq!(Availability::AlwaysOn.available(5, &mut rng).len(), 5);
    }

    #[test]
    fn bernoulli_rate_respected() {
        let mut rng = Rng::new(2);
        let a = Availability::Bernoulli { q: 0.3 };
        let total: usize =
            (0..2000).map(|_| a.available(50, &mut rng).len()).sum();
        let rate = total as f64 / (2000.0 * 50.0);
        assert!((rate - 0.3).abs() < 0.02, "{rate}");
    }

    #[test]
    fn per_client_rates() {
        let mut rng = Rng::new(3);
        let a = Availability::PerClient { q: vec![0.0, 1.0, 0.5] };
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            for i in a.available(3, &mut rng) {
                counts[i] += 1;
            }
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], 4000);
        assert!((counts[2] as f64 / 4000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn cohort_size_and_distinctness() {
        let mut rng = Rng::new(4);
        let cohort = sample_cohort(&Availability::AlwaysOn, 100, 32, &mut rng);
        assert_eq!(cohort.len(), 32);
        let mut s = cohort.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 32);
    }

    #[test]
    fn cohort_shrinks_when_pool_scarce() {
        let mut rng = Rng::new(5);
        let cohort = sample_cohort(&Availability::AlwaysOn, 8, 32, &mut rng);
        assert_eq!(cohort.len(), 8);
        let a = Availability::Bernoulli { q: 0.1 };
        let c2 = sample_cohort(&a, 20, 32, &mut rng);
        assert!(c2.len() <= 20);
    }

    #[test]
    fn cohort_is_uniform_over_pool() {
        let mut rng = Rng::new(6);
        let mut counts = vec![0usize; 10];
        for _ in 0..5000 {
            for i in sample_cohort(&Availability::AlwaysOn, 10, 3, &mut rng) {
                counts[i] += 1;
            }
        }
        for &c in &counts {
            let f = c as f64 / 5000.0;
            assert!((f - 0.3).abs() < 0.03, "{counts:?}");
        }
    }
}
