//! # fedsamp — Optimal Client Sampling for Federated Learning
//!
//! Production-oriented reproduction of Chen, Horváth & Richtárik,
//! *Optimal Client Sampling for Federated Learning* (TMLR).
//!
//! The crate is the **Layer-3 rust coordinator** of a three-layer stack
//! (see DESIGN.md): JAX/Pallas author the per-client compute at build
//! time (`python/compile/`), AOT-lowered to HLO text artifacts, which the
//! [`runtime`] module executes through the PJRT C API. The federated
//! orchestration — and the paper's contribution, the optimal client
//! [`sampling`] schemes — live entirely in rust; python never runs on the
//! training path.
//!
//! ## Quick tour
//!
//! * [`sampling`] — OCS (Eq. 7), AOCS (Alg. 2), uniform/full baselines,
//!   variance & improvement-factor machinery (Defs. 11–12).
//! * [`coordinator`] — the sharded round coordinator: an explicit round
//!   state machine (Announce → LocalCompute → NormReport → Negotiate →
//!   SecureAggregate → Repair → Commit) over a sharded client registry with
//!   worker-pool shard execution, per-shard partial tree-aggregation and
//!   deadline/straggler handling.
//! * [`fl`] — FedAvg (Alg. 3) / DSGD (Eq. 2) master-client protocol with
//!   secure aggregation and per-round communication accounting; `train`
//!   is a single-shard adapter over [`coordinator`]. `fl::availability`
//!   is the scenario engine's availability layer: streaming
//!   O(cohort)-memory cohort draws that scale to million-client pools,
//!   plus time-varying traces (diurnal schedules, session churn,
//!   correlated shard outages).
//! * [`exp`] — experiment drivers: figure regeneration, the perf bench
//!   suites, and `exp::sweep` — the `fedsamp sweep` scenario grid
//!   ({strategy × compressor × availability × pool} with multi-seed
//!   averaging → `BENCH_sweep.{json,csv}`).
//! * [`secure_agg`] — pairwise-mask additive secure aggregation.
//! * [`faults`] — the chaos layer: seeded, deterministic fault injection
//!   (mid-round crashes, payload corruption, stalled negotiation
//!   partials) over dedicated seed streams, paired with the round
//!   machine's Repair phase (mask-residue recovery, estimator
//!   renormalization, quarantine); a zero-rate plan is bitwise inert.
//! * [`telemetry`] — opt-in observability: round-phase spans, per-worker
//!   job timing histograms (p50/p90/p99), per-round counters, and JSONL +
//!   Chrome `trace_event` export; off by default and bitwise-free when
//!   off.
//! * [`data`] — synthetic federated datasets (FEMNIST-like, Shakespeare-
//!   like, CIFAR-like) incl. the paper's (s,a,b) unbalancing procedure.
//! * [`sim`] — pure-rust FL simulator over [`model`] (logistic/quadratic)
//!   for theory experiments and fast sweeps.
//! * [`runtime`] — PJRT artifact loading + execution (XLA path).
//! * [`config`] — experiment configs + per-figure presets.
//! * [`compress`] — optional update compression composed with OCS (§6),
//!   producing native [`wire`] payloads.
//! * [`wire`] — typed upload payloads (dense / sparse-k / quantized)
//!   with byte-exact framing; communication metrics are measured from
//!   the encoded wire bytes, not estimated.
//! * [`checkpoint`] — durable coordinator snapshots and the sweep's
//!   per-arm completion ledger: versioned, checksummed, crash-safely
//!   written (`--checkpoint-every` / `--resume`), with kill-and-resume
//!   pinned bitwise identical to the uninterrupted trajectory.
//!
//! ```no_run
//! use fedsamp::config::presets;
//! use fedsamp::sim::run_sim;
//!
//! let cfg = presets::femnist(1, 3); // Figure 3, m = 3
//! let result = run_sim(&cfg).unwrap();
//! println!("final accuracy {:.3}", result.final_accuracy());
//! ```

pub mod bench;
pub mod checkpoint;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod faults;
pub mod fl;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sampling;
pub mod secure_agg;
pub mod sim;
pub mod telemetry;
pub mod tensor;
pub mod util;
pub mod wire;
