//! Deterministic chaos layer: seeded fault injection and the bookkeeping
//! the round state machine needs to repair what the faults break.
//!
//! Production-scale FL must assume clients vanish mid-round and bytes
//! arrive mangled. A [`FaultPlan`] makes that regime *reproducible*:
//! every fault is a pure function of `(client, round)` (or
//! `(shard, round, exchange, attempt)` for negotiation stalls) over
//! dedicated seed streams, mirroring [`crate::fl::availability::Trace`].
//! Enabling a plan never consumes or perturbs the cohort/selection RNG,
//! so a zero-rate plan degrades **bitwise** to the fault-free trajectory
//! — the property the integration suite pins.
//!
//! Four injection points, matching where real deployments fail:
//!
//! * **crash-before-upload** (`crash_pre`): the client negotiated but its
//!   upload never starts — it neither commits pairwise masks nor sends
//!   bytes. Pure absence; no repair beyond estimator renormalization.
//! * **crash-after-mask-commitment** (`crash_post`): under secure
//!   aggregation the client joined the mask roster (its pairwise masks
//!   are woven into everyone else's uploads) and *then* died. Its
//!   uncancelled mask residue must be reconstructed and subtracted in
//!   the Repair phase ([`crate::secure_agg::SecureAggregator::recover`]).
//! * **payload corruption/truncation** (`corrupt`): the upload arrives
//!   but its wire frame is mangled in flight ([`corrupt_frame`]). Frames
//!   that fail the hardened decode ([`crate::wire::Payload::decode`] +
//!   [`crate::wire::Payload::validate_for_dim`]) quarantine the client;
//!   mutations that survive integrity checks fold silently, exactly as
//!   they would in production.
//! * **stalled negotiation partials** (`stall`): a sharded-AOCS scalar
//!   partial misses its delivery window. The coordinator retries with
//!   bounded exponential backoff (modeled as attempt-indexed draws — a
//!   later attempt is an independent, later delivery) and degrades the
//!   shard to last-good probabilities when retries are exhausted.
//!
//! ```
//! use fedsamp::faults::FaultPlan;
//! let plan = FaultPlan { crash_post: 0.2, ..FaultPlan::new(7)};
//! // pure per-(client, round) predicates: replayable anywhere
//! assert_eq!(plan.crash_post(3, 1), plan.crash_post(3, 1));
//! assert!(!FaultPlan::new(7).crash_post(3, 1)); // zero rate never fires
//! ```

use std::collections::HashMap;

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Seed-stream labels for the fault draws — dedicated streams, so chaos
/// never consumes (or perturbs) the round RNG that drives selection.
const CRASH_PRE_STREAM: u64 = 0xC4A5_15B4_E302_AD00;
const CRASH_POST_STREAM: u64 = 0xC4A5_1AF7_E302_AD01;
const CORRUPT_STREAM: u64 = 0xBAD0_B17E_5000_0002;
const CORRUPT_BYTES_STREAM: u64 = 0xBAD0_B17E_5000_0003;
const STALL_STREAM: u64 = 0x57A1_1ED0_AC75_0004;

/// Seed used when a plan comes from a CLI/sweep spec string rather than
/// config JSON — fixed so `--faults crash0.2` is reproducible across
/// runs and machines (the same convention as the sweep's trace arms).
pub const SPEC_FAULT_SEED: u64 = 0xFA17_5EED;

/// Default bounded-retry budget for stalled negotiation partials.
pub const DEFAULT_MAX_RETRIES: u32 = 3;

/// Prefix of the error message a `masterkill<r>` abort surfaces through
/// `Coordinator::run` — the CLI maps it to its own exit code (3) so the
/// kill-and-resume CI smoke can tell a planned kill from a real failure.
pub const MASTERKILL_ERR_PREFIX: &str = "masterkill:";

/// A deterministic fault-injection plan: per-kind rates over dedicated
/// seed streams. All predicates are pure functions — two evaluations of
/// the same `(client, round)` always agree, and a zero rate never even
/// constructs an RNG (the draw-free guard every hot path relies on).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for the plan's dedicated draw streams (independent of the
    /// experiment seed so chaos ablations can hold it fixed).
    pub seed: u64,
    /// Per-(client, round) probability the client crashes before its
    /// upload starts (no mask commitment, no bytes), in `[0, 1]`.
    pub crash_pre: f64,
    /// Per-(client, round) probability the client crashes after
    /// committing its pairwise masks but before its upload arrives
    /// (secure path: leaves uncancelled residue), in `[0, 1]`.
    pub crash_post: f64,
    /// Per-(client, round) probability the upload's wire frame is
    /// corrupted or truncated in flight, in `[0, 1]`.
    pub corrupt: f64,
    /// Per-(shard, round, exchange, attempt) probability a sharded
    /// negotiation partial stalls past its delivery window, in `[0, 1)`
    /// (1.0 would stall every retry forever, which is a dead master,
    /// not a fault model).
    pub stall: f64,
    /// Bounded retry budget per stalled partial before the shard is
    /// degraded to last-good probabilities.
    pub max_retries: u32,
    /// Deterministically kill the **coordinator itself** at the start of
    /// this round (the chaos layer's master-side fault; spec token
    /// `masterkill<r>`). Unlike the client-side rates, this does not
    /// flip [`FaultPlan::is_zero`]: a masterkill-only plan injects no
    /// client faults and stays on the bitwise fault-free path — the run
    /// simply dies at round `r`, which is exactly what the
    /// kill-and-resume checkpoint contract needs. Disarmed on
    /// `--resume` (the kill already happened).
    pub masterkill: Option<u64>,
}

impl FaultPlan {
    /// An all-zero plan over `seed`: injects nothing, draws nothing.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            crash_pre: 0.0,
            crash_post: 0.0,
            corrupt: 0.0,
            stall: 0.0,
            max_retries: DEFAULT_MAX_RETRIES,
            masterkill: None,
        }
    }

    /// True when no *client-side* fault kind can ever fire — the
    /// coordinator skips building a [`FaultCtx`] entirely (bitwise-inert
    /// fast path). Deliberately ignores [`FaultPlan::masterkill`]: a
    /// master-side kill is not a client fault and must not perturb the
    /// trajectory before it fires.
    pub fn is_zero(&self) -> bool {
        self.crash_pre <= 0.0
            && self.crash_post <= 0.0
            && self.corrupt <= 0.0
            && self.stall <= 0.0
    }

    fn draw(&self, stream: u64, a: u64, b: u64, p: f64) -> bool {
        // draw-free guards: rate-0 plans construct no RNG at all, and
        // certain faults burn no entropy either
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            return true;
        }
        Rng::new(self.seed ^ stream).fork(a).fork(b).bernoulli(p)
    }

    /// Does `client` crash before upload at `round`?
    pub fn crash_pre(&self, client: u64, round: u64) -> bool {
        self.draw(CRASH_PRE_STREAM, round, client, self.crash_pre)
    }

    /// Does `client` crash after mask commitment at `round`? A
    /// crash-before-upload takes precedence: a client cannot commit
    /// masks it never lived to compute.
    pub fn crash_post(&self, client: u64, round: u64) -> bool {
        !self.crash_pre(client, round)
            && self.draw(CRASH_POST_STREAM, round, client, self.crash_post)
    }

    /// Is `client`'s upload frame corrupted in flight at `round`?
    /// (Only meaningful for clients that upload at all.)
    pub fn corrupts(&self, client: u64, round: u64) -> bool {
        self.draw(CORRUPT_STREAM, round, client, self.corrupt)
    }

    /// Does delivery attempt `attempt` of `shard`'s partial for scalar
    /// exchange `exchange` stall at `round`? Attempt-indexed draws model
    /// exponential backoff: each retry is an independent, later delivery
    /// attempt, so the per-partial stall-out probability is
    /// `stall^(max_retries + 1)`.
    pub fn stalls(&self, shard: u64, round: u64, exchange: u64, attempt: u64) -> bool {
        if self.stall <= 0.0 {
            return false;
        }
        if self.stall >= 1.0 {
            return true;
        }
        Rng::new(self.seed ^ STALL_STREAM)
            .fork(round)
            .fork(shard)
            .fork(exchange)
            .fork(attempt)
            .bernoulli(self.stall)
    }

    /// The dedicated byte-mutation RNG for `client`'s round-`round`
    /// frame — separate stream from the fire/no-fire draw so adding
    /// mutation entropy never changes *which* uploads corrupt.
    pub fn corruption_rng(&self, client: u64, round: u64) -> Rng {
        Rng::new(self.seed ^ CORRUPT_BYTES_STREAM).fork(round).fork(client)
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [
            ("crash_pre", self.crash_pre),
            ("crash_post", self.crash_post),
            ("corrupt", self.corrupt),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("fault_plan.{name} must be in [0, 1]"));
            }
        }
        if !(0.0..1.0).contains(&self.stall) {
            return Err("fault_plan.stall must be in [0, 1)".into());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("crash_pre", Json::num(self.crash_pre)),
            ("crash_post", Json::num(self.crash_post)),
            ("corrupt", Json::num(self.corrupt)),
            ("stall", Json::num(self.stall)),
            ("max_retries", Json::num(self.max_retries as f64)),
            (
                "masterkill",
                match self.masterkill {
                    Some(r) => Json::num(r as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FaultPlan, String> {
        let seed = v.get("seed").as_f64().unwrap_or(0.0) as u64;
        let mut plan = FaultPlan::new(seed);
        plan.crash_pre = v.get("crash_pre").as_f64().unwrap_or(0.0);
        plan.crash_post = v.get("crash_post").as_f64().unwrap_or(0.0);
        plan.corrupt = v.get("corrupt").as_f64().unwrap_or(0.0);
        plan.stall = v.get("stall").as_f64().unwrap_or(0.0);
        plan.max_retries = v
            .get("max_retries")
            .as_usize()
            .map(|r| r as u32)
            .unwrap_or(DEFAULT_MAX_RETRIES);
        plan.masterkill = v.get("masterkill").as_f64().map(|r| r as u64);
        plan.validate()?;
        Ok(plan)
    }
}

/// Typed failure parsing a `--faults` spec — each variant carries the
/// offending token, so `--faults crash0.2,jitter0.5` names `jitter0.5`
/// instead of dying with a generic message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultSpecError {
    /// Token starts with no known fault kind.
    UnknownKind { token: String },
    /// A rate suffix (`crash<p>`, `corrupt<p>`, `stall<p>`) is not a
    /// number.
    BadRate { token: String },
    /// `retries<k>` suffix is not a non-negative integer.
    BadRetries { token: String },
    /// `seed<k>` suffix is not a non-negative integer.
    BadSeed { token: String },
    /// `masterkill<r>` suffix is not a round index.
    BadRound { token: String },
    /// Tokens parsed but the resulting plan fails
    /// [`FaultPlan::validate`] (e.g. a rate outside `[0, 1]`).
    InvalidPlan { message: String },
}

impl std::fmt::Display for FaultSpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultSpecError::UnknownKind { token } => write!(
                f,
                "unknown fault kind '{token}' (want crash/crashpre/crashpost/\
                 corrupt/stall/retries/seed/masterkill)"
            ),
            FaultSpecError::BadRate { token } => {
                write!(f, "bad fault rate in token '{token}'")
            }
            FaultSpecError::BadRetries { token } => {
                write!(f, "bad retry count in token '{token}'")
            }
            FaultSpecError::BadSeed { token } => {
                write!(f, "bad seed in token '{token}'")
            }
            FaultSpecError::BadRound { token } => {
                write!(f, "bad round index in token '{token}'")
            }
            FaultSpecError::InvalidPlan { message } => write!(f, "{message}"),
        }
    }
}

impl std::error::Error for FaultSpecError {}

impl From<FaultSpecError> for String {
    fn from(e: FaultSpecError) -> String {
        e.to_string()
    }
}

/// Parse a CLI/sweep fault spec into a plan over [`SPEC_FAULT_SEED`].
///
/// Grammar: kinds joined by `,` or `+` —
/// `crash<p>` (sets both crash rates), `crashpre<p>`, `crashpost<p>`,
/// `corrupt<p>`, `stall<p>`, `retries<k>`, `seed<k>`,
/// `masterkill<r>` (kill the coordinator at round `r`).
/// Examples: `crash0.2,corrupt0.05` · `crashpost0.3+stall0.1+retries2`
/// · `masterkill5`.
pub fn parse_fault_spec(spec: &str) -> Result<FaultPlan, FaultSpecError> {
    let mut plan = FaultPlan::new(SPEC_FAULT_SEED);
    for token in spec.split([',', '+']).filter(|t| !t.is_empty()) {
        let rate = |rest: &str| -> Result<f64, FaultSpecError> {
            rest.parse::<f64>()
                .map_err(|_| FaultSpecError::BadRate { token: token.to_string() })
        };
        // longest prefixes first: "crash" is a prefix of the others
        if let Some(rest) = token.strip_prefix("masterkill") {
            plan.masterkill = Some(rest.parse::<u64>().map_err(|_| {
                FaultSpecError::BadRound { token: token.to_string() }
            })?);
        } else if let Some(rest) = token.strip_prefix("crashpre") {
            plan.crash_pre = rate(rest)?;
        } else if let Some(rest) = token.strip_prefix("crashpost") {
            plan.crash_post = rate(rest)?;
        } else if let Some(rest) = token.strip_prefix("crash") {
            let p = rate(rest)?;
            plan.crash_pre = p;
            plan.crash_post = p;
        } else if let Some(rest) = token.strip_prefix("corrupt") {
            plan.corrupt = rate(rest)?;
        } else if let Some(rest) = token.strip_prefix("stall") {
            plan.stall = rate(rest)?;
        } else if let Some(rest) = token.strip_prefix("retries") {
            plan.max_retries = rest.parse::<u32>().map_err(|_| {
                FaultSpecError::BadRetries { token: token.to_string() }
            })?;
        } else if let Some(rest) = token.strip_prefix("seed") {
            plan.seed = rest.parse::<u64>().map_err(|_| {
                FaultSpecError::BadSeed { token: token.to_string() }
            })?;
        } else {
            return Err(FaultSpecError::UnknownKind { token: token.to_string() });
        }
    }
    plan.validate().map_err(|message| FaultSpecError::InvalidPlan { message })?;
    Ok(plan)
}

/// Mutate an encoded wire frame in place the way a flaky transport
/// would: a handful of byte flips, occasionally a truncation. The
/// mutation is guaranteed to change the frame (a flip XORs a nonzero
/// value), so every `corrupt` fire produces a genuinely adversarial
/// input for the hardened decoder.
pub fn corrupt_frame(frame: &mut Vec<u8>, rng: &mut Rng) {
    if frame.is_empty() {
        return;
    }
    if rng.bernoulli(0.25) {
        // truncation: cut the frame short (possibly to nothing)
        let keep = rng.below(frame.len() as u64) as usize;
        frame.truncate(keep);
    }
    if frame.is_empty() {
        return;
    }
    let flips = 1 + rng.below(4) as usize;
    for _ in 0..flips {
        let pos = rng.below(frame.len() as u64) as usize;
        frame[pos] ^= 1 + rng.below(255) as u8;
    }
}

/// Running fault/repair tally for one run — the chaos analogue of
/// `CoordStats`, surfaced in run JSON (via telemetry counters) and the
/// sweep CSV.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Clients that crashed before upload.
    pub crash_pre: u64,
    /// Clients that crashed after mask commitment.
    pub crash_post: u64,
    /// Uploads whose frames were corrupted in flight.
    pub corrupt: u64,
    /// Corrupted uploads that failed integrity checks and were
    /// quarantined (the rest folded silently, as in production).
    pub quarantined: u64,
    /// Stalled negotiation-partial delivery attempts.
    pub stalls: u64,
    /// Retry attempts issued for stalled partials.
    pub retries: u64,
    /// Shards degraded to last-good probabilities after retries ran out.
    pub shards_degraded: u64,
    /// Post-commit dropouts whose uncancelled mask residue was
    /// reconstructed and subtracted in the Repair phase.
    pub mask_repairs: u64,
}

impl FaultCounters {
    /// Total faults injected across all kinds.
    pub fn injected(&self) -> u64 {
        self.crash_pre + self.crash_post + self.corrupt + self.stalls
    }

    /// Total repair actions taken (mask-residue subtractions,
    /// quarantines, shard degradations).
    pub fn repaired(&self) -> u64 {
        self.mask_repairs + self.quarantined + self.shards_degraded
    }

    /// Fold another tally into this one (multi-seed sweep arms sum
    /// their per-run counters).
    pub fn absorb(&mut self, other: &FaultCounters) {
        self.crash_pre += other.crash_pre;
        self.crash_post += other.crash_post;
        self.corrupt += other.corrupt;
        self.quarantined += other.quarantined;
        self.stalls += other.stalls;
        self.retries += other.retries;
        self.shards_degraded += other.shards_degraded;
        self.mask_repairs += other.mask_repairs;
    }
}

/// Per-run chaos state threaded through the round machine: the plan,
/// the running counters, and the last-good probability cache that
/// degraded negotiation shards fall back to.
#[derive(Clone, Debug)]
pub struct FaultCtx {
    pub plan: FaultPlan,
    pub counters: FaultCounters,
    /// client id → last successfully negotiated inclusion probability
    /// (the degradation target for stalled-out shards).
    pub last_probs: HashMap<u64, f64>,
}

impl FaultCtx {
    pub fn new(plan: FaultPlan) -> FaultCtx {
        FaultCtx { plan, counters: FaultCounters::default(), last_probs: HashMap::new() }
    }

    /// Build the coordinator's chaos context: `None` unless the config
    /// carries a plan that can actually fire (zero-rate plans stay on
    /// the bitwise fault-free path).
    pub fn from_plan(plan: Option<&FaultPlan>) -> Option<FaultCtx> {
        plan.filter(|p| !p.is_zero()).map(|p| FaultCtx::new(p.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;
    use crate::wire::Payload;

    #[test]
    fn zero_plan_never_fires() {
        let plan = FaultPlan::new(9);
        assert!(plan.is_zero());
        for round in 0..20 {
            for client in 0..50 {
                assert!(!plan.crash_pre(client, round));
                assert!(!plan.crash_post(client, round));
                assert!(!plan.corrupts(client, round));
            }
            assert!(!plan.stalls(0, round, 1, 0));
        }
        assert!(FaultCtx::from_plan(Some(&plan)).is_none());
        assert!(FaultCtx::from_plan(None).is_none());
    }

    #[test]
    fn prop_draws_are_pure_and_seed_dependent() {
        quick("fault-draws", |rng, _| {
            let plan = FaultPlan {
                crash_pre: rng.f64(),
                crash_post: rng.f64(),
                corrupt: rng.f64(),
                stall: 0.99 * rng.f64(),
                ..FaultPlan::new(rng.next_u64())
            };
            let (c, k) = (rng.next_u64() % 10_000, rng.next_u64() % 1000);
            if plan.crash_pre(c, k) != plan.crash_pre(c, k)
                || plan.crash_post(c, k) != plan.crash_post(c, k)
                || plan.corrupts(c, k) != plan.corrupts(c, k)
                || plan.stalls(c % 16, k, 2, 1) != plan.stalls(c % 16, k, 2, 1)
            {
                return Err("fault draw not a pure function".into());
            }
            if plan.crash_pre(c, k) && plan.crash_post(c, k) {
                return Err("crash_pre and crash_post both fired".into());
            }
            Ok(())
        });
    }

    #[test]
    fn rates_are_respected_empirically() {
        let plan = FaultPlan { crash_pre: 0.3, corrupt: 0.1, ..FaultPlan::new(5) };
        let (mut pre, mut cor) = (0usize, 0usize);
        let total = 20_000;
        for round in 0..200 {
            for client in 0..100 {
                pre += plan.crash_pre(client, round) as usize;
                cor += plan.corrupts(client, round) as usize;
            }
        }
        let pre_rate = pre as f64 / total as f64;
        let cor_rate = cor as f64 / total as f64;
        assert!((pre_rate - 0.3).abs() < 0.02, "{pre_rate}");
        assert!((cor_rate - 0.1).abs() < 0.02, "{cor_rate}");
    }

    #[test]
    fn spec_grammar_round_trips_the_readme_examples() {
        let plan = parse_fault_spec("crash0.2,corrupt0.05").unwrap();
        assert_eq!(plan.crash_pre, 0.2);
        assert_eq!(plan.crash_post, 0.2);
        assert_eq!(plan.corrupt, 0.05);
        assert_eq!(plan.seed, SPEC_FAULT_SEED);
        assert_eq!(plan.max_retries, DEFAULT_MAX_RETRIES);

        let plan = parse_fault_spec("crashpost0.3+stall0.1+retries2+seed7").unwrap();
        assert_eq!(plan.crash_pre, 0.0);
        assert_eq!(plan.crash_post, 0.3);
        assert_eq!(plan.stall, 0.1);
        assert_eq!(plan.max_retries, 2);
        assert_eq!(plan.seed, 7);

        assert!(parse_fault_spec("crashpre1.0").unwrap().crash_pre == 1.0);
        assert!(parse_fault_spec("jitter0.5").is_err());
        assert!(parse_fault_spec("crash1.5").is_err()); // validate() rejects
        assert!(parse_fault_spec("stall1.0").is_err());
        assert!(parse_fault_spec("crashNaNo").is_err());
    }

    #[test]
    fn spec_errors_are_typed_and_name_the_offending_token() {
        assert_eq!(
            parse_fault_spec("jitter0.5"),
            Err(FaultSpecError::UnknownKind { token: "jitter0.5".into() })
        );
        assert_eq!(
            parse_fault_spec("corruptx"),
            Err(FaultSpecError::BadRate { token: "corruptx".into() })
        );
        assert_eq!(
            parse_fault_spec("retries-1"),
            Err(FaultSpecError::BadRetries { token: "retries-1".into() })
        );
        assert_eq!(
            parse_fault_spec("seedless"),
            Err(FaultSpecError::BadSeed { token: "seedless".into() })
        );
        assert_eq!(
            parse_fault_spec("masterkillx"),
            Err(FaultSpecError::BadRound { token: "masterkillx".into() })
        );
        assert!(matches!(
            parse_fault_spec("crash1.5"),
            Err(FaultSpecError::InvalidPlan { .. })
        ));
        // every Display carries the culprit token so CLI users see it
        for spec in ["jitter0.5", "corruptx", "retries-1", "seedless", "masterkillx"] {
            let msg: String = parse_fault_spec(spec).unwrap_err().into();
            let token = spec;
            assert!(msg.contains(token), "{msg} should name {token}");
        }
    }

    #[test]
    fn masterkill_parses_and_stays_off_the_client_fault_path() {
        let plan = parse_fault_spec("masterkill5").unwrap();
        assert_eq!(plan.masterkill, Some(5));
        // a masterkill-only plan is still "zero": no client faults, no
        // FaultCtx, bitwise-identical trajectory until the kill fires
        assert!(plan.is_zero());
        assert!(FaultCtx::from_plan(Some(&plan)).is_none());

        let plan = parse_fault_spec("masterkill3,crash0.2").unwrap();
        assert_eq!(plan.masterkill, Some(3));
        assert!(!plan.is_zero());

        // JSON round trip keeps the field (and its absence)
        let with = FaultPlan { masterkill: Some(9), ..FaultPlan::new(1) };
        assert_eq!(FaultPlan::from_json(&with.to_json()).unwrap(), with);
        let without = FaultPlan::new(1);
        assert_eq!(FaultPlan::from_json(&without.to_json()).unwrap(), without);
    }

    #[test]
    fn plan_json_round_trips() {
        let plan = FaultPlan {
            crash_pre: 0.1,
            crash_post: 0.25,
            corrupt: 0.05,
            stall: 0.2,
            max_retries: 5,
            ..FaultPlan::new(42)
        };
        assert_eq!(FaultPlan::from_json(&plan.to_json()).unwrap(), plan);
        assert!(FaultPlan::from_json(&Json::obj(vec![(
            "crash_pre",
            Json::num(2.0)
        )]))
        .is_err());
    }

    #[test]
    fn corrupt_frame_always_changes_a_nonempty_frame() {
        quick("corrupt-frame", |rng, _| {
            let len = 1 + rng.below(200) as usize;
            let frame: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let mut mutated = frame.clone();
            let mut frng = Rng::new(rng.next_u64());
            corrupt_frame(&mut mutated, &mut frng);
            if mutated == frame {
                return Err("mutation left the frame untouched".into());
            }
            Ok(())
        });
    }

    #[test]
    fn corruption_rng_is_per_client_per_round() {
        let plan = FaultPlan { corrupt: 1.0, ..FaultPlan::new(3) };
        let mut payload = Vec::new();
        Payload::Dense(vec![1.0; 8]).encode_into(&mut payload);
        let mut a = payload.clone();
        let mut b = payload.clone();
        corrupt_frame(&mut a, &mut plan.corruption_rng(1, 0));
        corrupt_frame(&mut b, &mut plan.corruption_rng(2, 0));
        // different clients draw from different mutation streams
        assert_ne!(a, b);
        let mut a2 = payload.clone();
        corrupt_frame(&mut a2, &mut plan.corruption_rng(1, 0));
        assert_eq!(a, a2, "mutation must be replayable");
    }

    #[test]
    fn stallout_needs_every_attempt_to_stall() {
        let plan = FaultPlan { stall: 0.5, max_retries: 2, ..FaultPlan::new(8) };
        // empirical stall-out rate across many (shard, round) cells is
        // roughly stall^(retries+1)
        let mut outs = 0usize;
        let cells = 4000;
        for round in 0..500u64 {
            for shard in 0..8u64 {
                let mut attempt = 0u64;
                let stalled_out = loop {
                    if !plan.stalls(shard, round, 1, attempt) {
                        break false;
                    }
                    if attempt >= plan.max_retries as u64 {
                        break true;
                    }
                    attempt += 1;
                };
                outs += stalled_out as usize;
            }
        }
        let rate = outs as f64 / cells as f64;
        assert!((rate - 0.125).abs() < 0.03, "stall-out rate {rate}");
    }

    #[test]
    fn counters_summarize() {
        let c = FaultCounters {
            crash_pre: 2,
            crash_post: 3,
            corrupt: 4,
            quarantined: 1,
            stalls: 5,
            retries: 4,
            shards_degraded: 1,
            mask_repairs: 3,
        };
        assert_eq!(c.injected(), 14);
        assert_eq!(c.repaired(), 5);
    }
}
