//! The XLA-path [`ClientEngine`]: per-client local training through the
//! AOT artifacts, with an optional persistent worker pool.
//!
//! PJRT handles are thread-local (`Rc`), so each worker thread constructs
//! its *own* [`Runtime`] at startup (one compile per worker, amortized
//! over the whole run) and pulls `(round, client)` jobs from a shared
//! queue; only plain `Vec<f32>` data crosses threads.
//!
//! Host-side folds here (delta math, the `EngineRunner` masked folds)
//! ride `tensor::kernels` and therefore the process-wide kernel-backend
//! selection of `tensor::dispatch` (DESIGN.md §12); the XLA executables
//! themselves are untouched by that knob.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::Algorithm;
use crate::data::{ClientData, FederatedData};
use crate::fl::{ClientEngine, EvalOutcome, LocalOutcome};
use crate::tensor;
use crate::util::rng::Rng;

use super::{RtResult, Runtime};

/// Gather batch rows into contiguous buffers.
fn gather_batch(
    data: &ClientData,
    idx: &[usize],
) -> (Vec<f32>, Vec<i32>, Vec<u32>) {
    let dim = data.dim;
    let mut labels = Vec::with_capacity(idx.len());
    if data.is_tokens() {
        let mut toks = Vec::with_capacity(idx.len() * dim);
        for &i in idx {
            toks.extend_from_slice(data.token_row(i));
            labels.push(data.labels[i]);
        }
        (Vec::new(), toks, labels)
    } else {
        let mut xs = Vec::with_capacity(idx.len() * dim);
        for &i in idx {
            xs.extend_from_slice(data.dense_row(i));
            labels.push(data.labels[i]);
        }
        (xs, Vec::new(), labels)
    }
}

/// One client's local pass on a [`Runtime`] (shared by the single-thread
/// path and the pool workers).
pub fn local_train(
    rt: &Runtime,
    data: &ClientData,
    round: usize,
    client_id: usize,
    global: &[f32],
    algorithm: &Algorithm,
    seed: u64,
) -> RtResult<LocalOutcome> {
    let batch_size = rt.manifest.batch_size;
    let mut rng =
        Rng::new(seed ^ 0x10CA1).fork(round as u64).fork(client_id as u64);
    let mut params = rt.params_to_literals(global)?;
    let mut loss_sum = 0.0f64;
    let mut steps = 0usize;

    match algorithm {
        Algorithm::Dsgd { .. } => {
            // one stochastic batch, lr=1 ⇒ delta = exact minibatch gradient
            let idx: Vec<usize> = (0..batch_size)
                .map(|_| rng.range(0, data.len()))
                .collect();
            let (xs, toks, labels) = gather_batch(data, &idx);
            let xb = rt.input_literal(
                Some(&xs).filter(|v| !v.is_empty()).map(Vec::as_slice),
                Some(&toks).filter(|v| !v.is_empty()).map(Vec::as_slice),
                batch_size,
            )?;
            let oh = rt.onehot_literal(&labels, batch_size)?;
            loss_sum += rt.train_step(&mut params, &xb, &oh, 1.0)?;
            steps += 1;
        }
        Algorithm::FedAvg { local_epochs, eta_l, .. } => {
            for _ in 0..*local_epochs {
                for bidx in data.epoch_batches(batch_size, &mut rng) {
                    let (xs, toks, labels) = gather_batch(data, &bidx);
                    let xb = rt.input_literal(
                        Some(&xs).filter(|v| !v.is_empty()).map(Vec::as_slice),
                        Some(&toks)
                            .filter(|v| !v.is_empty())
                            .map(Vec::as_slice),
                        batch_size,
                    )?;
                    let oh = rt.onehot_literal(&labels, batch_size)?;
                    loss_sum +=
                        rt.train_step(&mut params, &xb, &oh, *eta_l as f32)?;
                    steps += 1;
                }
            }
        }
    }

    let y = rt.literals_to_params(&params)?;
    Ok(LocalOutcome {
        delta: tensor::sub(global, &y),
        train_loss: loss_sum / steps.max(1) as f64,
        examples: data.len(),
    })
}

/// Evaluate a flat parameter vector over a validation split.
pub fn evaluate(
    rt: &Runtime,
    val: &ClientData,
    global: &[f32],
) -> RtResult<EvalOutcome> {
    let eb = rt.manifest.eval_batch;
    let params = rt.params_to_literals(global)?;
    let per = rt.manifest.input_elems();
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let n = val.len();
    let mut i = 0usize;
    while i < n {
        let take = (n - i).min(eb);
        let idx: Vec<usize> = (i..i + take).collect();
        let (mut xs, mut toks, mut labels) = gather_batch(val, &idx);
        // pad the tail with masked rows (all-zero one-hot)
        if take < eb {
            labels.resize(eb, u32::MAX);
            if val.is_tokens() {
                toks.resize(eb * per, 0);
            } else {
                xs.resize(eb * per, 0.0);
            }
        }
        let xb = rt.input_literal(
            Some(&xs).filter(|v| !v.is_empty()).map(Vec::as_slice),
            Some(&toks).filter(|v| !v.is_empty()).map(Vec::as_slice),
            eb,
        )?;
        let oh = rt.onehot_literal(&labels, eb)?;
        let (l, c) = rt.eval_step(&params, &xb, &oh)?;
        loss += l;
        correct += c;
        i += take;
    }
    Ok(EvalOutcome {
        loss: loss / n.max(1) as f64,
        accuracy: correct / n.max(1) as f64,
    })
}

// ---------------------------------------------------------------------------
// worker pool
// ---------------------------------------------------------------------------

struct Job {
    order: usize,
    round: usize,
    client: usize,
    global: Arc<Vec<f32>>,
}

struct Reply {
    order: usize,
    outcome: Result<LocalOutcome, String>,
}

struct WorkerPool {
    jobs: mpsc::Sender<Job>,
    replies: mpsc::Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    fn spawn(
        workers: usize,
        artifacts_dir: String,
        model: String,
        data: Arc<FederatedData>,
        algorithm: Algorithm,
        seed: u64,
    ) -> WorkerPool {
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (rep_tx, rep_rx) = mpsc::channel::<Reply>();
        let handles = (0..workers)
            .map(|_| {
                let job_rx = Arc::clone(&job_rx);
                let rep_tx = rep_tx.clone();
                let dir = artifacts_dir.clone();
                let model = model.clone();
                let data = Arc::clone(&data);
                let algorithm = algorithm.clone();
                std::thread::spawn(move || {
                    // thread-local runtime (PJRT handles are not Send)
                    let rt = match Runtime::load(&dir, &model) {
                        Ok(rt) => rt,
                        Err(e) => {
                            // surface the error on the first job instead
                            while let Ok(job) = recv_job(&job_rx) {
                                let _ = rep_tx.send(Reply {
                                    order: job.order,
                                    outcome: Err(format!("worker init: {e}")),
                                });
                            }
                            return;
                        }
                    };
                    while let Ok(job) = recv_job(&job_rx) {
                        let outcome = local_train(
                            &rt,
                            &data.clients[job.client],
                            job.round,
                            job.client,
                            &job.global,
                            &algorithm,
                            seed,
                        )
                        .map_err(|e| e.to_string());
                        if rep_tx
                            .send(Reply { order: job.order, outcome })
                            .is_err()
                        {
                            break;
                        }
                    }
                })
            })
            .collect();
        WorkerPool { jobs: job_tx, replies: rep_rx, handles }
    }
}

fn recv_job(rx: &Arc<Mutex<mpsc::Receiver<Job>>>) -> Result<Job, mpsc::RecvError> {
    rx.lock().expect("job queue poisoned").recv()
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the channel stops the workers
        let (dead_tx, _) = mpsc::channel();
        self.jobs = dead_tx;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// engine
// ---------------------------------------------------------------------------

/// XLA-backed [`ClientEngine`].
pub struct XlaEngine {
    runtime: Runtime, // main-thread runtime (eval + single-thread path)
    data: Arc<FederatedData>,
    algorithm: Algorithm,
    seed: u64,
    pool: Option<WorkerPool>,
}

impl XlaEngine {
    /// `workers == 0 or 1` runs clients sequentially on the main thread;
    /// more spawns that many persistent PJRT workers.
    pub fn new(
        artifacts_dir: &str,
        model: &str,
        data: FederatedData,
        algorithm: Algorithm,
        workers: usize,
        seed: u64,
    ) -> RtResult<XlaEngine> {
        let runtime = Runtime::load(artifacts_dir, model)?;
        let data = Arc::new(data);
        let pool = if workers > 1 {
            Some(WorkerPool::spawn(
                workers,
                artifacts_dir.to_string(),
                model.to_string(),
                Arc::clone(&data),
                algorithm.clone(),
                seed,
            ))
        } else {
            None
        };
        Ok(XlaEngine { runtime, data, algorithm, seed, pool })
    }

    pub fn manifest(&self) -> &super::manifest::ModelManifest {
        &self.runtime.manifest
    }
}

impl ClientEngine for XlaEngine {
    fn dim(&self) -> usize {
        self.runtime.manifest.num_params
    }

    fn num_clients(&self) -> usize {
        self.data.clients.len()
    }

    fn client_examples(&self, id: usize) -> usize {
        self.data.clients[id].len()
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        // deterministic AOT init, plus a seed-dependent jitter so seed
        // sweeps explore different basins (matches the paper's 5-seed
        // protocol)
        let mut p = self.runtime.init_params().expect("init params");
        if seed != 0 {
            let mut rng = Rng::new(seed ^ 0x1217);
            for v in p.iter_mut() {
                if *v != 0.0 {
                    *v *= 1.0 + 0.02 * rng.gaussian() as f32;
                }
            }
        }
        p
    }

    fn run_local(
        &mut self,
        round: usize,
        global: &[f32],
        cohort: &[usize],
    ) -> Vec<LocalOutcome> {
        match &self.pool {
            None => cohort
                .iter()
                .map(|&id| {
                    local_train(
                        &self.runtime,
                        &self.data.clients[id],
                        round,
                        id,
                        global,
                        &self.algorithm,
                        self.seed,
                    )
                    .expect("local training failed")
                })
                .collect(),
            Some(pool) => {
                let global = Arc::new(global.to_vec());
                for (order, &client) in cohort.iter().enumerate() {
                    pool.jobs
                        .send(Job {
                            order,
                            round,
                            client,
                            global: Arc::clone(&global),
                        })
                        .expect("worker pool dead");
                }
                let mut out: Vec<Option<LocalOutcome>> =
                    vec![None; cohort.len()];
                for _ in 0..cohort.len() {
                    let rep = pool.replies.recv().expect("worker pool dead");
                    out[rep.order] =
                        Some(rep.outcome.expect("local training failed"));
                }
                out.into_iter().map(Option::unwrap).collect()
            }
        }
    }

    fn evaluate(&mut self, global: &[f32]) -> EvalOutcome {
        evaluate(&self.runtime, &self.data.validation, global)
            .expect("evaluation failed")
    }
}
