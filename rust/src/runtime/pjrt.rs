//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! coordinator hot path (adapted from /opt/xla-example/load_hlo).
//!
//! One [`Runtime`] owns a PJRT CPU client plus the compiled train/eval
//! executables for one model. Parameters cross the boundary as a flat
//! `Vec<f32>` (layout = manifest order); inside a local epoch they stay
//! as per-tensor [`xla::Literal`]s so repeated train steps avoid the
//! flat↔literal conversions (the hot-path optimization measured in
//! EXPERIMENTS.md §Perf).
//!
//! `PjRtClient` is `Rc`-based (not `Send`): a [`Runtime`] must live and
//! die on one thread. [`crate::runtime::engine`] builds one per worker.
//!
//! Compiled only with `--features xla` (needs the vendored `xla` bindings
//! crate); the default build substitutes [`super::stub`].

use super::manifest::{load_init_params, load_manifest, ModelManifest};
use super::RtResult;

pub use xla::Literal;

/// Loaded executables + manifest for one model.
pub struct Runtime {
    pub manifest: ModelManifest,
    client: xla::PjRtClient,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
}

/// Owned parameter state in literal form (one entry per tensor).
pub struct ParamLiterals(Vec<xla::Literal>);

impl Runtime {
    /// Load and compile one model's artifacts.
    pub fn load(artifacts_dir: &str, model: &str) -> RtResult<Runtime> {
        let manifest = load_manifest(artifacts_dir, model)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu: {e}"))?;
        let compile =
            |path: &std::path::Path| -> RtResult<xla::PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(path)
                    .map_err(|e| format!("parse {path:?}: {e}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| format!("compile {path:?}: {e}"))
            };
        let train_exe = compile(&manifest.train_hlo)?;
        let eval_exe = compile(&manifest.eval_hlo)?;
        Ok(Runtime { manifest, client, train_exe, eval_exe })
    }

    /// The model's deterministic initial parameters (from aot.py).
    pub fn init_params(&self) -> RtResult<Vec<f32>> {
        load_init_params(&self.manifest)
    }

    /// Flat parameter vector → per-tensor literals.
    pub fn params_to_literals(&self, flat: &[f32]) -> RtResult<ParamLiterals> {
        if flat.len() != self.manifest.num_params {
            return Err(format!(
                "param length {} != manifest {}",
                flat.len(),
                self.manifest.num_params
            ));
        }
        let mut lits = Vec::with_capacity(self.manifest.params.len());
        let mut off = 0usize;
        for spec in &self.manifest.params {
            let chunk = &flat[off..off + spec.size];
            off += spec.size;
            let dims: Vec<i64> =
                spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(chunk)
                .reshape(&dims)
                .map_err(|e| format!("reshape {}: {e}", spec.name))?;
            lits.push(lit);
        }
        Ok(ParamLiterals(lits))
    }

    /// Per-tensor literals → flat parameter vector.
    pub fn literals_to_params(&self, lits: &ParamLiterals) -> RtResult<Vec<f32>> {
        let mut flat = Vec::with_capacity(self.manifest.num_params);
        for lit in &lits.0 {
            flat.extend(
                lit.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))?,
            );
        }
        Ok(flat)
    }

    /// Build the dense/token input literal for a batch.
    pub fn input_literal(
        &self,
        rows_f32: Option<&[f32]>,
        rows_i32: Option<&[i32]>,
        batch: usize,
    ) -> RtResult<xla::Literal> {
        let per = self.manifest.input_elems();
        let mut dims: Vec<i64> = vec![batch as i64];
        dims.extend(self.manifest.input_shape.iter().map(|&d| d as i64));
        match self.manifest.input_dtype.as_str() {
            "f32" => {
                let rows = rows_f32.ok_or("need f32 rows")?;
                debug_assert_eq!(rows.len(), batch * per);
                xla::Literal::vec1(rows)
                    .reshape(&dims)
                    .map_err(|e| format!("reshape input: {e}"))
            }
            "i32" => {
                let rows = rows_i32.ok_or("need i32 rows")?;
                debug_assert_eq!(rows.len(), batch * per);
                xla::Literal::vec1(rows)
                    .reshape(&dims)
                    .map_err(|e| format!("reshape input: {e}"))
            }
            other => Err(format!("unsupported input dtype {other}")),
        }
    }

    /// One-hot label literal `(batch, classes)`; entries with
    /// `label == u32::MAX` become all-zero rows (padding mask).
    pub fn onehot_literal(
        &self,
        labels: &[u32],
        batch: usize,
    ) -> RtResult<xla::Literal> {
        let c = self.manifest.num_classes;
        debug_assert_eq!(labels.len(), batch);
        let mut oh = vec![0.0f32; batch * c];
        for (i, &l) in labels.iter().enumerate() {
            if l != u32::MAX {
                oh[i * c + l as usize] = 1.0;
            }
        }
        xla::Literal::vec1(&oh)
            .reshape(&[batch as i64, c as i64])
            .map_err(|e| format!("reshape onehot: {e}"))
    }

    /// Execute one train step: `(params, xb, onehot, lr) → (params', loss)`.
    /// The literal params are replaced in place.
    pub fn train_step(
        &self,
        params: &mut ParamLiterals,
        xb: &xla::Literal,
        onehot: &xla::Literal,
        lr: f32,
    ) -> RtResult<f64> {
        let n = self.manifest.params.len();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(n + 3);
        args.extend(params.0.iter());
        args.push(xb);
        args.push(onehot);
        let lr_lit = xla::Literal::scalar(lr);
        args.push(&lr_lit);
        let bufs = self
            .train_exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| format!("train execute: {e}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| format!("train readback: {e}"))?;
        let mut parts =
            result.to_tuple().map_err(|e| format!("train tuple: {e}"))?;
        if parts.len() != n + 1 {
            return Err(format!(
                "train output arity {} != {}",
                parts.len(),
                n + 1
            ));
        }
        let loss = parts
            .pop()
            .unwrap()
            .get_first_element::<f32>()
            .map_err(|e| format!("train loss: {e}"))? as f64;
        params.0 = parts;
        Ok(loss)
    }

    /// Execute the eval step: `(params, xb, onehot) → (loss_sum, correct)`.
    pub fn eval_step(
        &self,
        params: &ParamLiterals,
        xb: &xla::Literal,
        onehot: &xla::Literal,
    ) -> RtResult<(f64, f64)> {
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(self.manifest.params.len() + 2);
        args.extend(params.0.iter());
        args.push(xb);
        args.push(onehot);
        let bufs = self
            .eval_exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| format!("eval execute: {e}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| format!("eval readback: {e}"))?;
        let (loss, correct) =
            result.to_tuple2().map_err(|e| format!("eval tuple: {e}"))?;
        Ok((
            loss.get_first_element::<f32>()
                .map_err(|e| format!("eval loss: {e}"))? as f64,
            correct
                .get_first_element::<f32>()
                .map_err(|e| format!("eval correct: {e}"))? as f64,
        ))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
