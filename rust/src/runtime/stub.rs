//! Std-only stand-in for the PJRT runtime (built when the `xla` feature
//! is off — the default in environments without the vendored `xla`
//! bindings crate).
//!
//! The stub keeps the exact API surface of `super::pjrt` so the engine,
//! worker pool and experiment drivers compile unchanged; every execution
//! entry point fails loudly at [`Runtime::load`] with a actionable
//! message. Manifest parsing ([`super::manifest`]) stays fully functional
//! either way — it is plain JSON over std.

use super::manifest::ModelManifest;
use super::RtResult;

const UNAVAILABLE: &str =
    "XLA runtime unavailable in this build: vendor the `xla` bindings \
     crate, add it to Cargo.toml [dependencies], and rebuild with \
     `--features xla` — or use a `native:*` model for the sim path";

/// Placeholder for `xla::Literal` (never constructed).
pub struct Literal;

/// Placeholder for the per-tensor parameter literals (never constructed).
pub struct ParamLiterals(());

/// API-compatible stub of the PJRT runtime.
pub struct Runtime {
    pub manifest: ModelManifest,
}

impl Runtime {
    pub fn load(_artifacts_dir: &str, _model: &str) -> RtResult<Runtime> {
        Err(UNAVAILABLE.into())
    }

    pub fn init_params(&self) -> RtResult<Vec<f32>> {
        Err(UNAVAILABLE.into())
    }

    pub fn params_to_literals(&self, _flat: &[f32]) -> RtResult<ParamLiterals> {
        Err(UNAVAILABLE.into())
    }

    pub fn literals_to_params(
        &self,
        _lits: &ParamLiterals,
    ) -> RtResult<Vec<f32>> {
        Err(UNAVAILABLE.into())
    }

    pub fn input_literal(
        &self,
        _rows_f32: Option<&[f32]>,
        _rows_i32: Option<&[i32]>,
        _batch: usize,
    ) -> RtResult<Literal> {
        Err(UNAVAILABLE.into())
    }

    pub fn onehot_literal(
        &self,
        _labels: &[u32],
        _batch: usize,
    ) -> RtResult<Literal> {
        Err(UNAVAILABLE.into())
    }

    pub fn train_step(
        &self,
        _params: &mut ParamLiterals,
        _xb: &Literal,
        _onehot: &Literal,
        _lr: f32,
    ) -> RtResult<f64> {
        Err(UNAVAILABLE.into())
    }

    pub fn eval_step(
        &self,
        _params: &ParamLiterals,
        _xb: &Literal,
        _onehot: &Literal,
    ) -> RtResult<(f64, f64)> {
        Err(UNAVAILABLE.into())
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_actionable_message() {
        let err = Runtime::load("/nonexistent", "femnist_mlp").unwrap_err();
        assert!(err.contains("--features xla"), "{err}");
    }
}
